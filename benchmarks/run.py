"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the repo contract:
  * us_per_call — wall-clock microseconds of the benchmarked call (for the
    sim-tier serving runs this is the bench wall time; for kernels it is the
    per-op latency),
  * derived — the paper-facing metric (tokens/s, latency, regret slope, ...).

Run everything:   PYTHONPATH=src python -m benchmarks.run
Single item:      PYTHONPATH=src python -m benchmarks.run --only table5
Fast smoke:       PYTHONPATH=src python -m benchmarks.run --fast
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import numpy as np

from .common import (CSV, PAIRS, POLICIES, POLICY_LABEL, VICUNA_13B,
                     VICUNA_68M, bench_out, run_cluster, run_serving,
                     saturated_gamma_stats, timed)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.bandits import make_policy  # noqa: E402
from repro.core.cswitch import CSwitchTable  # noqa: E402
from repro.core.planner import NightjarPlanner  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.serving.costmodel import (RTX_4090, RooflineCostModel)  # noqa: E402
from repro.serving.workload import dynamic_rate_trace  # noqa: E402


# ---------------------------------------------------------------------------
# Figure 2: throughput vs request rate for fixed speculative lengths
# ---------------------------------------------------------------------------


def fig2_fixed_gamma(csv: CSV, fast: bool):
    rates = [5, 15, 25] if fast else [2, 5, 10, 15, 20, 25, 30]
    gammas = [0, 1, 3, 5]
    for rate in rates:
        n = max(int(rate * (8 if fast else 15)), 40)
        for g in gammas:
            t0 = time.perf_counter()
            m, _ = run_serving("7b", f"fixed-{g}" if g else "ar", rate=rate,
                               n=n, dataset="sharegpt")
            csv.add(f"fig2.qps{rate}.gamma{g}",
                    (time.perf_counter() - t0) * 1e6,
                    f"throughput={m.throughput:.1f}tok/s")


# ---------------------------------------------------------------------------
# Figure 9 + Tables 5/6: method comparison
# ---------------------------------------------------------------------------


def table5_table6(csv: CSV, fast: bool):
    pairs = ["7b"] if fast else ["7b", "13b"]
    datasets = ["sharegpt"] if fast else ["alpaca", "sharegpt", "specbench"]
    trace = dynamic_rate_trace(duration_s=40 if fast else 90,
                               low=3, high=28, period_s=20)
    for pair in pairs:
        for ds in datasets:
            n = 150 if fast else 400
            for pol in POLICIES:
                t0 = time.perf_counter()
                m, _ = run_serving(pair, pol, trace=trace, n=n, dataset=ds)
                csv.add(f"table5.{pair}.{ds}.{POLICY_LABEL[pol]}",
                        (time.perf_counter() - t0) * 1e6,
                        f"throughput={m.throughput:.1f}tok/s")
                csv.add(f"table6.{pair}.{ds}.{POLICY_LABEL[pol]}", 0.0,
                        f"mean_latency={m.mean_latency*1e3:.0f}ms;"
                        f"ttft={m.mean_ttft*1e3:.0f}ms")


def fig9_low_high(csv: CSV, fast: bool):
    for label, rate in (("low", 3), ("high", 28)):
        n = max(int(rate * (10 if fast else 20)), 50)
        for pol in POLICIES:
            t0 = time.perf_counter()
            m, _ = run_serving("7b", pol, rate=rate, n=n, dataset="sharegpt")
            csv.add(f"fig9.{label}.{POLICY_LABEL[pol]}",
                    (time.perf_counter() - t0) * 1e6,
                    f"throughput={m.throughput:.1f}tok/s")


# ---------------------------------------------------------------------------
# Figure 11: throughput trace under the dynamic request-rate trace
# ---------------------------------------------------------------------------


def fig11_dynamic_trace(csv: CSV, fast: bool):
    trace = dynamic_rate_trace(duration_s=40 if fast else 80, low=3, high=25,
                               period_s=20)
    n = 200 if fast else 500
    for pol in (["ar", "sd", "nightjar"] if fast else POLICIES):
        m, _ = run_serving("7b", pol, trace=trace, n=n, dataset="sharegpt",
                           record_timeline=True)
        # bucket the timeline into 5s windows
        win, acc = {}, {}
        for r in m.timeline:
            w = int(r["t"] // 5)
            win[w] = win.get(w, 0) + r["tokens"]
        series = [round(win.get(w, 0) / 5.0, 1)
                  for w in range(int(m.elapsed // 5) + 1)]
        csv.add(f"fig11.{POLICY_LABEL[pol]}", 0.0,
                "trace_tok_s=" + "|".join(str(s) for s in series[:24]))


# ---------------------------------------------------------------------------
# Figure 12: bandit-method ablation
# ---------------------------------------------------------------------------


def fig12_bandit_ablation(csv: CSV, fast: bool):
    datasets = ["sharegpt"] if fast else ["alpaca", "sharegpt", "specbench"]
    pols = ["eps-greedy", "linucb", "banditspec", "ada-bingreedy", "nightjar"]
    for ds in datasets:
        for rate in ([5, 25] if fast else [3, 10, 25]):
            n = max(int(rate * 12), 60)
            for pol in pols:
                m, _ = run_serving("7b", pol, rate=rate, n=n, dataset=ds)
                csv.add(f"fig12.{ds}.qps{rate}.{POLICY_LABEL[pol]}", 0.0,
                        f"throughput={m.throughput:.1f}tok/s")


# ---------------------------------------------------------------------------
# Figure 13: offload ablation (throughput + TTFT), Figure 14: threshold sweep
# ---------------------------------------------------------------------------


def fig13_offload(csv: CSV, fast: bool):
    # memory pressure: small KV reserve + high rate on the 24GB card
    for rate in ([30] if fast else [20, 30, 35]):
        n = max(int(rate * (10 if fast else 18)), 80)
        for off in (True, False):
            m, eng = run_serving("7b", "nightjar", rate=rate, n=n,
                                 dataset="sharegpt", enable_offload=off,
                                 kv_reserve_frac=0.35)
            name = "offload" if off else "no-offload"
            csv.add(f"fig13.qps{rate}.{name}", 0.0,
                    f"throughput={m.throughput:.1f}tok/s;"
                    f"ttft={m.mean_ttft*1e3:.0f}ms;"
                    f"offloads={m.offload_events};reloads={m.reload_events}")


def fig14_threshold(csv: CSV, fast: bool):
    fracs = [0.05, 0.1, 0.2] if fast else [0.02, 0.05, 0.1, 0.2, 0.4]
    for frac in fracs:
        m, _ = run_serving("7b", "nightjar", rate=28, n=250,
                           dataset="sharegpt", tau_low_frac=frac,
                           kv_reserve_frac=0.35)
        csv.add(f"fig14.tau{int(frac*100)}pct", 0.0,
                f"throughput={m.throughput:.1f}tok/s")


# ---------------------------------------------------------------------------
# Figure 15: Nightjar vs every fixed gamma (13B)
# ---------------------------------------------------------------------------


def fig15_fixed_vs_adaptive(csv: CSV, fast: bool):
    rates = [5, 20] if fast else [3, 8, 15, 25]
    for rate in rates:
        n = max(int(rate * 12), 60)
        best_fixed, best_name = 0.0, ""
        for g in range(0, 6):
            m, _ = run_serving("13b", f"fixed-{g}" if g else "ar",
                               rate=rate, n=n, dataset="specbench")
            if m.throughput > best_fixed:
                best_fixed, best_name = m.throughput, f"gamma{g}"
            csv.add(f"fig15.qps{rate}.gamma{g}", 0.0,
                    f"throughput={m.throughput:.1f}tok/s")
        m, _ = run_serving("13b", "nightjar", rate=rate, n=n,
                           dataset="specbench")
        csv.add(f"fig15.qps{rate}.nightjar", 0.0,
                f"throughput={m.throughput:.1f}tok/s;"
                f"best_fixed={best_name}:{best_fixed:.1f}")


# ---------------------------------------------------------------------------
# Chunked-prefill hybrid batching: monolithic vs chunked tail latency
# ---------------------------------------------------------------------------


def prefill_hybrid(csv: CSV, fast: bool):
    """Monolithic vs chunked prefill at {low,high} arrival rate.

    The high-rate cell is the paper's dynamic-load, compute-bound regime:
    monolithic admission prefills whole prompt batches in one call and every
    running sequence stalls behind them (head-of-line blocking), which shows
    up as p99 TTFT / SLO-goodput — exactly the tail the chunked token-budget
    scheduler is built to fix.  Reports p50/p99 TTFT, SLO attainment and
    goodput for each cell.  The budget is TOTAL step tokens (Sarathi
    decode-token accounting): 384 = ~128 decode slots at saturation plus a
    256-token prefill share."""
    chunk = 384
    cells = (("low", 8), ("high", 80))
    for label, rate in cells:
        n = max(int(rate * (2 if fast else 5)), 30)
        for mode, ct in (("monolithic", 0), (f"chunk{chunk}", chunk)):
            t0 = time.perf_counter()
            m, _ = run_serving("7b", "nightjar", rate=rate, n=n,
                               dataset="alpaca", chunk_tokens=ct)
            csv.add(f"prefill.{label}.{mode}",
                    (time.perf_counter() - t0) * 1e6,
                    f"p50_ttft={m.ttft_percentile(0.5)*1e3:.0f}ms;"
                    f"p99_ttft={m.ttft_percentile(0.99)*1e3:.0f}ms;"
                    f"slo_att={m.slo_attainment:.3f};"
                    f"goodput={m.goodput:.1f}tok/s;"
                    f"throughput={m.throughput:.1f}tok/s")


# ---------------------------------------------------------------------------
# Prefix-sharing copy-on-write KV caching: templated vs disjoint workloads
# ---------------------------------------------------------------------------


def prefix_grid(csv: CSV, fast: bool):
    """Prefix caching on the templated workload: {templated, disjoint} x
    {caching on, off} x {low, high} arrival rate, chunked scheduler.

    The headline cell is templated.high: every prompt repeats a 512-token
    system prompt, so caching-off re-stores identical prefix blocks per
    request AND re-runs identical prefill compute — copy-on-write sharing
    reclaims both, which shows up as strictly lower p99 TTFT and strictly
    fewer allocated blocks with byte-identical per-request committed token
    streams.  The disjoint rows (template_len=0, same length shapes) are the
    control: caching buys ~nothing when prompts never repeat.  Persists the
    grid to BENCH_prefix.json."""
    import hashlib

    from repro.serving.workload import templated_requests

    chunk = 384
    results = {"chunk_tokens": chunk, "template_len": 512, "grid": {}}
    cells = (("low", 8), ("high", 80))
    for wl, template in (("templated", 512), ("disjoint", 0)):
        for label, rate in cells:
            n = max(int(rate * (2 if fast else 5)), 30)
            reqs = templated_requests(rate, n, template_len=template, seed=1)
            for caching in (False, True):
                mode = "cache" if caching else "nocache"
                t0 = time.perf_counter()
                m, _ = run_serving("7b", "nightjar", chunk_tokens=chunk,
                                   prefix_caching=caching, requests=reqs)
                wall = (time.perf_counter() - t0) * 1e6
                stream = sorted((r.req_id, r.tokens) for r in m.requests)
                sha = hashlib.sha256(repr(stream).encode()).hexdigest()[:16]
                hit = m.prefix_hit_rate
                row = {
                    "p50_ttft_s": m.ttft_percentile(0.5),
                    "p99_ttft_s": m.ttft_percentile(0.99),
                    "slo_attainment": m.slo_attainment,
                    "goodput_tok_s": m.goodput,
                    "throughput_tok_s": m.throughput,
                    "blocks_allocated": m.blocks_allocated,
                    "total_tokens": m.total_tokens,
                    "finished": len(m.requests),
                    "prefix_hit_rate": hit,
                    "saved_prefill_tokens": m.prefix.get("saved_tokens", 0),
                    "forks": m.prefix.get("forks", 0),
                    "tokens_sha": sha,
                }
                results["grid"][f"{wl}.{label}.{mode}"] = row
                csv.add(f"prefix.{wl}.{label}.{mode}", wall,
                        f"p99_ttft={row['p99_ttft_s']*1e3:.0f}ms;"
                        f"blocks={row['blocks_allocated']};"
                        f"goodput={row['goodput_tok_s']:.1f}tok/s;"
                        f"hit_rate={hit:.3f};tokens_sha={sha}")
    with open(bench_out("BENCH_prefix.json"), "w") as f:
        json.dump(results, f, indent=1)


# ---------------------------------------------------------------------------
# Host-memory KV offload tier: multi-turn session workload
# ---------------------------------------------------------------------------


def sessions_grid(csv: CSV, fast: bool):
    """Host KV offload on the multi-turn session workload: {offload, none}
    at a FIXED device pool, chunked scheduler, prefix caching on.

    Each session opens with a long context and returns after think-time
    gaps with its whole history as the prompt.  Between turns the device
    LRU evicts the session's prefix blocks under pressure from other
    sessions; without the host tier the next turn re-runs prefill over the
    full history, with it the blocks restore from host memory into free
    device blocks at PCIe cost.  The headline: warm-turn (turn > 0) p50/p99
    TTFT strictly below cold-turn TTFT and cross-turn hit rate > 0.8 with
    offload on, with byte-identical committed token streams vs offload-off
    (restores change WHERE bytes live, never WHAT is computed).  Persists
    the grid to BENCH_sessions.json."""
    import hashlib

    from repro.serving.request import percentile
    from repro.serving.workload import session_requests

    chunk = 384
    n_sessions, turns, num_blocks = (8, 5, 512) if fast else (16, 6, 768)
    rate = 0.5
    results = {"chunk_tokens": chunk, "sessions": n_sessions, "turns": turns,
               "rate_qps": rate, "num_blocks": num_blocks, "grid": {}}
    reqs = session_requests(n_sessions, turns=turns, rate_qps=rate, seed=0)
    for kv_off in (False, True):
        mode = "offload" if kv_off else "none"
        t0 = time.perf_counter()
        m, eng = run_serving("7b", "nightjar", chunk_tokens=chunk,
                             prefix_caching=True, requests=reqs,
                             enable_offload=False, num_blocks=num_blocks,
                             kv_offload=kv_off)
        wall = (time.perf_counter() - t0) * 1e6
        eng.scheduler.bm.check_invariants()
        stream = sorted((r.req_id, r.tokens) for r in m.requests)
        sha = hashlib.sha256(repr(stream).encode()).hexdigest()[:16]
        warm = [r for r in m.requests if r.turn > 0]
        cold = [r for r in m.requests if r.turn == 0]
        wttft = [r.ttft for r in warm]
        cttft = [r.ttft for r in cold]
        hit = (sum(1 for r in warm if r.cached_tokens > 0)
               / max(len(warm), 1))
        row = {
            "p50_warm_ttft_s": percentile(wttft, 0.5),
            "p99_warm_ttft_s": percentile(wttft, 0.99),
            "p50_cold_ttft_s": percentile(cttft, 0.5),
            "p99_cold_ttft_s": percentile(cttft, 0.99),
            "warm_turns": len(warm),
            "cold_turns": len(cold),
            "cross_turn_hit_rate": hit,
            "prefix_hit_rate": m.prefix_hit_rate,
            "host_spills": m.host.get("spills", 0),
            "host_restores": m.host.get("restores", 0),
            "host_restore_s": m.host.get("restore_s", 0.0),
            "restored_blocks": m.prefix.get("restored_blocks", 0),
            "throughput_tok_s": m.throughput,
            "goodput_tok_s": m.goodput,
            "slo_attainment": m.slo_attainment,
            "finished": len(m.requests),
            "tokens_sha": sha,
        }
        results["grid"][mode] = row
        csv.add(f"sessions.{mode}", wall,
                f"warm_p50={row['p50_warm_ttft_s']*1e3:.0f}ms;"
                f"warm_p99={row['p99_warm_ttft_s']*1e3:.0f}ms;"
                f"cold_p50={row['p50_cold_ttft_s']*1e3:.0f}ms;"
                f"cold_p99={row['p99_cold_ttft_s']*1e3:.0f}ms;"
                f"xturn_hit={hit:.3f};"
                f"restores={row['host_restores']};tokens_sha={sha}")
    with open(bench_out("BENCH_sessions.json"), "w") as f:
        json.dump(results, f, indent=1)


# ---------------------------------------------------------------------------
# Cluster tier: replica-count x arrival-rate grid (the fleet scenario)
# ---------------------------------------------------------------------------


def _gamma_trace(metrics, *, window_s: float = 2.0, max_windows: int = 16):
    """Mean gamma per virtual-time window — the per-replica gamma trace."""
    acc, cnt = {}, {}
    for r in metrics.timeline:
        w = int(r["t"] // window_s)
        acc[w] = acc.get(w, 0) + r["gamma"]
        cnt[w] = cnt.get(w, 0) + 1
    ws = sorted(acc)[:max_windows]
    return "|".join(f"{acc[w] / cnt[w]:.1f}" for w in ws)


def cluster_sweep(csv: CSV, fast: bool):
    """Weak-scaling grid: {1,2,4} replicas x {low,high} per-replica rate.

    The total arrival rate scales with replica count (every replica sees the
    same offered load), so the high cell keeps every replica saturated: each
    replica's planner must independently learn gamma -> 0 while the low cell
    keeps speculation on.  Emits per-replica gamma traces, saturated-regime
    gamma stats and the planner's final exploit arm for the full batch."""
    max_batch = 256
    reps_list = (1, 2) if fast else (1, 2, 4)
    dur = 6 if fast else 12
    agg = {}
    for n_rep in reps_list:
        for label, rate_per in (("low", 4), ("high", 200)):
            rate = rate_per * n_rep
            n = max(int(rate * dur), 40)
            t0 = time.perf_counter()
            m, cl = run_cluster("7b", n_rep, "nightjar", router="jsq",
                                rate=rate, n=n, dataset="alpaca",
                                max_batch=max_batch, record_timeline=True)
            agg[(n_rep, label)] = m.throughput
            sat, arms = [], []
            for i, rm in enumerate(m.per_replica):
                g, f0 = saturated_gamma_stats(rm, max_batch)
                sat.append(f"r{i}:{'-' if g is None else f'{g:.2f}/{f0:.2f}'}")
                pol = cl.replicas[i].policy
                arms.append(str(pol._eq4(pol.bucket(max_batch), 0, max_batch))
                            if hasattr(pol, "_eq4") else "-")
            csv.add(f"cluster.reps{n_rep}.{label}",
                    (time.perf_counter() - t0) * 1e6,
                    f"throughput={m.throughput:.1f}tok/s;"
                    f"sat_gamma={','.join(sat)};"
                    f"exploit_arm={','.join(arms)};"
                    f"requests={'/'.join(map(str, m.replica_counts()))}")
            for i, rm in enumerate(m.per_replica):
                csv.add(f"cluster.reps{n_rep}.{label}.gamma_trace.r{i}", 0.0,
                        f"trace={_gamma_trace(rm)}")
    hi = reps_list[-1]
    csv.add("cluster.weak_scaling", 0.0,
            f"reps{hi}_vs_reps1_high="
            f"{agg[(hi, 'high')] / agg[(1, 'high')]:.2f}x;"
            f"reps{hi}_vs_reps1_low="
            f"{agg[(hi, 'low')] / agg[(1, 'low')]:.2f}x")


def control_grid(csv: CSV, fast: bool):
    """Cluster control plane: {static, autoscale} fleets x
    {rr, kv, slo, affinity} routers x {templated, bursty} traces.

    Templated arm (static 2-replica fleet, prefix caching on, chunked):
    a multi-template workload where sticky affinity routing partitions the
    template population across replicas — each replica's prefix cache
    specialises, which shows up as strictly higher aggregate hit rate and
    strictly lower p99 TTFT than KV-headroom routing, with identical
    per-request committed token counts (the acceptance criterion; the sim
    tier commits counts, not token contents).

    Bursty arm (baseline -> spike -> drain): the elastic fleet (autoscale
    1 -> 2 replicas + admission control) against the static 2-replica
    fleet at EQUAL peak replica count.  During the spike the offered load
    exceeds even the full fleet; the static fleet admits everything and
    lets the queue collapse its tail, while the control plane sheds the
    hopeless arrivals at the door and keeps admitted traffic inside the
    deadline — strictly higher SLO attainment of admitted traffic (shed
    requests reported separately), at fewer replica-seconds.

    Persists the grid to BENCH_control.json."""
    import hashlib

    from repro.serving.workload import bursty_trace, templated_requests

    # per-arm scheduler configs differ (the templated arm exercises the
    # prefix cache through the chunked path; the bursty arm is the plain
    # monolithic fleet) — record each arm's config so rows are only ever
    # compared within their arm
    results = {
        "templated": {"chunk_tokens": 384, "template_len": 512,
                      "num_templates": 8, "prefix_caching": True,
                      "replicas": 2},
        "bursty": {"chunk_tokens": 0, "prefix_caching": False,
                   "dataset": "alpaca", "peak_replicas": 2,
                   "trace": "baseline 4qps -> spike 240qps -> drain 2qps"},
        "grid": {},
    }
    routers = ("rr", "kv", "slo", "affinity")

    # -- templated arm: static 2-replica fleet, caching on ---------------
    n_t = 140 if fast else 360
    treqs = templated_requests(60, n_t, num_templates=8, seed=1)
    for router in routers:
        t0 = time.perf_counter()
        m, cl = run_cluster("7b", 2, "nightjar", router=router,
                            requests=treqs, chunk_tokens=384,
                            prefix_caching=True)
        wall = (time.perf_counter() - t0) * 1e6
        stream = sorted((r.req_id, r.tokens) for r in m.requests)
        sha = hashlib.sha256(repr(stream).encode()).hexdigest()[:16]
        row = {
            "p50_ttft_s": m.ttft_percentile(0.5),
            "p99_ttft_s": m.ttft_percentile(0.99),
            "slo_attainment": m.slo_attainment,
            "goodput_tok_s": m.goodput,
            "prefix_hit_rate": m.prefix_hit_rate,
            "blocks_allocated": sum(r.blocks_allocated
                                    for r in m.per_replica),
            "finished": len(m.requests),
            "replica_requests": m.replica_counts(),
            "spills": getattr(cl.router, "spills", 0),
            "tokens_sha": sha,
        }
        results["grid"][f"templated.static.{router}"] = row
        csv.add(f"control.templated.static.{router}", wall,
                f"p99_ttft={row['p99_ttft_s']*1e3:.0f}ms;"
                f"hit_rate={row['prefix_hit_rate']:.3f};"
                f"slo_att={row['slo_attainment']:.3f};"
                f"tokens_sha={sha}")

    # -- bursty arm: static vs elastic at equal peak replica count -------
    trace = bursty_trace(base=4, spike=240, base_s=12 if fast else 20,
                         spike_s=6 if fast else 12,
                         drain_s=20 if fast else 30, drain=2, seed=2)
    n_b = 1560 if fast else 3040
    breqs = trace.sample_requests(n_b, dataset="alpaca", seed=3)
    bursty_routers = ("kv", "slo") if fast else routers
    for fleet in ("static", "autoscale"):
        kw = dict(requests=breqs)
        if fleet == "autoscale":
            kw.update(shed_factor=1.5,
                      autoscale=dict(min_replicas=1, max_replicas=2,
                                     window_s=8.0))
        for router in bursty_routers:
            t0 = time.perf_counter()
            m, cl = run_cluster("7b", 2, "nightjar", router=router, **kw)
            wall = (time.perf_counter() - t0) * 1e6
            s = m.summary()
            row = {
                "p50_ttft_s": m.ttft_percentile(0.5),
                "p99_ttft_s": m.ttft_percentile(0.99),
                "slo_attainment": m.slo_attainment,
                "slo_attainment_offered": m.slo_attainment_offered,
                "goodput_tok_s": m.goodput,
                "shed": m.shed_count,
                "finished": len(m.requests),
                "peak_replicas": m.peak_replicas,
                "replica_seconds": m.replica_seconds,
                "autoscale_adds": s.get("autoscale", {}).get("adds", 0),
                "autoscale_drains": s.get("autoscale", {}).get("drains", 0),
            }
            results["grid"][f"bursty.{fleet}.{router}"] = row
            csv.add(f"control.bursty.{fleet}.{router}", wall,
                    f"slo_att={row['slo_attainment']:.3f};"
                    f"offered={row['slo_attainment_offered']:.3f};"
                    f"shed={row['shed']};"
                    f"peak_replicas={row['peak_replicas']};"
                    f"replica_s={row['replica_seconds']:.0f}")

    with open(bench_out("BENCH_control.json"), "w") as f:
        json.dump(results, f, indent=1)


def disagg_grid(csv: CSV, fast: bool):
    """Disaggregated prefill/decode fleet vs the colocated fleet at EQUAL
    fleet size (4 replicas vs 2 prefill + 2 decode) on the mixed
    long-prompt/long-decode workload.

    The high cells are the slot-clogging regime: with a bounded admission
    batch, colocated replicas' slots fill with long-lived decodes, so long
    prompts queue behind residents and p99 TTFT collapses — while the
    disaggregated prefill pool hands every finished prompt's KV blocks to a
    decode replica (batched block migration priced at interconnect
    bandwidth) and keeps admitting.  Headline: disagg.high strictly beats
    colocated.high on p99 TTFT AND goodput with byte-identical per-request
    committed token streams (migration changes WHERE decode runs, never
    WHAT is computed).

    The pricedout cells are the fallback demonstration: at low load with a
    pricer margin, the queue-delay forecast saved never covers the modelled
    transfer time, so the control plane declines (nearly) every handoff and
    the 'disaggregated' fleet degrades gracefully to colocated serving —
    never worse by construction.  Persists the grid to BENCH_disagg.json."""
    import hashlib

    from repro.serving.workload import mixed_requests

    chunk, mb, qa = 128, 48, 0.25
    rate_hi, n_hi = 28.0, 500
    rate_lo, n_lo = 6.0, 100 if fast else 150
    results = {"chunk_tokens": chunk, "max_batch": mb, "dataset": "mixed",
               "qa_frac": qa, "replicas": 4, "split": "2 prefill + 2 decode",
               "high": {"rate_qps": rate_hi, "requests": n_hi},
               "pricedout": {"rate_qps": rate_lo, "requests": n_lo,
                             "margin_s": 0.25},
               "grid": {}}
    hi_reqs = mixed_requests(rate_hi, n_hi, qa_frac=qa, seed=1)
    lo_reqs = mixed_requests(rate_lo, n_lo, qa_frac=qa, seed=1)
    cells = (
        ("colocated.high", hi_reqs, None),
        ("disagg.high", hi_reqs, dict(prefill=2, decode=2)),
        ("colocated.low", lo_reqs, None),
        ("disagg.pricedout", lo_reqs,
         dict(prefill=2, decode=2, margin_s=0.25)),
    )
    for name, reqs, disagg in cells:
        t0 = time.perf_counter()
        m, cl = run_cluster("7b", 4, "nightjar", router="jsq",
                            requests=reqs, chunk_tokens=chunk,
                            max_batch=mb, disaggregate=disagg)
        wall = (time.perf_counter() - t0) * 1e6
        stream = sorted((r.req_id, r.tokens) for r in m.requests)
        sha = hashlib.sha256(repr(stream).encode()).hexdigest()[:16]
        row = {
            "p50_ttft_s": m.ttft_percentile(0.5),
            "p99_ttft_s": m.ttft_percentile(0.99),
            "slo_attainment": m.slo_attainment,
            "goodput_tok_s": m.goodput,
            "throughput_tok_s": m.throughput,
            "handoffs": len(m.handoffs),
            "handoffs_declined": m.handoffs_declined,
            "handoff_transfer_s": m.handoff_transfer_s,
            "handoff_fallbacks": m.handoff_fallbacks,
            "replica_seconds": m.replica_seconds,
            "peak_replicas": m.peak_replicas,
            "finished": len(m.requests),
            "tokens_sha": sha,
        }
        results["grid"][name] = row
        csv.add(f"disagg.{name}", wall,
                f"p99_ttft={row['p99_ttft_s']*1e3:.0f}ms;"
                f"slo_att={row['slo_attainment']:.3f};"
                f"goodput={row['goodput_tok_s']:.1f}tok/s;"
                f"handoffs={row['handoffs']};"
                f"declined={row['handoffs_declined']};"
                f"tokens_sha={sha}")
    g = results["grid"]
    results["acceptance"] = {
        "disagg_wins_p99_ttft": (g["disagg.high"]["p99_ttft_s"]
                                 < g["colocated.high"]["p99_ttft_s"]),
        "disagg_wins_goodput": (g["disagg.high"]["goodput_tok_s"]
                                > g["colocated.high"]["goodput_tok_s"]),
        "streams_identical_high": (g["disagg.high"]["tokens_sha"]
                                   == g["colocated.high"]["tokens_sha"]),
        "streams_identical_low": (g["disagg.pricedout"]["tokens_sha"]
                                  == g["colocated.low"]["tokens_sha"]),
        "pricedout_declines": (g["disagg.pricedout"]["handoffs_declined"]
                               > g["disagg.pricedout"]["handoffs"]),
    }
    csv.add("disagg.acceptance", 0.0,
            ";".join(f"{k}={v}" for k, v in results["acceptance"].items()))
    with open(bench_out("BENCH_disagg.json"), "w") as f:
        json.dump(results, f, indent=1)


def chaos_grid(csv: CSV, fast: bool):
    """Chaos gate: crash-and-recover vs the fault-free baseline on the SAME
    seeded workload (2 replicas, alpaca, TTFT SLO).

    The crash cell kills replica 1 mid-run; the failure detector notices
    the silence on the shared virtual clock, a replacement replica spawns
    from the seeded factory, and every in-flight request re-queues through
    the router with exponential backoff and re-prefills from its prompt.
    The chaos cell adds a transient straggler window on replica 0 on top.

    Machine-checked acceptance flags (CI asserts all of them): ZERO
    requests dropped in every cell, committed token streams byte-identical
    to the fault-free run, every crash-lost request re-queued and completed
    (retry budget never exhausted), recovered SLO attainment within a
    bounded gap of baseline, and MTTD/MTTR actually measured (not zero and
    not fabricated when nothing fired).  Persists BENCH_chaos.json."""
    import hashlib

    from repro.serving.workload import poisson_requests

    rate, n = 20.0, (160 if fast else 320)
    results = {"replicas": 2, "dataset": "alpaca", "rate_qps": rate,
               "requests": n, "grid": {}}
    reqs = poisson_requests(rate, n, dataset="alpaca", seed=1)
    cells = [
        ("faultfree", None),
        ("crash", "crash:1@2.0"),
    ]
    if not fast:
        cells.append(("chaos", "crash:1@2.0;straggle:0@1.0..5.0x3"))
    for name, plan in cells:
        t0 = time.perf_counter()
        m, cl = run_cluster("7b", 2, "nightjar", router="jsq",
                            requests=reqs, fault_plan=plan)
        wall = (time.perf_counter() - t0) * 1e6
        stream = sorted((r.req_id, r.tokens) for r in m.requests)
        sha = hashlib.sha256(repr(stream).encode()).hexdigest()[:16]
        row = {
            "p50_ttft_s": m.ttft_percentile(0.5),
            "p99_ttft_s": m.ttft_percentile(0.99),
            "slo_attainment": m.slo_attainment,
            "goodput_tok_s": m.goodput,
            "throughput_tok_s": m.throughput,
            "finished": len(m.requests),
            "crashes": len(m.crashes),
            "requests_lost": sum(c["lost"] for c in m.crashes),
            "requeues": m.requeues,
            "retries": m.retries,
            "failed_requests": len(m.failed_requests),
            "mttd_s": m.mttd,
            "mttr_s": m.mttr,
            "recovery_seconds": m.recovery_seconds,
            "tokens_sha": sha,
        }
        results["grid"][name] = row
        csv.add(f"chaos.{name}", wall,
                f"finished={row['finished']}/{n};"
                f"crashes={row['crashes']};"
                f"requeues={row['requeues']};"
                f"failed={row['failed_requests']};"
                f"slo_att={row['slo_attainment']:.3f};"
                f"mttr={'n/a' if m.mttr is None else f'{m.mttr:.3f}s'};"
                f"tokens_sha={sha}")
    g = results["grid"]
    base = g["faultfree"]
    fault_cells = [g[k] for k in g if k != "faultfree"]
    results["acceptance"] = {
        "zero_dropped": all(c["finished"] == n for c in g.values()),
        "streams_identical": all(c["tokens_sha"] == base["tokens_sha"]
                                 for c in fault_cells),
        "all_requeued_completed": all(
            c["requeues"] > 0 and c["requeues"] == c["requests_lost"]
            and c["failed_requests"] == 0 for c in fault_cells),
        "recovered_slo_bounded": all(
            c["slo_attainment"] >= base["slo_attainment"] - 0.15
            for c in fault_cells),
        "mttr_measured": (all(c["mttr_s"] is not None and c["mttr_s"] > 0
                              for c in fault_cells)
                          and base["mttr_s"] is None),
    }
    csv.add("chaos.acceptance", 0.0,
            ";".join(f"{k}={v}" for k, v in results["acceptance"].items()))
    with open(bench_out("BENCH_chaos.json"), "w") as f:
        json.dump(results, f, indent=1)


def surge_grid(csv: CSV, fast: bool):
    """Surge gate: 3x sustained overload with mixed priority classes and a
    seeded client-cancellation storm, brownout ladder ON vs OFF (2
    replicas, alpaca lengths, per-class SLOs/deadlines).

    Three cells on the SAME seeded workload: ``base`` (no cancellations —
    the stream-identity reference), ``no_brownout`` (storm + classic
    class-blind admission) and ``brownout`` (same storm + class-weighted
    admission + the fleet brownout ladder: gamma->0, draft offload, a
    best_effort output cap, class-ordered shedding).  The plateau is
    deliberately past fleet capacity, so the only question is HOW service
    degrades.

    Machine-checked acceptance flags (CI asserts all of them): brownout
    strictly beats no-brownout on interactive-class offered-SLO attainment
    AND fleet goodput; every request in every cell is accounted per class
    (finished+shed+cancelled+expired+failed == offered); invariants I1-I8
    clean on every replica post-run; surviving committed streams
    byte-identical to the cancellation-free run; and both the
    speculation-off and draft-offload rungs observably fired.  Persists
    BENCH_surge.json."""
    from repro.serving.cluster import FAILED
    from repro.serving.workload import (cancellation_storm, surge_requests,
                                        surge_trace)

    base_s, surge_s, recover_s = (6.0, 14.0, 8.0) if fast else \
        (8.0, 24.0, 12.0)
    base_rate, mult = 60.0, 3.0
    n = int(base_rate * (base_s + recover_s) + base_rate * mult * surge_s)
    trace = surge_trace(base=base_rate, surge_mult=mult, base_s=base_s,
                        surge_s=surge_s, recover_s=recover_s, seed=2)
    reqs = surge_requests(n, trace=trace, dataset="alpaca", seed=1)
    storm = dict(frac=0.12, start=base_s + 2.0, end=base_s + surge_s)
    cancels = cancellation_storm(reqs, seed=4, **storm)
    weights = {"interactive": 1.5, "batch": 0.8, "best_effort": 0.4}
    bo = dict(slo=0.5, enter_factor=1.5, exit_factor=0.8,
              kv_low_frac=0.10, kv_calm_frac=0.30, best_effort_cap=32,
              cooldown_s=1.0, check_interval_s=0.25)
    results = {"replicas": 2, "dataset": "alpaca", "requests": n,
               "trace": {"base_qps": base_rate, "surge_mult": mult,
                         "base_s": base_s, "surge_s": surge_s,
                         "recover_s": recover_s},
               "storm": storm, "cancel_schedule": len(cancels),
               "class_weights": weights, "brownout_cfg": bo, "grid": {}}
    cells = (
        ("base", dict(shed_factor=1.5)),
        ("no_brownout", dict(shed_factor=1.5, cancels=cancels)),
        ("brownout", dict(shed_factor=1.5, class_weights=weights,
                          cancels=cancels, brownout=bo)),
    )

    def offered_att(per_class, cls):
        """SLO attainment over the class's offered load: shed/expired/
        failed count as misses, client cancels are excluded (neither met
        nor missed).  None without samples."""
        b = per_class.get(cls)
        if b is None:
            return None
        denom = b["slo_samples"] + b["shed"] + b["expired"] + b["failed"]
        return b["slo_met"] / denom if denom else None

    toks = {}
    for name, kw in cells:
        t0 = time.perf_counter()
        m, cl = run_cluster("7b", 2, "nightjar", router="jsq",
                            max_batch=256, requests=reqs, **kw)
        wall = (time.perf_counter() - t0) * 1e6
        toks[name] = {r.req_id: r.tokens for r in m.requests}
        per_class = m.class_summary()
        inv_ok = True
        try:
            for i, e in enumerate(cl.replicas):
                e.scheduler.bm.check_invariants(
                    failed=cl.state[i] == FAILED)
        except AssertionError:
            inv_ok = False
        ia = offered_att(per_class, "interactive")
        row = {
            "p50_ttft_s": m.ttft_percentile(0.5),
            "p99_ttft_s": m.ttft_percentile(0.99),
            "slo_attainment": m.slo_attainment,
            "goodput_tok_s": m.goodput,
            "throughput_tok_s": m.throughput,
            "finished": len(m.requests),
            "shed": m.shed_count,
            "cancelled": len(m.cancelled),
            "expired": len(m.expired),
            "failed": len(m.failed_requests),
            "per_class": per_class,
            "interactive_offered_attainment": ia,
            "brownout_transitions": len(m.brownout_events),
            "brownout_timeline": m.brownout_events,
            "invariants_clean": inv_ok,
        }
        results["grid"][name] = row
        csv.add(f"surge.{name}", wall,
                f"finished={row['finished']}/{n};"
                f"shed={row['shed']};cancelled={row['cancelled']};"
                f"expired={row['expired']};"
                f"interactive_att={'n/a' if ia is None else f'{ia:.3f}'};"
                f"goodput={row['goodput_tok_s']:.1f}tok/s;"
                f"brownout_stages={len(row['brownout_timeline'])}")
    g = results["grid"]
    # survivors of the storm run must commit the exact streams the
    # cancellation-free run committed (intersection of finished ids;
    # brownout cell excluded — its best_effort output cap intentionally
    # clips streams)
    common = set(toks["base"]) & set(toks["no_brownout"])
    fired = {e["to"] for e in g["brownout"]["brownout_timeline"]}
    ia_bo = g["brownout"]["interactive_offered_attainment"]
    ia_nb = g["no_brownout"]["interactive_offered_attainment"]
    results["acceptance"] = {
        "interactive_attainment_improves": (
            ia_bo is not None and ia_nb is not None and ia_bo > ia_nb),
        "goodput_improves": (g["brownout"]["goodput_tok_s"]
                             > g["no_brownout"]["goodput_tok_s"]),
        "all_accounted": all(
            sum(b["offered"] for b in c["per_class"].values()) == n
            for c in g.values()),
        "invariants_clean": all(c["invariants_clean"] for c in g.values()),
        "streams_identical": (len(common) > 0 and all(
            toks["base"][k] == toks["no_brownout"][k] for k in common)),
        "stage_spec_off_fired": "spec_off" in fired,
        "stage_draft_offload_fired": "draft_offload" in fired,
    }
    csv.add("surge.acceptance", 0.0,
            ";".join(f"{k}={v}" for k, v in results["acceptance"].items()))
    with open(bench_out("BENCH_surge.json"), "w") as f:
        json.dump(results, f, indent=1)


def cluster_routers(csv: CSV, fast: bool):
    """Router-policy comparison at moderate load on 2 replicas."""
    for router in ("rr", "jsq", "kv"):
        rate, n = 40, (160 if fast else 400)
        t0 = time.perf_counter()
        m, _ = run_cluster("7b", 2, "nightjar", router=router, rate=rate,
                           n=n, dataset="sharegpt")
        csv.add(f"cluster.router.{router}", (time.perf_counter() - t0) * 1e6,
                f"throughput={m.throughput:.1f}tok/s;"
                f"mean_latency={m.mean_latency:.2f}s;"
                f"balance={'/'.join(map(str, m.replica_counts()))}")


# ---------------------------------------------------------------------------
# Backend grid: dense slot caches vs the paged-KV runtime (REAL execution)
# ---------------------------------------------------------------------------


def backend_grid(csv: CSV, fast: bool):
    """Dense-slot vs paged-KV real backends on actual JAX execution:
    prefill / decode / verify step latency (wall clock, post-compile) and
    the max admissible batch at a fixed HBM KV budget (dense reserves
    max_seq tokens per slot; paged admits by actual context through the
    BlockManager).  Persists the grid to BENCH_backend.json."""
    from repro.serving.kv_cache import BlockManager, OutOfBlocks
    from repro.serving.real_backend import DenseSlotBackend, RealBackend
    from repro.serving.request import Request, Sequence

    cfg = configs.reduced(configs.get_config("deepseek-7b")).replace(
        dtype="float32")
    dcfg = configs.reduced(configs.get_draft_config("deepseek-7b")).replace(
        dtype="float32")
    target, draft = registry.get_model(cfg), registry.get_model(dcfg)

    B = 2 if fast else 4
    P = 16            # prompt tokens
    max_seq = 128     # dense per-slot reservation
    block_size = 8
    rng = np.random.default_rng(0)
    results = {"batch": B, "prompt": P, "max_seq": max_seq,
               "block_size": block_size, "grid": {}}

    def mkseqs(base):
        return [Sequence(request=Request(
            base + i, 0.0, P, 64,
            prompt_tokens=[int(x) for x in rng.integers(0, cfg.vocab_size, P)]))
            for i in range(B)]

    for mode in ("dense", "paged"):
        if mode == "dense":
            be = DenseSlotBackend(target, draft, max_batch=B,
                                  max_seq=max_seq, seed=0)
        else:
            bm = BlockManager(max(B * max_seq // block_size, 64), block_size)
            be = RealBackend(target, draft, max_batch=B, max_seq=max_seq,
                             seed=0, block_manager=bm)
        warm = mkseqs(100)
        be.prefill(warm, with_draft=True)      # compile
        for s in warm:
            be.release(s)
        seqs = mkseqs(0)
        t0 = time.perf_counter()
        be.prefill(seqs, with_draft=True)
        t_pref = time.perf_counter() - t0
        be.step(seqs, 0)                        # compile AR
        be.step(seqs, 2)                        # compile spec
        _, t_dec = timed(lambda: be.step(seqs, 0), repeat=3 if fast else 5)
        _, t_ver = timed(lambda: be.step(seqs, 2), repeat=3 if fast else 5)
        row = {"prefill_s": t_pref, "decode_step_s": t_dec,
               "verify_step_s": t_ver}
        results["grid"][mode] = row
        csv.add(f"backend.{mode}.prefill", t_pref * 1e6,
                f"batch={B};prompt={P}")
        csv.add(f"backend.{mode}.decode", t_dec * 1e6, f"batch={B}")
        csv.add(f"backend.{mode}.verify", t_ver * 1e6, f"batch={B};gamma=2")

    # max admissible batch at a fixed KV budget: the paged pool admits by
    # ACTUAL context (prompt + a 32-token decode horizon) while dense must
    # reserve max_seq tokens per slot up front
    budget_tokens = 2048
    n_dense = budget_tokens // max_seq
    bm = BlockManager(budget_tokens // block_size, block_size)
    n_paged = 0
    try:
        while True:
            bm.allocate(n_paged, P + 1)
            bm.append_tokens(n_paged, 32)
            n_paged += 1
    except OutOfBlocks:
        pass
    results["capacity"] = {"budget_tokens": budget_tokens,
                           "dense_max_batch": n_dense,
                           "paged_max_batch": n_paged}
    csv.add("backend.capacity", 0.0,
            f"budget_tokens={budget_tokens};dense={n_dense};paged={n_paged};"
            f"gain={n_paged / max(n_dense, 1):.1f}x")

    with open(bench_out("BENCH_backend.json"), "w") as f:
        json.dump(results, f, indent=1)


# ---------------------------------------------------------------------------
# Table 3: C_switch profiling (real tier + analytic tier)
# ---------------------------------------------------------------------------


def table3_cswitch(csv: CSV, fast: bool):
    # analytic tier: the paper's 7B/0.5B pair on the 4090 profile
    cm = RooflineCostModel(RTX_4090)
    draft = configs.get_draft_config("paper-7b")
    for delta in (128, 256, 512):
        for batch in ((32, 64) if True else ()):
            c = cm.prefill_latency(draft, batch, delta)
            csv.add(f"table3.analytic.len{delta}.b{batch}", 0.0,
                    f"cswitch={c*1e3:.2f}ms")

    # real tier: wall-clock draft re-prefill of a tiny model on CPU
    dcfg = configs.reduced(configs.get_draft_config("paper-7b"))
    api = registry.get_model(dcfg)
    params = api.init(jax.random.PRNGKey(0))
    prefill = jax.jit(lambda p, b: api.prefill(p, b, 600))

    def measure(delta, batch):
        toks = jnp.zeros((batch, delta), jnp.int32)
        out = prefill(params, {"tokens": toks})
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = prefill(params, {"tokens": toks})
        jax.block_until_ready(out)
        return time.perf_counter() - t0

    table = CSwitchTable.profile(measure, deltas=(128, 256, 512),
                                 batches=(2, 8) if fast else (2, 8, 32))
    for (d, b), v in sorted(table.table.items()):
        csv.add(f"table3.real.len{d}.b{b}", v * 1e6, f"cswitch={v*1e3:.2f}ms")


# ---------------------------------------------------------------------------
# Table 7: elastic memory operation overheads (real execution)
# ---------------------------------------------------------------------------


def table7_memops(csv: CSV, fast: bool):
    from repro.serving.kv_cache import BlockManager, PhysicalKVPool
    L, nb, bs, kh, hd = 8, 256, 16, 8, 64
    pool = PhysicalKVPool(L, nb, bs, kh, hd)
    bm = BlockManager(nb, bs)
    bm.allocate(1, nb * bs - bs)

    # expansion: attach blocks (pool grow + free-list update)
    def expand():
        p2 = PhysicalKVPool(L, nb, bs, kh, hd)
        p2.grow(32)
        return p2
    _, dt = timed(expand, repeat=2)
    csv.add("table7.expansion", dt * 1e6, f"latency={dt*1e3:.1f}ms")

    # contraction: kernel-backed block migration of 32 blocks
    src = jnp.arange(nb - 32, nb, dtype=jnp.int32)
    dst = jnp.arange(0, 32, dtype=jnp.int32)

    def contract():
        out = pool.k
        from repro.kernels import ops
        out = ops.migrate_blocks(out, src, dst, use_kernel=False)
        out.block_until_ready()
        return out
    _, dt = timed(contract, repeat=3)
    csv.add("table7.contraction.vectorized", dt * 1e6,
            f"latency={dt*1e3:.2f}ms;blocks=32")

    # reload dispatch: CPU overhead of triggering the async reload
    from repro.serving.memory_manager import ElasticMemoryManager
    bm2 = BlockManager(100, 4)
    mm = ElasticMemoryManager(bm2, draft_blocks=10, t_persist=1)
    mm.draft_resident = False
    mm.expanded = True
    bm2.expand(10)
    t0 = time.perf_counter()
    mm.step(0.0, spec_disabled=True, waiting=0)
    dt = time.perf_counter() - t0
    csv.add("table7.reload_dispatch", dt * 1e6, f"latency={dt*1e6:.1f}us")


# ---------------------------------------------------------------------------
# Appendix A: sublinear regret
# ---------------------------------------------------------------------------


def appendix_regret(csv: CSV, fast: bool):
    lat = {0: 0.030, 1: 0.022, 2: 0.016, 3: 0.018, 4: 0.021, 5: 0.025}
    best = min(lat.values())
    horizons = [2000, 8000] if fast else [2000, 8000, 32000]
    Rs = []
    for T in horizons:
        pl = NightjarPlanner(5, seed=0)
        rng = np.random.default_rng(1)
        R = 0.0
        for t in range(T):
            g = pl.select(8)
            pl.observe(8, g, max(lat[g] + rng.normal(0, 0.002), 1e-6))
            R += lat[g] - best
        Rs.append(R)
        csv.add(f"regret.T{T}", 0.0,
                f"R={R:.2f};R_over_sqrtT={R/math.sqrt(T):.4f};"
                f"switches={pl.switch_count}")
    # sublinearity: R(4T)/R(T) should be well under 4 (≈2 for sqrt)
    ratio = Rs[-1] / Rs[0]
    growth = horizons[-1] / horizons[0]
    csv.add("regret.sublinearity", 0.0,
            f"R_ratio={ratio:.2f};T_ratio={growth};sublinear={ratio < growth}")


# ---------------------------------------------------------------------------
# Kernel microbenchmarks
# ---------------------------------------------------------------------------


def kernel_microbench(csv: CSV, fast: bool):
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)

    # block migration (ref path = production path on CPU)
    x = jax.random.normal(key, (8, 512, 16, 8, 64), jnp.float32)
    src = jnp.arange(480, 512, dtype=jnp.int32)
    dst = jnp.arange(0, 32, dtype=jnp.int32)
    _, dt = timed(lambda: ops.migrate_blocks(x, src, dst).block_until_ready(),
                  repeat=3)
    csv.add("kernel.block_migration.32x1MB", dt * 1e6,
            f"GBps={(32*8*16*8*64*4*2/dt)/1e9:.1f}")

    B, H, KH, D, bs, maxb = 8, 16, 4, 128, 16, 16
    q = jax.random.normal(key, (B, H, D))
    kp = jax.random.normal(key, (256, bs, KH, D))
    vp = jax.random.normal(key, (256, bs, KH, D))
    tables = jax.random.randint(key, (B, maxb), 0, 256)
    lengths = jnp.full((B,), maxb * bs)
    _, dt = timed(lambda: ops.paged_attention_op(
        q, kp, vp, tables, lengths).block_until_ready(), repeat=5)
    csv.add("kernel.paged_attention.b8h16", dt * 1e6,
            f"ctx={maxb*bs}")

    # multi-query extension (speculative verify / chunked-prefill appends)
    qm = jax.random.normal(key, (B, 4, H, D))
    _, dt = timed(lambda: ops.paged_attention_op(
        qm, kp, vp, tables, lengths).block_until_ready(), repeat=5)
    csv.add("kernel.paged_attention.b8t4h16", dt * 1e6,
            f"ctx={maxb*bs};T=4")

    S = 512 if fast else 1024
    q = jax.random.normal(key, (2, S, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, S, 8, 64), jnp.float32)
    _, dt = timed(lambda: ops.flash_attention_op(
        q, k, k, causal=True).block_until_ready(), repeat=3)
    csv.add(f"kernel.flash_attention.s{S}", dt * 1e6,
            f"gflops={(4*2*8*S*S*64/2/dt)/1e9:.1f}")


# ---------------------------------------------------------------------------
# Roofline table (reads the dry-run artifacts)
# ---------------------------------------------------------------------------


def roofline(csv: CSV, fast: bool):
    for fname in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        path = os.path.join(os.path.dirname(__file__), "..", fname)
        if not os.path.exists(path):
            csv.add(f"roofline.{fname}", 0.0, "missing=run dryrun first")
            continue
        cells = json.load(open(path))
        for c in cells:
            csv.add(
                f"roofline.{c['mesh']}.{c['arch']}.{c['shape']}", 0.0,
                f"bottleneck={c['bottleneck']};"
                f"compute_s={c['compute_s']:.4f};"
                f"memory_s={c['memory_s']:.4f};"
                f"collective_s={c['collective_s']:.4f};"
                f"peak_gb={c['peak_bytes_per_device']/1e9:.2f};"
                f"fits={c['fits_hbm']}")


BENCHES = {
    "fig2": fig2_fixed_gamma,
    "table5": table5_table6,
    "fig9": fig9_low_high,
    "fig11": fig11_dynamic_trace,
    "fig12": fig12_bandit_ablation,
    "fig13": fig13_offload,
    "fig14": fig14_threshold,
    "fig15": fig15_fixed_vs_adaptive,
    "prefill": prefill_hybrid,
    "prefix": prefix_grid,
    "sessions": sessions_grid,
    "backend": backend_grid,
    "cluster": cluster_sweep,
    "routers": cluster_routers,
    "control": control_grid,
    "disagg": disagg_grid,
    "chaos": chaos_grid,
    "surge": surge_grid,
    "table3": table3_cswitch,
    "table7": table7_memops,
    "regret": appendix_regret,
    "kernels": kernel_microbench,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    csv = CSV()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and args.only != name:
            continue
        t0 = time.perf_counter()
        fn(csv, args.fast)
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
