"""Shared benchmark utilities: model pairs, engine builders, CSV output."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.serving.costmodel import (A100_40G, RTX_4090, TPU_V5E,  # noqa: E402
                                     RooflineCostModel)
from repro.serving.simulator import (SimConfig, build_sim_cluster,  # noqa: E402
                                     build_sim_engine)
from repro.serving.workload import (dynamic_rate_trace,  # noqa: E402
                                    poisson_requests)

# the paper's second testbed: vicuna-13b (llama-13b arch) + vicuna-68m draft
VICUNA_13B = ModelConfig(
    name="vicuna-13b", family="dense", num_layers=40, d_model=5120,
    num_heads=40, num_kv_heads=40, d_ff=13824, vocab_size=32000,
    tie_embeddings=False)
VICUNA_68M = ModelConfig(
    name="vicuna-68m", family="dense", num_layers=2, d_model=768,
    num_heads=12, num_kv_heads=12, d_ff=3072, vocab_size=32000,
    tie_embeddings=True)

PAIRS = {
    "7b": (configs.get_config("paper-7b"), configs.get_draft_config("paper-7b"),
           RTX_4090),
    "13b": (VICUNA_13B, VICUNA_68M, A100_40G),
}

POLICIES = ["ar", "sd", "banditspec", "dsd", "nightjar"]
POLICY_LABEL = {"ar": "w/o SD", "sd": "SD(g=3)", "banditspec": "BanditSpec",
                "dsd": "DSD", "nightjar": "Nightjar", "linucb": "LinUCB",
                "eps-greedy": "EpsGreedy", "ada-bingreedy": "AdaBinGreedy"}


def run_serving(pair: str, policy: str, *, rate: float = None, n: int = None,
                dataset: str = "sharegpt", trace=None, max_batch: int = 256,
                seed: int = 0, enable_offload: bool = True,
                tau_low_frac: float = 0.1, kv_reserve_frac: float = 0.1,
                chunk_tokens: int = 0, slo: float = None,
                prefix_caching: bool = False, requests=None,
                num_blocks: int = None, kv_offload: bool = False,
                host_kv_blocks: int = 0, record_timeline: bool = False):
    target, draft, hw = PAIRS[pair]
    cfg = SimConfig(target=target, draft=draft, hw=hw, max_batch=max_batch,
                    seed=seed, enable_offload=enable_offload,
                    tau_low_frac=tau_low_frac,
                    kv_reserve_frac=kv_reserve_frac,
                    chunk_tokens=chunk_tokens,
                    prefix_caching=prefix_caching,
                    num_blocks=num_blocks, kv_offload=kv_offload,
                    host_kv_blocks=host_kv_blocks)
    eng = build_sim_engine(cfg, policy)
    if requests is not None:
        reqs = requests
    elif trace is not None:
        reqs = trace.sample_requests(n, dataset=dataset, seed=seed + 1,
                                     slo=slo)
    else:
        reqs = poisson_requests(rate, n, dataset=dataset, seed=seed + 1,
                                slo=slo)
    m = eng.run(reqs, max_steps=500_000, record_timeline=record_timeline)
    return m, eng


def run_cluster(pair: str, n_replicas: int, policy: str = "nightjar", *,
                router: str = "jsq", rate: float = 10.0, n: int = 100,
                dataset: str = "alpaca", max_batch: int = 256, seed: int = 0,
                chunk_tokens: int = 0, prefix_caching: bool = False,
                requests=None, trace=None, router_kwargs=None,
                shed_factor=None, class_weights=None, autoscale=None,
                disaggregate=None, fault_plan=None, brownout=None,
                cancels=None, num_blocks=None, enable_offload=True,
                record_timeline: bool = False):
    """Run one cluster cell on the simulated tier; rate is the TOTAL fleet
    arrival rate.  ``requests``/``trace`` override the Poisson stream;
    ``shed_factor``/``autoscale`` enable the control-plane admission and
    elastic-scaling controllers; ``disaggregate`` splits the fleet into
    prefill/decode pools with priced KV handoff (kwargs dict for
    ``build_sim_cluster``); ``fault_plan`` (FaultPlan or spec string) arms
    the seeded fault injector.  Returns (ClusterMetrics, ServingCluster)."""
    target, draft, hw = PAIRS[pair]
    cfg = SimConfig(target=target, draft=draft, hw=hw, max_batch=max_batch,
                    seed=seed, chunk_tokens=chunk_tokens,
                    prefix_caching=prefix_caching, num_blocks=num_blocks,
                    enable_offload=enable_offload)
    cl = build_sim_cluster(cfg, n_replicas, policy, router=router,
                           router_kwargs=router_kwargs,
                           shed_factor=shed_factor,
                           class_weights=class_weights, autoscale=autoscale,
                           disaggregate=disaggregate, fault_plan=fault_plan,
                           brownout=brownout, cancels=cancels)
    if requests is not None:
        reqs = requests
    elif trace is not None:
        reqs = trace.sample_requests(n, dataset=dataset, seed=seed + 1)
    else:
        reqs = poisson_requests(rate, n, dataset=dataset, seed=seed + 1)
    m = cl.run(reqs, record_timeline=record_timeline)
    return m, cl


def saturated_gamma_stats(metrics, max_batch: int, *, last: int = 200):
    """Planner behaviour in the saturated (high-batch) regime: over the final
    `last` decode steps whose batch exceeded max_batch/2, the mean gamma and
    the fraction of pure-AR (gamma == 0) steps.  (None, None) when the
    replica never reached that regime."""
    hb = [r["gamma"] for r in metrics.timeline if r["B"] > max_batch // 2]
    if not hb:
        return None, None
    tail = hb[-min(last, len(hb)):]
    return (sum(tail) / len(tail),
            sum(1 for g in tail if g == 0) / len(tail))


def bench_out(fname: str) -> str:
    """Resolve a ``BENCH_*.json`` artifact path: the repo root by default,
    or ``$BENCH_OUT_DIR`` when set (CI smoke runs point this at a temp dir
    so bench artifacts never land in the checkout)."""
    root = os.environ.get("BENCH_OUT_DIR")
    if root:
        return os.path.join(root, fname)
    return os.path.join(os.path.dirname(__file__), "..", fname)


class CSV:
    """Collects `name,us_per_call,derived` rows (the harness contract)."""

    def __init__(self):
        self.rows = []

    def add(self, name: str, us_per_call: float, derived: str):
        row = f"{name},{us_per_call:.2f},{derived}"
        self.rows.append(row)
        print(row, flush=True)


def timed(fn, *args, repeat=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt
