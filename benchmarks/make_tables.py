"""Render the EXPERIMENTS.md §Roofline-table from the dry-run JSONs.

Also post-corrects the CPU-upcast artifact accounting for runs produced by
the earlier (deduplicating) detector: the k and v shadow buffers have
identical dims, so the artifact for decode cells is 2x the deduped figure.

  PYTHONPATH=src python -m benchmarks.make_tables
"""
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
HBM = 16e9


def bench_path(fname):
    """BENCH_* artifacts follow ``$BENCH_OUT_DIR`` when set (matching
    ``benchmarks.common.bench_out``, so CI temp-dir runs render too);
    dry-run artifacts always live at the repo root."""
    root = os.environ.get("BENCH_OUT_DIR") or ROOT
    return os.path.join(root, fname)


def load(fname):
    path = os.path.join(ROOT, fname)
    return json.load(open(path)) if os.path.exists(path) else []


def fix_artifact(c):
    """Floor the TPU-adjusted peak at args+outputs-alias (the artifact
    detector can overcount when one buffer receives several updates)."""
    raw = c["peak_bytes_per_device"]
    adj = c.get("peak_bytes_tpu_adjusted", raw)
    floor = c.get("argument_bytes_per_device", 0)
    c["peak_bytes_tpu_adjusted"] = max(adj, min(floor, raw))
    c["fits_hbm"] = c["peak_bytes_tpu_adjusted"] < HBM
    return c


def table(cells, title):
    out = [f"\n### {title}\n"]
    out.append("| arch | shape | mode | peak GB (tpu) | fits | bottleneck | "
               "compute s | memory s | collective s | ideal-mem s |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for c in cells:
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['weight_mode']} "
            f"| {c['peak_bytes_tpu_adjusted']/1e9:.2f} "
            f"| {'Y' if c['fits_hbm'] else 'N'} "
            f"| {c['bottleneck']} | {c['compute_s']:.4f} "
            f"| {c['memory_s']:.4f} | {c['collective_s']:.4f} "
            f"| {c.get('ideal_memory_s', 0):.4f} |")
    return "\n".join(out)


def fmt_ms(v, n=1):
    """Latency cell guarded by its sample count: `percentile` returns 0.0
    on EMPTY input (and `goodput_of` returns 0.0 at zero elapsed), so a
    cell backed by zero samples would render as a perfect 0ms — render
    `n/a` instead whenever the count is 0."""
    return f"{v * 1e3:.0f}ms" if n else "n/a"


def fmt_num(v, n=1, spec=".1f"):
    """Numeric cell with the same zero-sample guard as :func:`fmt_ms`."""
    return format(v, spec) if n else "n/a"


def prefix_table():
    """Render the prefix-sharing grid persisted by `run.py --only prefix`."""
    path = bench_path("BENCH_prefix.json")
    if not os.path.exists(path):
        print("BENCH_prefix.json: missing (run benchmarks.run --only prefix)")
        return
    data = json.load(open(path))
    out = [f"\n### Prefix-sharing CoW KV cache "
           f"(chunk={data.get('chunk_tokens')}, "
           f"template={data.get('template_len')} tokens)\n"]
    out.append("| cell | p50 TTFT | p99 TTFT | goodput tok/s | blocks "
               "| hit rate | saved prefill tok | tokens sha |")
    out.append("|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        n = r.get("finished", 1)
        out.append(
            f"| {name} | {fmt_ms(r['p50_ttft_s'], n)} "
            f"| {fmt_ms(r['p99_ttft_s'], n)} "
            f"| {fmt_num(r['goodput_tok_s'], n)} | {r['blocks_allocated']} "
            f"| {r['prefix_hit_rate']:.3f} | {r['saved_prefill_tokens']} "
            f"| {r['tokens_sha']} |")
    print("\n".join(out))


def control_table():
    """Render the control-plane grid persisted by `run.py --only control`."""
    path = bench_path("BENCH_control.json")
    if not os.path.exists(path):
        print("BENCH_control.json: missing (run benchmarks.run "
              "--only control)")
        return
    data = json.load(open(path))
    tmeta = data.get("templated", {})
    bmeta = data.get("bursty", {})
    out = [f"\n### Cluster control plane (templated arm: "
           f"{tmeta.get('num_templates')} templates x "
           f"{tmeta.get('template_len')} tokens, "
           f"chunk={tmeta.get('chunk_tokens')}, caching on; bursty arm: "
           f"{bmeta.get('trace', 'baseline->spike->drain')}, monolithic, "
           f"caching off)\n"]
    out.append("| cell | p50 TTFT | p99 TTFT | SLO att | offered | shed "
               "| hit rate | per-replica reqs | peak reps | replica s |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        reqs = "/".join(str(c) for c in r.get("replica_requests", [])) or "-"
        n = r.get("finished", 1)
        # offered-traffic attainment is None (n/a by contract) when no
        # request was offered inside the window — same guard as fmt_ms
        offered = r.get("slo_attainment_offered", r["slo_attainment"])
        out.append(
            f"| {name} | {fmt_ms(r['p50_ttft_s'], n)} "
            f"| {fmt_ms(r['p99_ttft_s'], n)} "
            f"| {r['slo_attainment']:.3f} "
            f"| {'n/a' if offered is None else format(offered, '.3f')} "
            f"| {r.get('shed', 0)} "
            f"| {r.get('prefix_hit_rate', 0.0):.3f} "
            f"| {reqs} "
            f"| {r.get('peak_replicas', 2)} "
            f"| {r.get('replica_seconds', 0.0):.0f} |")
    print("\n".join(out))


def sessions_table():
    """Render the host-offload session grid from `run.py --only sessions`."""
    path = bench_path("BENCH_sessions.json")
    if not os.path.exists(path):
        print("BENCH_sessions.json: missing (run benchmarks.run "
              "--only sessions)")
        return
    data = json.load(open(path))
    out = [f"\n### Host-memory KV offload, multi-turn sessions "
           f"({data.get('sessions')} sessions x {data.get('turns')} turns, "
           f"pool={data.get('num_blocks')} blocks, "
           f"chunk={data.get('chunk_tokens')}, caching on)\n"]
    out.append("| cell | warm p50 | warm p99 | cold p50 | cold p99 "
               "| x-turn hit | restores | restore s | goodput | tokens sha |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        nw, nc = r.get("warm_turns", 0), r.get("cold_turns", 0)
        out.append(
            f"| {name} | {fmt_ms(r['p50_warm_ttft_s'], nw)} "
            f"| {fmt_ms(r['p99_warm_ttft_s'], nw)} "
            f"| {fmt_ms(r['p50_cold_ttft_s'], nc)} "
            f"| {fmt_ms(r['p99_cold_ttft_s'], nc)} "
            f"| {fmt_num(r['cross_turn_hit_rate'], nw, '.3f')} "
            f"| {r['host_restores']} | {r['host_restore_s']:.4f} "
            f"| {fmt_num(r['goodput_tok_s'], r.get('finished', 1))} "
            f"| {r['tokens_sha']} |")
    print("\n".join(out))


def disagg_table():
    """Render the disaggregated-fleet grid from `run.py --only disagg`."""
    path = bench_path("BENCH_disagg.json")
    if not os.path.exists(path):
        print("BENCH_disagg.json: missing (run benchmarks.run --only disagg)")
        return
    data = json.load(open(path))
    hi = data.get("high", {})
    out = [f"\n### Disaggregated prefill/decode fleet "
           f"({data.get('replicas')} replicas vs {data.get('split')}, "
           f"dataset={data.get('dataset')} qa_frac={data.get('qa_frac')}, "
           f"chunk={data.get('chunk_tokens')}, "
           f"max_batch={data.get('max_batch')}, "
           f"high rate={hi.get('rate_qps')}qps)\n"]
    out.append("| cell | p50 TTFT | p99 TTFT | SLO att | goodput tok/s "
               "| handoffs | declined | transfer s | replica s "
               "| tokens sha |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        n = r.get("finished", 1)
        out.append(
            f"| {name} | {fmt_ms(r['p50_ttft_s'], n)} "
            f"| {fmt_ms(r['p99_ttft_s'], n)} "
            f"| {fmt_num(r['slo_attainment'], n, '.3f')} "
            f"| {fmt_num(r['goodput_tok_s'], n)} "
            f"| {r.get('handoffs', 0)} | {r.get('handoffs_declined', 0)} "
            f"| {r.get('handoff_transfer_s', 0.0):.4f} "
            f"| {r.get('replica_seconds', 0.0):.0f} "
            f"| {r['tokens_sha']} |")
    acc = data.get("acceptance", {})
    if acc:
        out.append("\nacceptance: "
                   + "; ".join(f"{k}={v}" for k, v in sorted(acc.items())))
    print("\n".join(out))


def chaos_table():
    """Render the chaos gate grid from `run.py --only chaos`.

    MTTD/MTTR/recovery cells follow the n/a-by-contract rule (the same
    contract tests/test_metrics_edges.py pins for latency percentiles): a
    cell where no crash fired carries ``None``, never 0.0 — a fault-free
    run has no recovery time, not an infinitely fast one."""
    path = bench_path("BENCH_chaos.json")
    if not os.path.exists(path):
        print("BENCH_chaos.json: missing (run benchmarks.run --only chaos)")
        return
    data = json.load(open(path))
    out = [f"\n### Chaos gate ({data.get('replicas')} replicas, "
           f"dataset={data.get('dataset')}, rate={data.get('rate_qps')}qps, "
           f"n={data.get('requests')})\n"]
    out.append("| cell | finished | p99 TTFT | SLO att | crashes | lost "
               "| requeues | failed | MTTD | MTTR | tokens sha |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        n = r.get("finished", 1)
        nc = r.get("crashes", 0)
        mttd = r.get("mttd_s")
        mttr = r.get("mttr_s")
        out.append(
            f"| {name} | {n}/{data.get('requests')} "
            f"| {fmt_ms(r['p99_ttft_s'], n)} "
            f"| {fmt_num(r['slo_attainment'], n, '.3f')} "
            f"| {nc} | {r.get('requests_lost', 0)} "
            f"| {r.get('requeues', 0)} | {r.get('failed_requests', 0)} "
            f"| {fmt_ms(mttd if mttd is not None else 0.0, nc)} "
            f"| {fmt_ms(mttr if mttr is not None else 0.0, nc)} "
            f"| {r['tokens_sha']} |")
    acc = data.get("acceptance", {})
    if acc:
        out.append("\nacceptance: "
                   + "; ".join(f"{k}={v}" for k, v in sorted(acc.items())))
    print("\n".join(out))


def surge_table():
    """Render the overload-surge gate grid from `run.py --only surge`.

    Per-class SLO-attainment cells follow the n/a-by-contract rule: a
    class with zero admitted-and-finished deadline samples renders as
    ``n/a``, never a perfect 0 or 1.  The brownout stage line summarises
    the ladder timeline (every observable transition, in order)."""
    path = bench_path("BENCH_surge.json")
    if not os.path.exists(path):
        print("BENCH_surge.json: missing (run benchmarks.run --only surge)")
        return
    data = json.load(open(path))
    tr = data.get("trace", {})
    out = [f"\n### Overload surge gate ({data.get('replicas')} replicas, "
           f"dataset={data.get('dataset')}, "
           f"{tr.get('base_qps')}qps x{tr.get('surge_mult')} plateau "
           f"{tr.get('surge_s')}s, n={data.get('requests')}, "
           f"{data.get('cancel_schedule')} seeded cancellations)\n"]
    out.append("| cell | finished | shed | cancelled | expired "
               "| int att (offered) | batch att | be att | goodput tok/s "
               "| ladder |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for name, r in sorted(data.get("grid", {}).items()):
        pc = r.get("per_class", {})

        def att(cls):
            b = pc.get(cls, {})
            v = b.get("slo_attainment")
            return "n/a" if v is None else format(v, ".3f")

        ia = r.get("interactive_offered_attainment")
        out.append(
            f"| {name} | {r.get('finished', 0)}/{data.get('requests')} "
            f"| {r.get('shed', 0)} | {r.get('cancelled', 0)} "
            f"| {r.get('expired', 0)} "
            f"| {'n/a' if ia is None else format(ia, '.3f')} "
            f"| {att('batch')} | {att('best_effort')} "
            f"| {fmt_num(r.get('goodput_tok_s', 0.0), r.get('finished', 0))} "
            f"| {r.get('brownout_transitions', 0)} transitions |")
    tl = data.get("grid", {}).get("brownout", {}).get("brownout_timeline", [])
    if tl:
        out.append("\nbrownout ladder: "
                   + " -> ".join(f"{e['to']}@{e['at']:.1f}s" for e in tl))
    acc = data.get("acceptance", {})
    if acc:
        out.append("\nacceptance: "
                   + "; ".join(f"{k}={v}" for k, v in sorted(acc.items())))
    print("\n".join(out))


def trace_table():
    """Render the flight-recorder report from ``trace_report.py --json-out``.

    Two tables off one artifact: the speculation-efficiency surface
    (per batch-bin/gamma cell of the planner's decision space) and the
    time-in-stage waterfall over finished requests, plus the measured
    restart-cost line.  n/a-by-contract: an acceptance cell only exists
    when drafts were proposed (gamma > 0), a latency-per-token cell only
    when the cell committed tokens — absent keys render ``n/a``."""
    path = bench_path("BENCH_trace_report.json")
    if not os.path.exists(path):
        print("BENCH_trace_report.json: missing (run launch/serve.py "
              "--trace T.jsonl, then benchmarks.trace_report T.jsonl "
              "--json-out BENCH_trace_report.json)")
        return
    data = json.load(open(path))
    wf = data.get("waterfall", {})
    out = [f"\n### Flight recorder ({data.get('events')} trace events, "
           f"{wf.get('requests', 0)} requests, "
           f"{wf.get('finished', 0)} finished)\n"]
    sb = wf.get("stage_breakdown", {})
    if sb:
        # lifecycle order, not the JSON round-trip's alphabetical order
        order = ("queue", "prefill", "decode", "transfer", "stall")
        out.append("| stage | mean s/req | % of e2e |")
        out.append("|---|---|---|")
        for stage in sorted(sb, key=lambda s: (order.index(s)
                                               if s in order else 99, s)):
            r = sb[stage]
            out.append(f"| {stage} | {r['mean_s']:.4f} "
                       f"| {100 * r['frac_of_e2e']:.1f}% |")
        out.append(f"\nfinished e2e: mean={wf['e2e_mean_s']:.3f}s "
                   f"p50={wf['e2e_p50_s']:.3f}s p99={wf['e2e_p99_s']:.3f}s")
    surf = data.get("spec_surface", {})
    if surf:
        out.append("\n| batch bin | gamma | steps | acceptance "
                   "| ms / committed tok |")
        out.append("|---|---|---|---|---|")
        for key in sorted(surf, key=lambda k: tuple(map(int, k.split("/")))):
            r = surf[key]
            bb, g = key.split("/")
            acc = r.get("acceptance_rate")
            lpc = r.get("latency_per_committed_s")
            out.append(
                f"| <={bb} | {g} | {r['steps']} "
                f"| {'n/a' if acc is None else format(acc, '.3f')} "
                f"| {'n/a' if lpc is None else format(1e3 * lpc, '.3f')} |")
    eps = data.get("restart_episodes", [])
    closed = [e for e in eps if e.get("restart_cost_s") is not None]
    if closed:
        out.append(f"\nmeasured restart cost: "
                   f"mean={data['restart_cost_mean_s']:.3f}s over "
                   f"{len(closed)} episode(s) "
                   f"(recovery {data['restart_recovery_mean_s']:.3f}s; "
                   + "; ".join(
                       f"#{i}: {e['restart_cost_s']:.2f}s via "
                       f"{e['deepest_stage']}" for i, e in enumerate(closed))
                   + ")")
    elif eps:
        out.append(f"\nrestart episodes: {len(eps)} entered, none closed "
                   "(no post-resume speculative commit in trace)")
    print("\n".join(out))


def main():
    for fname in ("dryrun_single_pod.json", "dryrun_multi_pod.json"):
        cells = [fix_artifact(c) for c in load(fname)]
        if not cells:
            print(f"{fname}: missing")
            continue
        json.dump(cells, open(os.path.join(ROOT, fname), "w"), indent=1)
        fits = sum(1 for c in cells if c["fits_hbm"])
        print(table(cells, f"{fname} ({fits}/{len(cells)} fit 16 GB)"))
    prefix_table()
    control_table()
    sessions_table()
    disagg_table()
    chaos_table()
    surge_table()
    trace_table()


if __name__ == "__main__":
    main()
