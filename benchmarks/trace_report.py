"""Trace analyzer: waterfalls, speculation surface, restart-cost attribution.

Consumes the JSONL traces emitted by ``TraceRecorder.export_jsonl``
(``launch/serve.py --trace PATH``) and derives three reports:

* **time-in-stage waterfalls** — per finished request, how its end-to-end
  latency splits across queue / prefill / decode / transfer / stall.  The
  stage machine closes every span contiguously, so the per-request stage
  durations sum to the e2e latency exactly (the span-balance invariant).

* **speculation-efficiency surface** — per (batch-size bin, gamma) cell of
  the planner's decision space: steps taken, draft-token acceptance rate,
  and latency per committed token.  This is the empirical reward surface
  the MAB explores (Eq. 4's measured counterpart).

* **restart-cost episodes** — the measured cost of a spec-off excursion:
  from the brownout ladder leaving ``normal`` (speculation suppressed /
  draft offloaded) through the draft reload to the first speculative
  commit after returning to ``normal``.  ``restart_cost_s`` is the full
  span; ``recovery_s`` isolates the post-resume part (reload + first
  verified step) that the paper's restart-cost term models.

Usage::

    python -m benchmarks.trace_report TRACE.jsonl [--json-out OUT.json]

With ``--json-out`` the structured report is also written as a
``BENCH_*``-style artifact for ``make_tables.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serving.observability import OUTCOMES, STAGES  # noqa: E402


# ---------------------------------------------------------------------------
# loading
# ---------------------------------------------------------------------------


def load_trace(path: str) -> list:
    """One JSON object per line; returns events in emit order."""
    events = []
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def _percentile(sorted_vals, q):
    if not sorted_vals:
        return 0.0
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


# ---------------------------------------------------------------------------
# time-in-stage waterfalls
# ---------------------------------------------------------------------------


def stage_waterfalls(events: list) -> dict:
    """Per-request lifecycle: req_id -> {submit, end, outcome, e2e,
    stages: {stage: seconds}}.  Only requests with a terminal outcome are
    returned (open spans at trace end have no e2e latency to partition)."""
    reqs: dict = {}
    for e in events:
        rid = e.get("req")
        if rid is None or e.get("cat") != "request":
            continue
        r = reqs.setdefault(rid, {"submit": None, "end": None,
                                  "outcome": None,
                                  "stages": {s: 0.0 for s in STAGES}})
        if e["ph"] == "X":
            r["stages"][e["name"]] = r["stages"].get(e["name"], 0.0) \
                + e["dur"]
        elif e["name"] == "submit":
            r["submit"] = e["t"]
        elif e["name"] in OUTCOMES:
            r["outcome"] = e["name"]
            r["end"] = e["t"]
    out = {}
    for rid, r in sorted(reqs.items()):
        if r["outcome"] is None or r["submit"] is None:
            continue
        r["e2e"] = round(r["end"] - r["submit"], 9)
        out[rid] = r
    return out


def waterfall_summary(waterfalls: dict) -> dict:
    """Aggregate the per-request waterfalls: outcome counts, and for the
    finished population the mean seconds + fraction of e2e per stage and
    e2e percentiles."""
    outcomes: dict = {}
    for r in waterfalls.values():
        outcomes[r["outcome"]] = outcomes.get(r["outcome"], 0) + 1
    fin = [r for r in waterfalls.values() if r["outcome"] == "finished"]
    summary = {"requests": len(waterfalls),
               "outcomes": dict(sorted(outcomes.items())),
               "finished": len(fin)}
    if fin:
        tot_e2e = sum(r["e2e"] for r in fin)
        stages = {}
        for s in STAGES:
            sec = sum(r["stages"].get(s, 0.0) for r in fin)
            stages[s] = {"mean_s": round(sec / len(fin), 6),
                         "frac_of_e2e": round(sec / tot_e2e, 4)
                         if tot_e2e > 0 else 0.0}
        lats = sorted(r["e2e"] for r in fin)
        summary["stage_breakdown"] = stages
        summary["e2e_mean_s"] = round(tot_e2e / len(fin), 6)
        summary["e2e_p50_s"] = round(_percentile(lats, 0.50), 6)
        summary["e2e_p99_s"] = round(_percentile(lats, 0.99), 6)
    return summary


# ---------------------------------------------------------------------------
# speculation-efficiency surface
# ---------------------------------------------------------------------------


def batch_bin(b: int) -> int:
    """Power-of-two batch-size bucket (1, 2, 4, ... as in the planner's
    bucketed state space)."""
    return 1 << max(int(b) - 1, 0).bit_length() if b > 1 else 1


def spec_surface(events: list) -> dict:
    """Per (batch bin, gamma) cell: steps, acceptance rate and latency per
    committed token, from the engine step spans.  Keys are strings
    ("bin/gamma") so the report round-trips through JSON."""
    cells: dict = {}
    for e in events:
        if e.get("cat") != "engine" or e.get("name") != "step" \
                or e.get("ph") != "X":
            continue
        a = e["args"]
        if a["B"] <= 0:
            continue
        key = (batch_bin(a["B"]), a["gamma"])
        c = cells.setdefault(key, {"steps": 0, "proposed": 0, "accepted": 0,
                                   "committed": 0, "latency_s": 0.0})
        c["steps"] += 1
        c["proposed"] += a["gamma"] * a["B"]
        c["accepted"] += a["accepted"]
        c["committed"] += a["tokens"]
        c["latency_s"] += e["dur"]
    out = {}
    for (bb, g), c in sorted(cells.items()):
        row = {"steps": c["steps"], "committed_tokens": c["committed"]}
        # n/a by contract: acceptance only defined when drafts were proposed
        if c["proposed"] > 0:
            row["acceptance_rate"] = round(c["accepted"] / c["proposed"], 4)
        if c["committed"] > 0:
            row["latency_per_committed_s"] = round(
                c["latency_s"] / c["committed"], 9)
        out[f"{bb}/{g}"] = row
    return out


# ---------------------------------------------------------------------------
# restart-cost attribution
# ---------------------------------------------------------------------------


def restart_episodes(events: list) -> list:
    """Measured spec-restart episodes from the fleet brownout transitions.

    An episode opens when the ladder leaves ``normal`` (speculation is the
    first capability shed) and closes at the first engine step that
    commits speculative tokens (gamma > 0, tokens > 0) at or after the
    ladder's return to ``normal``.  Draft ``reload`` events inside the
    window are attributed to the episode.  Episodes still open at trace
    end are reported with ``restart_cost_s: None``."""
    evs = sorted(events, key=lambda e: e["t"])
    episodes: list = []
    cur = None
    for e in evs:
        cat, name = e.get("cat"), e.get("name")
        if cat == "fleet" and name == "brownout":
            a = e["args"]
            if cur is None and a.get("from") == "normal":
                cur = {"entry_t": e["t"], "deepest_stage": a.get("to"),
                       "resume_t": None, "reloads": 0,
                       "first_commit_t": None, "restart_cost_s": None}
            elif cur is not None:
                if cur["resume_t"] is None:
                    cur["deepest_stage"] = max(
                        cur["deepest_stage"], a.get("to", ""),
                        key=lambda s: _stage_depth(s))
                if a.get("to") == "normal":
                    cur["resume_t"] = e["t"]
        elif cur is not None and cat == "memmgr" and name == "reload":
            cur["reloads"] += 1
        elif cur is not None and cur["resume_t"] is not None \
                and cat == "engine" and name == "step" and e["ph"] == "X":
            a = e["args"]
            if e["t"] >= cur["resume_t"] and a["gamma"] > 0 \
                    and a["tokens"] > 0:
                cur["first_commit_t"] = round(e["t"] + e["dur"], 9)
                cur["restart_cost_s"] = round(
                    cur["first_commit_t"] - cur["entry_t"], 9)
                cur["spec_off_s"] = round(
                    cur["resume_t"] - cur["entry_t"], 9)
                cur["recovery_s"] = round(
                    cur["first_commit_t"] - cur["resume_t"], 9)
                episodes.append(cur)
                cur = None
    if cur is not None:
        episodes.append(cur)   # still open at trace end
    return episodes


def _stage_depth(stage: str) -> int:
    order = ("normal", "spec_off", "draft_offload", "output_cap", "shed")
    return order.index(stage) if stage in order else -1


# ---------------------------------------------------------------------------
# report assembly
# ---------------------------------------------------------------------------


def analyze(events: list) -> dict:
    waterfalls = stage_waterfalls(events)
    episodes = restart_episodes(events)
    closed = [ep for ep in episodes if ep["restart_cost_s"] is not None]
    report = {"events": len(events),
              "waterfall": waterfall_summary(waterfalls),
              "spec_surface": spec_surface(events),
              "restart_episodes": episodes}
    if closed:
        report["restart_cost_mean_s"] = round(
            sum(ep["restart_cost_s"] for ep in closed) / len(closed), 6)
        report["restart_recovery_mean_s"] = round(
            sum(ep["recovery_s"] for ep in closed) / len(closed), 6)
    return report


def render(report: dict) -> str:
    lines = [f"trace events: {report['events']}"]
    wf = report["waterfall"]
    lines.append(f"requests: {wf['requests']} "
                 f"outcomes={wf['outcomes']}")
    if "stage_breakdown" in wf:
        lines.append(f"finished e2e: mean={wf['e2e_mean_s']:.3f}s "
                     f"p50={wf['e2e_p50_s']:.3f}s p99={wf['e2e_p99_s']:.3f}s")
        lines.append("time in stage (finished requests):")
        for s, row in wf["stage_breakdown"].items():
            lines.append(f"  {s:9s} mean={row['mean_s']:9.4f}s  "
                         f"{100 * row['frac_of_e2e']:5.1f}% of e2e")
    surf = report["spec_surface"]
    if surf:
        lines.append("speculation surface (batch bin / gamma):")
        for key, row in surf.items():
            acc = row.get("acceptance_rate")
            lpc = row.get("latency_per_committed_s")
            lines.append(
                f"  B<={key.split('/')[0]:>4s} g={key.split('/')[1]:>2s}  "
                f"steps={row['steps']:6d}  "
                f"acc={'n/a' if acc is None else f'{acc:.3f}'}  "
                f"lat/tok={'n/a' if lpc is None else f'{1e3 * lpc:.3f}ms'}")
    eps = report["restart_episodes"]
    lines.append(f"restart episodes: {len(eps)}")
    for i, ep in enumerate(eps):
        if ep["restart_cost_s"] is None:
            lines.append(f"  #{i}: entered spec-off at t={ep['entry_t']:.3f}s"
                         " — still open at trace end")
        else:
            lines.append(
                f"  #{i}: t={ep['entry_t']:.3f}s -> {ep['deepest_stage']}"
                f" ({ep['reloads']} reloads), resumed t={ep['resume_t']:.3f}s,"
                f" first spec commit t={ep['first_commit_t']:.3f}s:"
                f" restart_cost={ep['restart_cost_s']:.3f}s"
                f" (spec_off={ep['spec_off_s']:.3f}s"
                f" recovery={ep['recovery_s']:.3f}s)")
    if "restart_cost_mean_s" in report:
        lines.append(f"measured restart cost: "
                     f"mean={report['restart_cost_mean_s']:.3f}s "
                     f"(recovery {report['restart_recovery_mean_s']:.3f}s)")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace from --trace / export_jsonl")
    ap.add_argument("--json-out", default=None,
                    help="also write the structured report as JSON "
                         "(BENCH_trace_report.json for make_tables.py)")
    args = ap.parse_args(argv)
    report = analyze(load_trace(args.trace))
    print(render(report))
    if args.json_out:
        with open(args.json_out, "w", encoding="utf-8") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json_out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
