"""Chaos demo: crash a replica mid-run and watch the cluster recover.

A 2-replica fleet serves a seeded arrival stream.  At t=2s the fault
injector kills replica 1: its in-flight requests are lost with its KV
blocks, the failure detector notices the silence on the shared virtual
clock, a replacement replica spawns from the seeded factory, and every
lost request re-queues through the router with exponential backoff and
re-prefills from its prompt.  The punchline: ZERO requests dropped and
committed token streams byte-identical to the fault-free run — the crash
costs tail latency, never correctness.

    PYTHONPATH=src python examples/chaos_demo.py [--crash-at 2.0]
"""
import argparse
import hashlib
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.serving.cluster import FAILED  # noqa: E402
from repro.serving.costmodel import RTX_4090  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_cluster  # noqa: E402
from repro.serving.workload import poisson_requests  # noqa: E402


def stream_sha(m):
    stream = sorted((r.req_id, r.tokens) for r in m.requests)
    return hashlib.sha256(repr(stream).encode()).hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--crash-at", type=float, default=2.0)
    ap.add_argument("--requests", type=int, default=240)
    ap.add_argument("--rate", type=float, default=20.0)
    args = ap.parse_args()

    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, max_batch=256, seed=0)
    reqs = poisson_requests(args.rate, args.requests, dataset="alpaca",
                            seed=1)

    print("=== fault-free baseline ===")
    base = build_sim_cluster(cfg, 2, "nightjar").run(list(reqs))
    print(f"finished {len(base.requests)}/{args.requests}, "
          f"p99 TTFT {base.ttft_percentile(0.99)*1e3:.0f}ms, "
          f"SLO attainment {base.slo_attainment:.3f}, "
          f"tokens sha {stream_sha(base)}")

    plan = f"crash:1@{args.crash_at}"
    print(f"\n=== chaos run: {plan} ===")
    cl = build_sim_cluster(cfg, 2, "nightjar", fault_plan=plan)
    m = cl.run(list(reqs))
    c = m.crashes[0]
    print(f"crash at t={c['at']}s killed replica {c['replica']} with "
          f"{c['lost']} requests in flight")
    print(f"detected at t={c['detected_at']:.2f}s (MTTD {m.mttd:.2f}s), "
          f"recovered at t={c['recovered_at']:.2f}s (MTTR {m.mttr:.2f}s)")
    print(f"requeues {m.requeues}, retries {m.retries}, "
          f"failed {len(m.failed_requests)}")
    print(f"fleet: {len(cl.replicas)} replicas, states "
          f"{[s for s in cl.state]} "
          f"(replica {c['replica']} is {FAILED}, replacement spawned)")
    print(f"finished {len(m.requests)}/{args.requests}, "
          f"p99 TTFT {m.ttft_percentile(0.99)*1e3:.0f}ms, "
          f"SLO attainment {m.slo_attainment:.3f}, "
          f"tokens sha {stream_sha(m)}")

    ok = (len(m.requests) == args.requests
          and stream_sha(m) == stream_sha(base))
    print(f"\nzero dropped + byte-identical committed streams: "
          f"{'PASS' if ok else 'FAIL'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
