"""Chunked-prefill hybrid batching vs monolithic prefill: tail latency.

Monolithic admission prefills whole prompt batches in one call, so one long
prompt stalls every running sequence (head-of-line blocking).  With a
per-step token budget (--chunk-tokens) the scheduler emits prefill chunks
interleaved with decode, and the tail (p99 TTFT, SLO goodput) recovers at
high arrival rate.

    PYTHONPATH=src python examples/chunked_prefill_demo.py [--rate 80]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.serving.costmodel import RTX_4090  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_engine  # noqa: E402
from repro.serving.workload import poisson_requests  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=80.0)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--dataset", default="alpaca")
    ap.add_argument("--chunk-tokens", type=int, default=384)
    args = ap.parse_args()

    target = configs.get_config("paper-7b")
    draft = configs.get_draft_config("paper-7b")
    reqs = poisson_requests(args.rate, args.requests, dataset=args.dataset,
                            seed=1)

    print(f"{args.dataset} @ {args.rate} QPS, {args.requests} requests, "
          f"chunk budget {args.chunk_tokens} tokens/step\n")
    print(f"{'mode':12s} {'p50 TTFT':>9s} {'p99 TTFT':>9s} {'SLO att':>8s} "
          f"{'goodput':>10s} {'thrpt':>10s}")
    for label, chunk in (("monolithic", 0), ("chunked", args.chunk_tokens)):
        cfg = SimConfig(target=target, draft=draft, hw=RTX_4090,
                        max_batch=256, seed=0, chunk_tokens=chunk)
        eng = build_sim_engine(cfg, "nightjar")
        m = eng.run(list(reqs))
        print(f"{label:12s} {m.ttft_percentile(.5)*1e3:8.0f}ms "
              f"{m.ttft_percentile(.99)*1e3:8.0f}ms "
              f"{m.slo_attainment:8.2%} {m.goodput:7.1f}t/s "
              f"{m.throughput:7.1f}t/s")


if __name__ == "__main__":
    main()
