"""Overload demo: a 3x arrival surge with and without the brownout ladder.

A 2-replica fleet serves a seeded surge trace (baseline -> 3x plateau ->
recovery) carrying three priority classes (interactive / batch /
best_effort, each with its own TTFT SLO and hard deadline) plus a seeded
client-cancellation storm during the plateau.  The same workload runs
twice: once with classic class-blind admission only, once with
class-weighted admission and the fleet brownout ladder (speculation off
-> draft offload -> best_effort output cap -> class-ordered shedding,
with hysteresis and cooldowns).  The punchline: under the SAME overload
the ladder trades best_effort completeness for interactive SLO
attainment AND total goodput — graceful degradation, not collapse.

    PYTHONPATH=src python examples/overload_demo.py [--base-rate 60]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.serving.costmodel import RTX_4090  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_cluster  # noqa: E402
from repro.serving.workload import (cancellation_storm,  # noqa: E402
                                    surge_requests, surge_trace)


def offered_attainment(per_class, cls):
    """SLO attainment over the class's OFFERED load: shed, expired and
    failed requests count as misses; client cancellations are excluded."""
    b = per_class.get(cls)
    if b is None:
        return None
    denom = b["slo_samples"] + b["shed"] + b["expired"] + b["failed"]
    return b["slo_met"] / denom if denom else None


def report(label, m):
    pc = m.class_summary()
    ia = offered_attainment(pc, "interactive")
    print(f"=== {label} ===")
    print(f"finished {len(m.requests)}, shed {m.shed_count}, "
          f"cancelled {len(m.cancelled)}, expired {len(m.expired)}")
    for cls, b in sorted(pc.items()):
        print(f"  {cls:12s} offered {b['offered']:4d}  "
              f"finished {b['finished']:4d}  shed {b['shed']:4d}  "
              f"cancelled {b['cancelled']:3d}  expired {b['expired']:3d}")
    print(f"interactive offered-SLO attainment "
          f"{'n/a' if ia is None else format(ia, '.3f')}, "
          f"goodput {m.goodput:.0f} tok/s")
    if m.brownout_events:
        print("brownout ladder: "
              + " -> ".join(f"{e['to']}@{e['at']:.1f}s"
                            for e in m.brownout_events))
    print()
    return ia, m.goodput


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base-rate", type=float, default=60.0)
    ap.add_argument("--surge-mult", type=float, default=3.0)
    args = ap.parse_args()

    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, max_batch=256, seed=0)
    base_s, surge_s, recover_s = 6.0, 14.0, 8.0
    trace = surge_trace(base=args.base_rate, surge_mult=args.surge_mult,
                        base_s=base_s, surge_s=surge_s, recover_s=recover_s,
                        seed=2)
    n = int(args.base_rate * (base_s + recover_s)
            + args.base_rate * args.surge_mult * surge_s)
    reqs = surge_requests(n, trace=trace, dataset="alpaca", seed=1)
    cancels = cancellation_storm(reqs, frac=0.12, start=base_s + 2.0,
                                 end=base_s + surge_s, seed=4)
    print(f"workload: {n} requests, {args.base_rate:.0f}qps baseline, "
          f"x{args.surge_mult:.0f} plateau for {surge_s:.0f}s, "
          f"{len(cancels)} seeded cancellations\n")

    m_off = build_sim_cluster(cfg, 2, "nightjar", shed_factor=1.5,
                              cancels=cancels).run(list(reqs))
    ia_off, gp_off = report("class-blind admission, no brownout", m_off)

    weights = {"interactive": 1.5, "batch": 0.8, "best_effort": 0.4}
    bo = dict(slo=0.5, enter_factor=1.5, exit_factor=0.8, kv_low_frac=0.10,
              kv_calm_frac=0.30, best_effort_cap=32, cooldown_s=1.0,
              check_interval_s=0.25)
    m_on = build_sim_cluster(cfg, 2, "nightjar", shed_factor=1.5,
                             class_weights=weights, brownout=bo,
                             cancels=cancels).run(list(reqs))
    ia_on, gp_on = report("class-weighted admission + brownout ladder", m_on)

    ok = (ia_on is not None and ia_off is not None and ia_on > ia_off
          and gp_on > gp_off)
    print(f"brownout beats no-brownout on interactive attainment "
          f"({'n/a' if ia_off is None else format(ia_off, '.3f')} -> "
          f"{'n/a' if ia_on is None else format(ia_on, '.3f')}) and goodput "
          f"({gp_off:.0f} -> {gp_on:.0f} tok/s): {'PASS' if ok else 'FAIL'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
