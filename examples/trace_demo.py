"""Flight-recorder demo: deterministic tracing of a brownout surge cell.

Runs the surge-workload brownout cell (traffic surge + client-cancellation
storm + the fleet brownout ladder) twice with a :class:`TraceRecorder`
attached, then:

* checks the two same-seed traces are **byte-identical** (a trace is a
  pure function of config + seed — no wall-clock reads anywhere),
* analyzes the trace with ``benchmarks/trace_report.py``: time-in-stage
  waterfall, speculation-efficiency surface, and the **measured restart
  cost** — the span from the ladder leaving ``normal`` (speculation shed,
  draft offloaded) through the draft reload to the first speculative
  commit after resume.

Exits 0 iff the trace is deterministic AND a closed restart-cost episode
was measured; non-zero otherwise.

    PYTHONPATH=src python examples/trace_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.trace_report import analyze, render  # noqa: E402
from repro import configs  # noqa: E402
from repro.serving.costmodel import TPU_V5E  # noqa: E402
from repro.serving.observability import TraceRecorder  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_cluster  # noqa: E402
from repro.serving.workload import (cancellation_storm,  # noqa: E402
                                    surge_requests, surge_trace)


def run_cell():
    """One seeded brownout surge cell with the recorder attached (the
    benchmarks.run surge grid's ``brownout`` cell, fast parameters)."""
    base_s, surge_s, recover_s = 6.0, 14.0, 8.0
    base_rate, mult = 60.0, 3.0
    n = int(base_rate * (base_s + recover_s) + base_rate * mult * surge_s)
    trace = surge_trace(base=base_rate, surge_mult=mult, base_s=base_s,
                        surge_s=surge_s, recover_s=recover_s, seed=2)
    reqs = surge_requests(n, trace=trace, dataset="alpaca", seed=1)
    cancels = cancellation_storm(reqs, seed=4, frac=0.12, start=base_s + 2.0,
                                 end=base_s + surge_s)
    bo = dict(slo=0.5, enter_factor=1.5, exit_factor=0.8,
              kv_low_frac=0.10, kv_calm_frac=0.30, best_effort_cap=32,
              cooldown_s=1.0, check_interval_s=0.25)
    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=TPU_V5E, max_batch=256, seed=0)
    rec = TraceRecorder()
    cl = build_sim_cluster(
        cfg, 2, "nightjar", router="jsq", shed_factor=1.5,
        class_weights={"interactive": 1.5, "batch": 0.8, "best_effort": 0.4},
        brownout=bo, cancels=cancels, trace=rec)
    m = cl.run(list(reqs))
    return rec, m


def decode_jsonl(raw: bytes):
    import json
    return [json.loads(ln) for ln in raw.decode("utf-8").splitlines() if ln]


def main():
    print("running seeded surge cell twice (brownout ladder ON, traced)...")
    rec1, m = run_cell()
    rec2, _ = run_cell()

    b1, b2 = rec1.jsonl_bytes(), rec2.jsonl_bytes()
    deterministic = b1 == b2
    print(f"trace: {len(rec1.events)} events, {len(b1)} bytes, "
          f"dropped={rec1.dropped}")
    print(f"deterministic (byte-identical re-run): {deterministic}")

    report = analyze(decode_jsonl(b1))
    print()
    print(render(report))

    closed = [ep for ep in report["restart_episodes"]
              if ep["restart_cost_s"] is not None]
    ok = deterministic and bool(closed)
    print()
    print("PASS" if ok else "FAIL", "- restart-cost episodes measured:",
          len(closed))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
