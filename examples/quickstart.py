"""Quickstart: serve a small model with Nightjar adaptive speculation — REAL
JAX execution on CPU.

    PYTHONPATH=src python examples/quickstart.py

Shows the full pipeline: continuous batching, MAB planner picking gamma per
step, speculative draft+verify, and identical greedy outputs to plain AR.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro import configs  # noqa: E402
from repro.core.bandits import make_policy  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.serving.engine import ServingEngine  # noqa: E402
from repro.serving.kv_cache import BlockManager  # noqa: E402
from repro.serving.real_backend import RealBackend  # noqa: E402
from repro.serving.scheduler import ContinuousBatchingScheduler  # noqa: E402
from repro.serving.workload import tiny_requests  # noqa: E402


def serve(policy_name: str, reqs):
    cfg = configs.reduced(configs.get_config("deepseek-7b"))
    dcfg = configs.reduced(configs.get_draft_config("deepseek-7b"))
    target, draft = registry.get_model(cfg), registry.get_model(dcfg)

    # one BlockManager drives BOTH the scheduler's admission decisions and
    # the backend's physical paged-KV pool (zero-copy block-table indexing)
    bm = BlockManager(num_blocks=256, block_size=8)
    backend = RealBackend(target, draft, max_batch=4, max_seq=128, seed=0,
                          block_manager=bm)
    sched = ContinuousBatchingScheduler(bm, max_batch=4)
    policy = make_policy(policy_name, gamma_max=3, seed=0)
    engine = ServingEngine(backend, sched, policy, None, gamma_max=3)
    metrics = engine.run(reqs, max_steps=2000, record_timeline=True)
    outputs = {r.req_id: backend.output_tokens(r.req_id) for r in reqs}
    return metrics, outputs


def main():
    cfg = configs.reduced(configs.get_config("deepseek-7b"))
    reqs = tiny_requests(6, rate_qps=50, prompt_len=12, output_len=12,
                         vocab=cfg.vocab_size, seed=7)

    print("=== Nightjar (adaptive speculation) ===")
    m_nj, out_nj = serve("nightjar", reqs)
    print(m_nj.summary())
    gammas = [r["gamma"] for r in m_nj.timeline]
    print("gamma decisions over steps:", gammas[:40], "...")

    print("\n=== vanilla autoregressive ===")
    m_ar, out_ar = serve("ar", reqs)
    print(m_ar.summary())

    same = all(out_nj[k][:13] == out_ar[k][:13] for k in out_ar)
    print(f"\nLOSSLESS: greedy outputs identical across modes -> {same}")
    for rid in list(out_nj)[:2]:
        print(f"  request {rid}: {out_nj[rid][:12]}")


if __name__ == "__main__":
    main()
