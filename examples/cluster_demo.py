"""Multi-replica cluster demo: one arrival stream, N Nightjar replicas.

Shows the fleet-tier story: at low offered load every replica keeps
speculation on (memory-bound regime); crank the rate and each replica's
planner independently drives gamma to 0 (compute-bound regime), while the
router keeps the fleet balanced.  Also compares dispatch policies.

    PYTHONPATH=src python examples/cluster_demo.py [--replicas 2]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.serving.costmodel import RTX_4090  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_cluster  # noqa: E402
from repro.serving.workload import poisson_requests  # noqa: E402


def sparkline(vals, width=48):
    blocks = " ▁▂▃▄▅▆▇█"
    if not vals:
        return ""
    mx = max(vals) or 1
    step = max(len(vals) // width, 1)
    v = [max(vals[i:i + step]) for i in range(0, len(vals), step)]
    return "".join(blocks[int(x / mx * (len(blocks) - 1))] for x in v)


def gamma_windows(m, window_s=1.0):
    acc, cnt = {}, {}
    for r in m.timeline:
        w = int(r["t"] // window_s)
        acc[w] = acc.get(w, 0) + r["gamma"]
        cnt[w] = cnt.get(w, 0) + 1
    return [acc[w] / cnt[w] for w in sorted(acc)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--duration", type=float, default=10.0)
    args = ap.parse_args()

    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, max_batch=256, seed=0)

    print(f"=== {args.replicas}-replica cluster, low vs high offered load ===")
    for label, rate_per in (("low ", 4), ("high", 200)):
        rate = rate_per * args.replicas
        n = max(int(rate * args.duration), 40)
        cl = build_sim_cluster(cfg, args.replicas, "nightjar", router="jsq")
        m = cl.run(poisson_requests(rate, n, dataset="alpaca", seed=1),
                   record_timeline=True)
        print(f"\n{label} ({rate} req/s total, {n} requests): "
              f"aggregate {m.throughput:7.1f} tok/s, "
              f"mean latency {m.mean_latency:.2f}s")
        for i, rm in enumerate(m.per_replica):
            gw = gamma_windows(rm)
            print(f"  replica {i}: gamma {sparkline(gw)}  "
                  f"(mean {sum(gw) / max(len(gw), 1):.2f})  "
                  f"{m.replica_counts()[i]} reqs, {rm.throughput:7.1f} tok/s")

    print("\n=== router comparison (2 replicas, 40 req/s sharegpt) ===")
    for router in ("rr", "jsq", "kv"):
        cl = build_sim_cluster(cfg, 2, "nightjar", router=router)
        m = cl.run(poisson_requests(40, 300, dataset="sharegpt", seed=1))
        print(f"  {router:3s}: {m.throughput:7.1f} tok/s, "
              f"latency {m.mean_latency:5.2f}s, "
              f"balance {m.replica_counts()}")


if __name__ == "__main__":
    main()
