"""Walkthrough of the elastic memory manager (§6): offload -> pool expansion
-> KV writes into the extended region -> contraction with kernel-backed
block migration -> draft reload.  Real block tables + real array moves.

    PYTHONPATH=src python examples/elastic_memory_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.serving.kv_cache import BlockManager, PhysicalKVPool  # noqa: E402
from repro.serving.memory_manager import ElasticMemoryManager  # noqa: E402


def main():
    L, nb, bs, kh, hd = 4, 24, 4, 2, 16
    bm = BlockManager(nb, bs)
    pool = PhysicalKVPool(L, nb, bs, kh, hd, dtype=jnp.float32)
    draft_blocks = 8

    mm = ElasticMemoryManager(
        bm, draft_blocks=draft_blocks, tau_low_frac=0.15, t_persist=2,
        offload_latency=0.004, reload_latency=0.004,
        migrate_fn=lambda plan: (pool.migrate(plan, use_kernel=True), 0.002)[1])

    print(f"pool: {nb} blocks x {bs} tokens; draft model worth "
          f"{draft_blocks} blocks; tau_low={mm.tau_low} blocks")

    # 1. load up the pool until pressure
    bm.allocate(1, 60)
    bm.allocate(2, 28)
    print(f"\n[load] free blocks = {bm.num_free} (< tau_low -> pressure)")

    # 2. speculation disabled + pressure persists -> offload & expand
    for step in range(3):
        mm.step(float(step), spec_disabled=True, waiting=4)
    print(f"[expand] draft_resident={mm.draft_resident} "
          f"total_blocks={bm.total_blocks} free={bm.num_free}")
    pool.grow(draft_blocks)

    # 3. new sequence lands in the extended region
    bm.allocate(3, 24)
    rng = np.random.default_rng(0)
    vals = rng.normal(size=(L, 24, kh, hd)).astype(np.float32)
    pool.write_tokens(jnp.asarray(vals), jnp.asarray(2 * vals),
                      bm.tables[3], 0)
    high = [b for b in bm.tables[3] if b >= bm.boundary]
    print(f"[write] seq3 occupies extended blocks {high}")
    before_k, before_v = pool.gather_sequence(bm.tables[3], 24)

    # 4. load drains -> contraction: plan, migrate (Pallas kernel), remap
    bm.release(1)
    mm.step(10.0, spec_disabled=True, waiting=0)
    pool.shrink(bm.base_blocks)
    print(f"[contract] total_blocks={bm.total_blocks} "
          f"draft_resident={mm.draft_resident}")
    print(f"  events: {[(e.kind, e.detail) for e in mm.events]}")

    # 5. verify logical consistency after physical moves
    after_k, after_v = pool.gather_sequence(bm.tables[3], 24)
    ok = (np.array_equal(np.asarray(before_k), np.asarray(after_k))
          and np.array_equal(np.asarray(before_v), np.asarray(after_v)))
    print(f"\nlogical KV identical across migration: {ok}")
    assert ok
    bm.check_invariants()
    print("allocator invariants hold")


if __name__ == "__main__":
    main()
