"""Train a small LM end-to-end with the full substrate: synthetic data
pipeline, AdamW, checkpointing, and crash-resume fault tolerance.

Default is a quick CPU-sized run; ``--model-dim/--layers/--steps`` scale it
up (e.g. ``--layers 12 --model-dim 768 --steps 300`` is a ~100M-param run).

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.training.data import make_batch_iter  # noqa: E402
from repro.training.train_loop import train  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--model-dim", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--simulate-crash", action="store_true",
                    help="stop at 50%% and resume, proving restart safety")
    args = ap.parse_args()

    cfg = configs.get_config("deepseek-7b").replace(
        num_layers=args.layers, d_model=args.model_dim,
        num_heads=max(args.model_dim // 64, 1),
        num_kv_heads=max(args.model_dim // 64, 1),
        d_ff=args.model_dim * 4, vocab_size=args.vocab,
        attn_chunk=128, xent_chunk=128)
    from repro.models import registry
    print(f"model: {registry.param_count(cfg)/1e6:.1f}M params")

    it = make_batch_iter(cfg.vocab_size, args.batch, args.seq, seed=0)

    if args.simulate_crash:
        half = args.steps // 2
        print(f"training to step {half}, then 'crashing'...")
        train(cfg, steps=half, batch_iter=it, checkpoint_dir=args.ckpt_dir,
              checkpoint_every=10)
        print("resuming from the latest checkpoint...")

    out = train(cfg, steps=args.steps, batch_iter=it,
                checkpoint_dir=args.ckpt_dir, checkpoint_every=20)
    for h in out["history"]:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"gnorm {h['grad_norm']:.3f}")
    print(f"done in {out['elapsed_s']:.1f}s; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
