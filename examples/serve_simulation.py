"""End-to-end serving driver (the paper's kind): batched requests under a
dynamic request-rate trace, Nightjar vs baselines at paper scale on the
analytical TPU-v5e tier.  Reproduces the Figure 11 dynamics.

    PYTHONPATH=src python examples/serve_simulation.py [--rate-high 30]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs  # noqa: E402
from repro.serving.costmodel import RTX_4090  # noqa: E402
from repro.serving.simulator import SimConfig, build_sim_engine  # noqa: E402
from repro.serving.workload import dynamic_rate_trace  # noqa: E402


def sparkline(vals, width=60):
    blocks = " ▁▂▃▄▅▆▇█"
    if not vals:
        return ""
    mx = max(vals) or 1
    step = max(len(vals) // width, 1)
    v = [max(vals[i:i + step]) for i in range(0, len(vals), step)]
    return "".join(blocks[int(x / mx * (len(blocks) - 1))] for x in v)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate-low", type=float, default=3)
    ap.add_argument("--rate-high", type=float, default=28)
    ap.add_argument("--requests", type=int, default=400)
    args = ap.parse_args()

    target = configs.get_config("paper-7b")
    draft = configs.get_draft_config("paper-7b")
    trace = dynamic_rate_trace(duration_s=90, low=args.rate_low,
                               high=args.rate_high, period_s=25)

    print(f"dynamic trace: {args.rate_low} <-> {args.rate_high} QPS")
    print("rate    :", sparkline([trace.rate_at(t) for t in range(90)]))
    results = {}
    for pol in ["ar", "sd", "dsd", "banditspec", "nightjar"]:
        cfg = SimConfig(target=target, draft=draft, hw=RTX_4090,
                        max_batch=256, seed=0)
        eng = build_sim_engine(cfg, pol)
        reqs = trace.sample_requests(args.requests, dataset="sharegpt", seed=1)
        m = eng.run(reqs, max_steps=500_000, record_timeline=True)
        results[pol] = m
        # throughput over 3s windows
        win = {}
        for r in m.timeline:
            win[int(r["t"] // 3)] = win.get(int(r["t"] // 3), 0) + r["tokens"]
        series = [win.get(w, 0) / 3 for w in range(int(m.elapsed // 3) + 1)]
        print(f"{pol:10s}: {sparkline(series)}  "
              f"thr={m.throughput:7.1f} tok/s lat={m.mean_latency:6.2f}s "
              f"switches={m.switch_count}")

    nj = results["nightjar"].throughput
    print(f"\nNightjar vs w/o-SD : {100*(nj/results['ar'].throughput-1):+.1f}%")
    print(f"Nightjar vs SD     : {100*(nj/results['sd'].throughput-1):+.1f}%")
    print(f"Nightjar vs DSD    : {100*(nj/results['dsd'].throughput-1):+.1f}%")


if __name__ == "__main__":
    main()
