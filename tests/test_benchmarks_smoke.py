"""Benchmark-harness smoke: the prefill grid, the control-plane grid, the
dense-vs-paged backend grid and the table renderer run end-to-end under
tier-1, so the bench entrypoints can't silently rot."""
import json
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=600)


def test_prefill_grid_end_to_end():
    res = _run("benchmarks.run", "--only", "prefill", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("prefill.")]
    # {low,high} x {monolithic,chunked} grid, CSV contract respected
    assert len(rows) == 4
    names = {r.split(",")[0] for r in rows}
    assert names == {"prefill.low.monolithic", "prefill.low.chunk384",
                     "prefill.high.monolithic", "prefill.high.chunk384"}
    for row in rows:
        assert "p99_ttft=" in row and "goodput=" in row

    def p99(name):
        row = next(r for r in rows if r.startswith(name + ","))
        field = next(f for f in row.split(";") if "p99_ttft=" in f)
        return float(field.split("p99_ttft=")[1].rstrip("ms"))

    # the headline result: chunked prefill cuts the tail at the high-rate
    # (compute-bound, head-of-line-blocked) point
    assert p99("prefill.high.chunk384") < p99("prefill.high.monolithic")


def test_prefix_grid_end_to_end():
    """`--only prefix` runs the {templated,disjoint} x {cache,nocache} grid,
    persists BENCH_prefix.json, and the headline templated.high cell shows
    prefix caching strictly reducing p99 TTFT and allocated blocks with
    byte-identical committed token streams — the acceptance criterion."""
    res = _run("benchmarks.run", "--only", "prefix", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("prefix.")]
    names = {r.split(",")[0] for r in rows}
    assert names == {f"prefix.{wl}.{rate}.{mode}"
                     for wl in ("templated", "disjoint")
                     for rate in ("low", "high")
                     for mode in ("cache", "nocache")}

    data = json.load(open(os.path.join(ROOT, "BENCH_prefix.json")))
    grid = data["grid"]
    for rate in ("low", "high"):
        on = grid[f"templated.{rate}.cache"]
        off = grid[f"templated.{rate}.nocache"]
        # identical committed token streams, every request finished
        assert on["tokens_sha"] == off["tokens_sha"]
        assert on["finished"] == off["finished"] > 0
        # the headline: strictly lower tail latency AND block consumption
        assert on["p99_ttft_s"] < off["p99_ttft_s"]
        assert on["blocks_allocated"] < off["blocks_allocated"]
        assert on["prefix_hit_rate"] > 0.5
    # the disjoint control: caching buys nothing and costs nothing
    for rate in ("low", "high"):
        on = grid[f"disjoint.{rate}.cache"]
        off = grid[f"disjoint.{rate}.nocache"]
        assert on["tokens_sha"] == off["tokens_sha"]
        assert on["prefix_hit_rate"] == 0.0


def test_control_grid_end_to_end():
    """`--only control` runs the control-plane grid, persists
    BENCH_control.json, and the acceptance criteria hold: affinity routing
    strictly beats kv on aggregate prefix hit-rate and p99 TTFT with
    identical per-request committed token counts (templated arm), and the elastic fleet
    strictly beats the static fleet on SLO attainment of admitted traffic
    at equal peak replica count (bursty arm)."""
    res = _run("benchmarks.run", "--only", "control", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("control.")]
    names = {r.split(",")[0] for r in rows}
    assert {f"control.templated.static.{r}"
            for r in ("rr", "kv", "slo", "affinity")} <= names
    assert {f"control.bursty.{f}.{r}" for f in ("static", "autoscale")
            for r in ("kv", "slo")} <= names

    data = json.load(open(os.path.join(ROOT, "BENCH_control.json")))
    grid = data["grid"]
    # templated arm: cache specialisation under sticky routing
    aff = grid["templated.static.affinity"]
    kv = grid["templated.static.kv"]
    assert aff["tokens_sha"] == kv["tokens_sha"]
    assert aff["finished"] == kv["finished"] > 0
    assert aff["prefix_hit_rate"] > kv["prefix_hit_rate"]
    assert aff["p99_ttft_s"] < kv["p99_ttft_s"]
    # bursty arm: elastic vs static at equal peak replica count
    for router in ("kv", "slo"):
        el = grid[f"bursty.autoscale.{router}"]
        st = grid[f"bursty.static.{router}"]
        assert el["peak_replicas"] == st["peak_replicas"] == 2
        assert el["slo_attainment"] > st["slo_attainment"]
        assert el["shed"] > 0 and st["shed"] == 0
        assert el["replica_seconds"] < st["replica_seconds"]
        assert el["autoscale_adds"] >= 1


def test_sessions_grid_end_to_end():
    """`--only sessions` runs the host-offload session grid, persists
    BENCH_sessions.json, and the acceptance criteria hold: with offload on
    at a fixed device pool, warm-turn p50/p99 TTFT strictly below cold-turn
    TTFT, cross-turn prefix hit-rate > 0.8, host restores actually happen,
    and committed token streams are byte-identical vs offload-off."""
    res = _run("benchmarks.run", "--only", "sessions", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("sessions.")]
    assert {r.split(",")[0] for r in rows} == {"sessions.none",
                                              "sessions.offload"}

    data = json.load(open(os.path.join(ROOT, "BENCH_sessions.json")))
    on, off = data["grid"]["offload"], data["grid"]["none"]
    # identical committed token streams, every request finished, same split
    assert on["tokens_sha"] == off["tokens_sha"]
    assert on["finished"] == off["finished"] > 0
    assert on["warm_turns"] == off["warm_turns"] > 0
    assert on["cold_turns"] == off["cold_turns"] > 0
    # the headline: restored history makes warm turns strictly cheaper
    assert on["p50_warm_ttft_s"] < on["p50_cold_ttft_s"]
    assert on["p99_warm_ttft_s"] < on["p99_cold_ttft_s"]
    assert on["cross_turn_hit_rate"] > 0.8
    assert on["cross_turn_hit_rate"] > off["cross_turn_hit_rate"]
    # the tier actually moved blocks both ways, at modelled PCIe cost
    assert on["host_restores"] > 0 and on["host_spills"] > 0
    assert on["host_restore_s"] > 0
    assert off["host_restores"] == off["host_spills"] == 0


def test_backend_grid_end_to_end():
    """`--only backend` runs REAL dense and paged backends, prints the CSV
    grid and persists BENCH_backend.json with the capacity comparison."""
    res = _run("benchmarks.run", "--only", "backend", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("backend.")]
    names = {r.split(",")[0] for r in rows}
    assert names == {f"backend.{m}.{op}" for m in ("dense", "paged")
                     for op in ("prefill", "decode", "verify")} | \
        {"backend.capacity"}
    data = json.load(open(os.path.join(ROOT, "BENCH_backend.json")))
    assert set(data["grid"]) == {"dense", "paged"}
    for row in data["grid"].values():
        assert all(v > 0 for v in row.values())
    # the paged pool admits by actual context, not per-slot max_seq
    cap = data["capacity"]
    assert cap["paged_max_batch"] > cap["dense_max_batch"]


def test_make_tables_end_to_end():
    res = _run("benchmarks.make_tables")
    assert res.returncode == 0, res.stderr[-2000:]
    # with or without dry-run artifacts present it must report each file
    assert "dryrun_single_pod.json" in res.stdout
    # and the prefix grid section renders (table when the JSON exists,
    # a pointer when it doesn't)
    assert "BENCH_prefix" in res.stdout or "Prefix-sharing" in res.stdout
    # same for the control-plane grid
    assert "BENCH_control" in res.stdout or "control plane" in res.stdout
