"""Benchmark-harness smoke: the prefill grid, the control-plane grid, the
dense-vs-paged backend grid and the table renderer run end-to-end under
tier-1, so the bench entrypoints can't silently rot."""
import json
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(*args, env_extra=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-m", *args], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=600)


def test_prefill_grid_end_to_end():
    res = _run("benchmarks.run", "--only", "prefill", "--fast")
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("prefill.")]
    # {low,high} x {monolithic,chunked} grid, CSV contract respected
    assert len(rows) == 4
    names = {r.split(",")[0] for r in rows}
    assert names == {"prefill.low.monolithic", "prefill.low.chunk384",
                     "prefill.high.monolithic", "prefill.high.chunk384"}
    for row in rows:
        assert "p99_ttft=" in row and "goodput=" in row

    def p99(name):
        row = next(r for r in rows if r.startswith(name + ","))
        field = next(f for f in row.split(";") if "p99_ttft=" in f)
        return float(field.split("p99_ttft=")[1].rstrip("ms"))

    # the headline result: chunked prefill cuts the tail at the high-rate
    # (compute-bound, head-of-line-blocked) point
    assert p99("prefill.high.chunk384") < p99("prefill.high.monolithic")


def test_prefix_grid_end_to_end(tmp_path):
    """`--only prefix` runs the {templated,disjoint} x {cache,nocache} grid,
    persists BENCH_prefix.json (to $BENCH_OUT_DIR — smoke runs must not
    clobber the committed artifact), and the headline templated.high cell
    shows prefix caching strictly reducing p99 TTFT and allocated blocks
    with byte-identical committed token streams — the acceptance
    criterion."""
    res = _run("benchmarks.run", "--only", "prefix", "--fast",
               env_extra={"BENCH_OUT_DIR": str(tmp_path)})
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("prefix.")]
    names = {r.split(",")[0] for r in rows}
    assert names == {f"prefix.{wl}.{rate}.{mode}"
                     for wl in ("templated", "disjoint")
                     for rate in ("low", "high")
                     for mode in ("cache", "nocache")}

    data = json.load(open(tmp_path / "BENCH_prefix.json"))
    grid = data["grid"]
    for rate in ("low", "high"):
        on = grid[f"templated.{rate}.cache"]
        off = grid[f"templated.{rate}.nocache"]
        # identical committed token streams, every request finished
        assert on["tokens_sha"] == off["tokens_sha"]
        assert on["finished"] == off["finished"] > 0
        # the headline: strictly lower tail latency AND block consumption
        assert on["p99_ttft_s"] < off["p99_ttft_s"]
        assert on["blocks_allocated"] < off["blocks_allocated"]
        assert on["prefix_hit_rate"] > 0.5
    # the disjoint control: caching buys nothing and costs nothing
    for rate in ("low", "high"):
        on = grid[f"disjoint.{rate}.cache"]
        off = grid[f"disjoint.{rate}.nocache"]
        assert on["tokens_sha"] == off["tokens_sha"]
        assert on["prefix_hit_rate"] == 0.0


def test_control_grid_end_to_end(tmp_path):
    """`--only control` runs the control-plane grid, persists
    BENCH_control.json, and the acceptance criteria hold: affinity routing
    strictly beats kv on aggregate prefix hit-rate and p99 TTFT with
    identical per-request committed token counts (templated arm), and the elastic fleet
    strictly beats the static fleet on SLO attainment of admitted traffic
    at equal peak replica count (bursty arm)."""
    res = _run("benchmarks.run", "--only", "control", "--fast",
               env_extra={"BENCH_OUT_DIR": str(tmp_path)})
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("control.")]
    names = {r.split(",")[0] for r in rows}
    assert {f"control.templated.static.{r}"
            for r in ("rr", "kv", "slo", "affinity")} <= names
    assert {f"control.bursty.{f}.{r}" for f in ("static", "autoscale")
            for r in ("kv", "slo")} <= names

    data = json.load(open(tmp_path / "BENCH_control.json"))
    grid = data["grid"]
    # templated arm: cache specialisation under sticky routing
    aff = grid["templated.static.affinity"]
    kv = grid["templated.static.kv"]
    assert aff["tokens_sha"] == kv["tokens_sha"]
    assert aff["finished"] == kv["finished"] > 0
    assert aff["prefix_hit_rate"] > kv["prefix_hit_rate"]
    assert aff["p99_ttft_s"] < kv["p99_ttft_s"]
    # bursty arm: elastic vs static at equal peak replica count
    for router in ("kv", "slo"):
        el = grid[f"bursty.autoscale.{router}"]
        st = grid[f"bursty.static.{router}"]
        assert el["peak_replicas"] == st["peak_replicas"] == 2
        assert el["slo_attainment"] > st["slo_attainment"]
        assert el["shed"] > 0 and st["shed"] == 0
        assert el["replica_seconds"] < st["replica_seconds"]
        assert el["autoscale_adds"] >= 1


def test_sessions_grid_end_to_end(tmp_path):
    """`--only sessions` runs the host-offload session grid, persists
    BENCH_sessions.json, and the acceptance criteria hold: with offload on
    at a fixed device pool, warm-turn p50/p99 TTFT strictly below cold-turn
    TTFT, cross-turn prefix hit-rate > 0.8, host restores actually happen,
    and committed token streams are byte-identical vs offload-off."""
    res = _run("benchmarks.run", "--only", "sessions", "--fast",
               env_extra={"BENCH_OUT_DIR": str(tmp_path)})
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("sessions.")]
    assert {r.split(",")[0] for r in rows} == {"sessions.none",
                                              "sessions.offload"}

    data = json.load(open(tmp_path / "BENCH_sessions.json"))
    on, off = data["grid"]["offload"], data["grid"]["none"]
    # identical committed token streams, every request finished, same split
    assert on["tokens_sha"] == off["tokens_sha"]
    assert on["finished"] == off["finished"] > 0
    assert on["warm_turns"] == off["warm_turns"] > 0
    assert on["cold_turns"] == off["cold_turns"] > 0
    # the headline: restored history makes warm turns strictly cheaper
    assert on["p50_warm_ttft_s"] < on["p50_cold_ttft_s"]
    assert on["p99_warm_ttft_s"] < on["p99_cold_ttft_s"]
    assert on["cross_turn_hit_rate"] > 0.8
    assert on["cross_turn_hit_rate"] > off["cross_turn_hit_rate"]
    # the tier actually moved blocks both ways, at modelled PCIe cost
    assert on["host_restores"] > 0 and on["host_spills"] > 0
    assert on["host_restore_s"] > 0
    assert off["host_restores"] == off["host_spills"] == 0


def test_backend_grid_end_to_end(tmp_path):
    """`--only backend` runs REAL dense and paged backends, prints the CSV
    grid and persists BENCH_backend.json with the capacity comparison."""
    res = _run("benchmarks.run", "--only", "backend", "--fast",
               env_extra={"BENCH_OUT_DIR": str(tmp_path)})
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("backend.")]
    names = {r.split(",")[0] for r in rows}
    assert names == {f"backend.{m}.{op}" for m in ("dense", "paged")
                     for op in ("prefill", "decode", "verify")} | \
        {"backend.capacity"}
    data = json.load(open(tmp_path / "BENCH_backend.json"))
    assert set(data["grid"]) == {"dense", "paged"}
    for row in data["grid"].values():
        assert all(v > 0 for v in row.values())
    # the paged pool admits by actual context, not per-slot max_seq
    cap = data["capacity"]
    assert cap["paged_max_batch"] > cap["dense_max_batch"]


def test_disagg_grid_end_to_end(tmp_path):
    """`--only disagg` runs the colocated-vs-disaggregated grid, persists
    BENCH_disagg.json, and the acceptance criteria hold: at the high-rate
    cell the 2+2 disaggregated split strictly beats 4 colocated replicas on
    p99 TTFT and goodput at equal replica-seconds budget, committed token
    streams are byte-identical in both regimes, and the priced-out cell
    (prohibitive margin at low rate) declines its handoffs — the colocated
    fallback, never worse by construction."""
    res = _run("benchmarks.run", "--only", "disagg", "--fast",
               env_extra={"BENCH_OUT_DIR": str(tmp_path)})
    assert res.returncode == 0, res.stderr[-2000:]
    rows = [l for l in res.stdout.splitlines() if l.startswith("disagg.")]
    names = {r.split(",")[0] for r in rows}
    assert names == {"disagg.colocated.high", "disagg.disagg.high",
                     "disagg.colocated.low", "disagg.disagg.pricedout",
                     "disagg.acceptance"}

    data = json.load(open(tmp_path / "BENCH_disagg.json"))
    assert all(data["acceptance"].values()), data["acceptance"]
    g = data["grid"]
    col, dis = g["colocated.high"], g["disagg.high"]
    # the headline: a strict tail-latency and goodput win at equal capacity
    assert dis["p99_ttft_s"] < col["p99_ttft_s"]
    assert dis["goodput_tok_s"] > col["goodput_tok_s"]
    assert dis["tokens_sha"] == col["tokens_sha"]
    assert dis["finished"] == col["finished"] > 0
    assert dis["peak_replicas"] == col["peak_replicas"] == 4
    assert dis["handoffs"] > 0 and dis["handoff_transfer_s"] > 0
    # priced-out cell: the pricer keeps everything colocated, streams
    # identical to the true colocated run
    po = g["disagg.pricedout"]
    assert po["handoffs_declined"] > po["handoffs"]
    assert po["tokens_sha"] == g["colocated.low"]["tokens_sha"]


def test_make_tables_end_to_end():
    res = _run("benchmarks.make_tables")
    assert res.returncode == 0, res.stderr[-2000:]
    # with or without dry-run artifacts present it must report each file
    assert "dryrun_single_pod.json" in res.stdout
    # and the prefix grid section renders (table when the JSON exists,
    # a pointer when it doesn't)
    assert "BENCH_prefix" in res.stdout or "Prefix-sharing" in res.stdout
    # same for the control-plane grid
    assert "BENCH_control" in res.stdout or "control plane" in res.stdout
    # and the disaggregated-fleet grid
    assert "BENCH_disagg" in res.stdout or "Disaggregated" in res.stdout
    # and the overload-surge gate
    assert "BENCH_surge" in res.stdout or "Overload surge" in res.stdout
