"""Multi-replica cluster tier: steppable engine, routers, deterministic e2e.

The end-to-end test pins golden aggregate metrics for a seeded 2-replica
cluster run — any change to engine stepping order, router tie-breaking,
scheduler admission or the cost model shows up as a golden mismatch here.
"""
import numpy as np
import pytest

from repro import configs
from repro.serving.cluster import ServingCluster
from repro.serving.costmodel import RTX_4090
from repro.serving.engine import ServingEngine
from repro.serving.request import Request
from repro.serving.router import (JoinShortestQueue, KVHeadroomRouter,
                                  RoundRobinRouter, make_router)
from repro.serving.simulator import (SimConfig, build_sim_cluster,
                                     build_sim_engine)
from repro.serving.workload import poisson_requests, split_requests


def _cfg(**kw):
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, max_batch=256, seed=0, **kw)


# ---------------------------------------------------------------------------
# steppable engine surface
# ---------------------------------------------------------------------------


def test_step_loop_equals_run():
    """Driving the engine manually via submit/peek/step reproduces run()
    exactly (same clock, tokens, timeline)."""
    reqs = poisson_requests(20, 60, dataset="alpaca", seed=3)

    e1 = build_sim_engine(_cfg(), "nightjar")
    m1 = e1.run(list(reqs))

    e2 = build_sim_engine(_cfg(), "nightjar")
    for r in reqs:
        e2.submit(r)
    while True:
        nxt = e2.peek_next_event()
        if nxt is None:
            break
        assert nxt == e2.clock or not e2.scheduler.num_running
        rep = e2.step()
        assert rep is not None
        assert rep.t_end >= rep.t_start
    m2 = e2.finalize_metrics(0.0)

    assert m1.total_tokens == m2.total_tokens
    assert m1.elapsed == m2.elapsed
    assert len(m1.timeline) == len(m2.timeline)
    assert m1.latencies == m2.latencies


def test_engine_idle_fast_forward():
    """An idle engine fast-forwards its clock to the next arrival instead of
    spinning, and reports an 'idle' step."""
    eng = build_sim_engine(_cfg(), "ar")
    eng.submit(Request(0, 5.0, 8, 4))
    assert eng.peek_next_event() == 5.0
    rep = eng.step()
    assert rep.kind == "idle"
    assert eng.clock == 5.0
    rep = eng.step()
    assert rep.kind == "decode" and rep.admitted == 1
    while eng.step() is not None:
        pass
    assert eng.peek_next_event() is None
    assert eng.metrics.latencies  # the request completed


def test_step_with_now_advances_clock():
    eng = build_sim_engine(_cfg(), "ar")
    eng.submit(Request(0, 0.0, 8, 4))
    rep = eng.step(now=2.5)
    assert rep.t_start == 2.5
    assert eng.clock >= 2.5


# ---------------------------------------------------------------------------
# routers
# ---------------------------------------------------------------------------


def _engines(n):
    return [build_sim_engine(_cfg(), "ar") for _ in range(n)]


def test_round_robin_cycles():
    router = RoundRobinRouter()
    engines = _engines(3)
    picks = [router.route(Request(i, 0.0, 8, 4), engines) for i in range(7)]
    assert picks == [0, 1, 2, 0, 1, 2, 0]


def test_jsq_picks_least_loaded():
    engines = _engines(3)
    engines[0].submit(Request(0, 0.0, 8, 4))
    engines[0].submit(Request(1, 0.0, 8, 4))
    engines[2].submit(Request(2, 0.0, 8, 4))
    router = JoinShortestQueue()
    assert router.route(Request(3, 0.0, 8, 4), engines) == 1
    engines[1].submit(Request(3, 0.0, 8, 4))
    engines[1].submit(Request(4, 0.0, 8, 4))
    # tie between 2 (1 req) and nobody else lower -> index 2
    assert router.route(Request(5, 0.0, 8, 4), engines) == 2


def test_kv_headroom_prefers_free_blocks():
    engines = _engines(2)
    # consume blocks on engine 0 directly through its block manager
    engines[0].scheduler.bm.allocate(99, 64 * engines[0].scheduler.bm.block_size)
    router = KVHeadroomRouter()
    assert router.route(Request(0, 0.0, 8, 4), engines) == 1
    # equal headroom -> deterministic tie-break on index
    engines[1].scheduler.bm.allocate(98, 64 * engines[1].scheduler.bm.block_size)
    assert router.route(Request(1, 0.0, 8, 4), engines) == 0


def test_make_router_names():
    assert isinstance(make_router("rr"), RoundRobinRouter)
    assert isinstance(make_router("jsq"), JoinShortestQueue)
    assert isinstance(make_router("kv"), KVHeadroomRouter)
    with pytest.raises(KeyError):
        make_router("nope")


def test_split_requests_deterministic():
    reqs = poisson_requests(10, 30, dataset="alpaca", seed=0)
    a = split_requests(reqs, 3)
    b = split_requests(list(reversed(reqs)), 3)  # order-insensitive
    assert [[r.req_id for r in s] for s in a] == \
           [[r.req_id for r in s] for s in b]
    assert sorted(r.req_id for s in a for r in s) == \
           sorted(r.req_id for r in reqs)
    for shard in a:
        assert [r.arrival for r in shard] == sorted(r.arrival for r in shard)


# ---------------------------------------------------------------------------
# deterministic end-to-end cluster runs
# ---------------------------------------------------------------------------

# golden values for the seeded 2-replica runs below; regenerate by running
# the same configs and pasting the new numbers if an INTENTIONAL behaviour
# change shifts them.
GOLDEN_HIGH = dict(total_tokens=138274, throughput=4137.803158109096,
                   counts=[742, 758])
GOLDEN_LOW = dict(total_tokens=5492, throughput=380.0183517756499,
                  counts=[36, 28])


def test_cluster_e2e_low_rate_golden():
    """Seeded 2-replica cluster at low rate: golden metrics + speculation
    stays ON (memory-bound regime)."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="jsq")
    m = cl.run(poisson_requests(8, 64, dataset="alpaca", seed=1),
               record_timeline=True)
    assert m.total_tokens == GOLDEN_LOW["total_tokens"]
    assert m.throughput == pytest.approx(GOLDEN_LOW["throughput"], rel=1e-6)
    assert m.replica_counts() == GOLDEN_LOW["counts"]
    for rm in m.per_replica:
        gs = [r["gamma"] for r in rm.timeline]
        assert np.mean(gs) > 1.5          # speculation kept on
        assert max(r["B"] for r in rm.timeline) < 128  # never saturated


def test_cluster_e2e_high_rate_golden():
    """Seeded 2-replica cluster at saturating rate: golden metrics + every
    replica's planner independently drives gamma -> 0 in the saturated
    (high-batch) regime."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="jsq")
    m = cl.run(poisson_requests(300, 1500, dataset="alpaca", seed=1),
               record_timeline=True)
    assert m.total_tokens == GOLDEN_HIGH["total_tokens"]
    assert m.throughput == pytest.approx(GOLDEN_HIGH["throughput"], rel=1e-6)
    assert m.replica_counts() == GOLDEN_HIGH["counts"]
    for i, rm in enumerate(m.per_replica):
        # saturated-regime tail: mostly pure-AR steps
        hb = [r["gamma"] for r in rm.timeline if r["B"] > 128]
        assert len(hb) > 100
        tail = hb[-100:]
        assert np.mean([g == 0 for g in tail]) > 0.5, (i, tail)
        # and the planner's exploitation arm for the full batch is AR
        pol = cl.replicas[i].policy
        assert pol._eq4(pol.bucket(256), 0, 256) == 0


def test_cluster_interleaves_replicas_in_virtual_time():
    """Replica clocks advance together (no replica races ahead while
    another still has earlier work) — the shared-event-clock property."""
    engines = [build_sim_engine(_cfg(), "ar") for _ in range(2)]
    cl = ServingCluster(engines, make_router("rr"))
    max_skew = 0.0
    reqs = poisson_requests(40, 80, dataset="alpaca", seed=2)
    pending = sorted(reqs, key=lambda r: (r.arrival, r.req_id))
    pi = 0
    # drive the loop manually to observe interleaving
    while True:
        evs = [(t, i) for i, t in
               enumerate(e.peek_next_event() for e in engines)
               if t is not None]
        t_engine = min(evs)[0] if evs else float("inf")
        if pi < len(pending) and pending[pi].arrival <= t_engine:
            cl.submit(pending[pi])
            pi += 1
            continue
        if not evs:
            break
        _, idx = min(evs)
        engines[idx].step()
        both = [e.peek_next_event() for e in engines]
        if all(t is not None for t in both):
            max_skew = max(max_skew, abs(both[0] - both[1]))
    # skew is bounded by one decode step, not by whole-run divergence
    assert max_skew < 5.0
    assert all(not e.has_work() for e in engines)
