"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward + one train step on CPU with correct output
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry
from repro.training.optimizer import adamw_init, adamw_update, cosine_schedule

ARCHS = list(configs.ASSIGNED_ARCHS)


def _batch(cfg, rng, B=2, S=32):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        batch["enc_emb"] = jax.random.normal(rng, (B, S, cfg.d_model),
                                             jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_emb"] = jax.random.normal(
            rng, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    batch["labels"] = batch["tokens"]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.reduced(configs.get_config(arch))
    api = registry.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    B, S = 2, 32
    batch = _batch(cfg, rng, B, S)
    h = api.forward(params, batch)
    S_total = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert h.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_nothing_nan(arch):
    cfg = configs.reduced(configs.get_config(arch))
    api = registry.get_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = api.init(rng)
    opt = adamw_init(params)
    lr_fn = cosine_schedule(1e-3, 2, 100)
    batch = _batch(cfg, rng)

    def loss_fn(p):
        l, _ = api.loss(p, batch)
        return l

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    params2, opt, gnorm = adamw_update(grads, opt, params, lr_fn=lr_fn)
    assert bool(jnp.isfinite(gnorm))
    loss1 = loss_fn(params2)
    assert bool(jnp.isfinite(loss1))
    # one step on the same batch should not increase the loss materially
    assert float(loss1) < float(loss0) + 0.1


@pytest.mark.parametrize("arch", ARCHS)
def test_draft_config_same_vocab(arch):
    cfg = configs.get_config(arch)
    dcfg = configs.get_draft_config(arch)
    assert dcfg.vocab_size == cfg.vocab_size
    assert registry.param_count(configs.reduced(dcfg)) > 0


def test_assigned_configs_exact():
    """The full configs must match the assignment table exactly."""
    c = configs.get_config("qwen2-72b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    c = configs.get_config("gemma-7b")
    assert (c.num_layers, c.d_model, c.resolved_head_dim, c.d_ff,
            c.vocab_size) == (28, 3072, 256, 24576, 256000)
    c = configs.get_config("grok-1-314b")
    assert (c.moe_num_experts, c.moe_top_k, c.num_layers) == (8, 2, 64)
    c = configs.get_config("granite-moe-1b-a400m")
    assert (c.moe_num_experts, c.moe_top_k, c.d_ff) == (32, 8, 512)
    c = configs.get_config("mamba2-780m")
    assert (c.num_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = configs.get_config("zamba2-1.2b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = configs.get_config("whisper-medium")
    assert (c.enc_layers, c.dec_layers, c.d_model, c.vocab_size) == \
        (24, 24, 1024, 51865)
    c = configs.get_config("paligemma-3b")
    assert (c.num_layers, c.num_kv_heads, c.vocab_size) == (18, 1, 257216)
    c = configs.get_config("deepseek-7b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (30, 4096, 11008, 102400)
    c = configs.get_config("qwen3-14b")
    assert (c.num_layers, c.d_model, c.qk_norm, c.vocab_size) == \
        (40, 5120, True, 151936)


def test_shapes_assignment():
    from repro.configs.base import shapes_for
    total = 0
    for arch in ARCHS:
        cfg = configs.get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)
        if cfg.is_subquadratic:
            assert "long_500k" in names
        total += 4  # each arch is assigned 4 cells (skips documented)
    assert total == 40
