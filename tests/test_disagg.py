"""Disaggregated prefill/decode fleets with priced KV handoff (tentpole).

Pins the PR's acceptance criteria at test scale:
  * the ``HandoffPricer`` decision flips exactly where the predicted
    queue-delay saved crosses the modelled transfer time (parametrized —
    the documented inequality IS the decision);
  * disaggregated and colocated runs of the same seeded mixed workload
    commit byte-identical token streams (migration moves bytes, never
    changes computation), with decode replicas fed only through handoffs;
  * a prohibitive pricing margin routes every candidate colocated (zero
    handoffs, still byte-identical) — the never-worse fallback;
  * a failed adoption (destination pool full) falls back to local
    re-prefill through the ordinary waiting queue and the request still
    completes with the same tokens;
  * the decode pool has its own autoscaler scaling on KV pressure / TPOT,
    not TTFT attainment;
  * (slow tier) the real backend's export/import moves the physical KV
    bytes: a request prefilled on one ``RealBackend`` and decoded on
    another emits the same greedy stream as a colocated run.
"""
import hashlib

import pytest

from repro import configs
from repro.serving.cluster import DECODE, PREFILL, ServingCluster
from repro.serving.controlplane import (ControlPlane, DecodePoolAutoscaler,
                                        HandoffPricer, ReplicaSnapshot)
from repro.serving.costmodel import RTX_4090
from repro.serving.request import Request
from repro.serving.router import make_router
from repro.serving.simulator import (SimConfig, build_sim_cluster,
                                     build_sim_engine)
from repro.serving.workload import mixed_requests


def _cfg(**kw):
    kw.setdefault("max_batch", 256)
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, seed=0, **kw)


def _stream_sha(m):
    stream = sorted((r.req_id, r.tokens) for r in m.requests)
    return hashlib.sha256(repr(stream).encode()).hexdigest()


# ---------------------------------------------------------------------------
# handoff pricing: the decision flip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backlog_reqs,margin,expect", [
    # deep prefill queue on the source, empty destination: the predicted
    # queue delay escaped dwarfs the modelled transfer time -> migrate
    (40, 0.0, True),
    # both replicas idle: nothing saved, the transfer still costs -> stay
    (0, 0.0, False),
    # same deep queue, prohibitive margin: priced out -> stay
    (40, 1e4, False),
])
def test_handoff_pricing_decision_flip(backlog_reqs, margin, expect):
    """accept <=> forecast_ttft(src) - forecast_ttft(dst) >
    kv_transfer_seconds(prompt) + margin, on the same telemetry the
    routers see."""
    cp = ControlPlane()
    src = build_sim_engine(_cfg(chunk_tokens=128), "nightjar")
    dst = build_sim_engine(_cfg(chunk_tokens=128), "nightjar")
    src.replica_id, dst.replica_id = 0, 1
    for i in range(backlog_reqs):
        src.submit(Request(100 + i, 0.0, 1024, 8))
    pricer = HandoffPricer(cp, margin_s=margin)
    req = Request(0, 0.0, 512, 64)
    saved, cost = pricer.quote(src, dst, req, 0.0)
    # the sim backend models the transfer at interconnect bandwidth: a
    # 512-token prompt's KV bytes never move for free
    assert cost >= pricer.transfer_seconds(src, req.prompt_len) > 0.0
    assert (saved > cost) is expect
    assert pricer.decide(src, dst, req, 0.0) is expect
    assert (pricer.accepted, pricer.declined) == \
        ((1, 0) if expect else (0, 1))


def test_pricer_transfer_scales_with_prompt_and_margin():
    cp = ControlPlane()
    eng = build_sim_engine(_cfg(chunk_tokens=128), "nightjar")
    eng.replica_id = 0
    p = HandoffPricer(cp, margin_s=0.5)
    assert p.transfer_seconds(eng, 2048) > p.transfer_seconds(eng, 128) > 0
    _, cost = p.quote(eng, eng, Request(0, 0.0, 128, 8), 0.0)
    assert cost == pytest.approx(p.transfer_seconds(eng, 128) + 0.5)


# ---------------------------------------------------------------------------
# cluster construction and routing scope
# ---------------------------------------------------------------------------


def test_disaggregate_requires_chunked_prefill():
    with pytest.raises(ValueError):
        build_sim_cluster(_cfg(), 4, "nightjar",
                          disaggregate=dict(prefill=2, decode=2))


def test_cluster_roles_validation():
    engines = [build_sim_engine(_cfg(chunk_tokens=128), "nightjar")
               for _ in range(2)]
    with pytest.raises(ValueError):
        ServingCluster(engines, make_router("jsq"), roles=[PREFILL])
    with pytest.raises(ValueError):
        ServingCluster(engines, make_router("jsq"), roles=[DECODE, DECODE])
    with pytest.raises(ValueError):
        ServingCluster(engines, make_router("jsq"), roles=["gpu", PREFILL])


def test_arrivals_route_to_prefill_pool_only():
    cl = build_sim_cluster(_cfg(chunk_tokens=128), 4, "nightjar",
                           router="rr", disaggregate=dict(prefill=2,
                                                          decode=2))
    assert cl.roles == [PREFILL, PREFILL, DECODE, DECODE]
    for i in range(8):
        cl.submit(Request(i, 0.0, 16, 4))
    assert set(cl.assignments.values()) == {0, 1}


# ---------------------------------------------------------------------------
# golden e2e: byte-identity, handoff accounting, priced-out fallback
# ---------------------------------------------------------------------------


def _mixed_run(disaggregate):
    cfg = _cfg(chunk_tokens=128, max_batch=16)
    cl = build_sim_cluster(cfg, 4, "nightjar", router="jsq",
                           disaggregate=disaggregate)
    reqs = mixed_requests(20.0, 120, qa_frac=0.25, seed=1)
    return cl.run(reqs), cl


def test_disagg_streams_byte_identical_to_colocated():
    """Same seeded mixed stream, 4 colocated replicas vs a 2+2 split:
    identical committed tokens per request, decode replicas fed only via
    the handoff path, transfer time accounted."""
    m_col, _ = _mixed_run(None)
    m_dis, cl = _mixed_run(dict(prefill=2, decode=2))
    assert len(m_col.requests) == len(m_dis.requests) == 120
    assert _stream_sha(m_dis) == _stream_sha(m_col)
    assert len(m_dis.handoffs) > 0
    assert m_dis.handoff_transfer_s > 0
    # decode replicas receive work ONLY through handoffs
    handed = {h["req_id"] for h in m_dis.handoffs}
    for rid, idx in m_dis.assignments.items():
        if cl.roles[idx] == DECODE:
            assert rid in handed
    # every handoff left a prefill replica for a decode replica
    for h in m_dis.handoffs:
        assert cl.roles[h["src"]] == PREFILL
        assert cl.roles[h["dst"]] == DECODE
        assert h["transfer_s"] > 0
    s = m_dis.summary()
    assert s["disagg"]["handoffs"] == len(m_dis.handoffs)
    assert {r["role"] for r in s["per_replica"]} == {PREFILL, DECODE}


def test_prohibitive_margin_prices_out_every_handoff():
    """With the margin cranked past any achievable saving, the pricer
    declines every candidate: zero migrations, decode pool idle, and the
    committed streams still match the colocated run exactly."""
    m_col, _ = _mixed_run(None)
    m_dis, _ = _mixed_run(dict(prefill=2, decode=2, margin_s=1e6))
    assert len(m_dis.handoffs) == 0
    assert m_dis.handoffs_declined > 0
    assert m_dis.handoff_transfer_s == 0.0
    assert _stream_sha(m_dis) == _stream_sha(m_col)


def test_disagg_deterministic_across_runs():
    a, _ = _mixed_run(dict(prefill=2, decode=2))
    b, _ = _mixed_run(dict(prefill=2, decode=2))
    assert a.assignments == b.assignments
    assert a.handoffs == b.handoffs
    assert _stream_sha(a) == _stream_sha(b)


# ---------------------------------------------------------------------------
# adoption fallback: a full destination pool is never worse
# ---------------------------------------------------------------------------


def test_adoption_out_of_blocks_falls_back_to_local_prefill():
    cfg = _cfg(chunk_tokens=128, num_blocks=64)
    src = build_sim_engine(cfg, "nightjar")
    dst = build_sim_engine(cfg, "nightjar")
    src.replica_id, dst.replica_id = 0, 1
    req = Request(0, 0.0, 100, 8)
    src.submit(req)
    while not any(s.prompt_remaining == 0 and s.generated == 0
                  for s in src.scheduler.running):
        src.step()
    seq = next(s for s in src.scheduler.running if s.prompt_remaining == 0)
    payload = src.extract_for_handoff(seq)
    assert payload["prompt_len"] == 100
    assert seq not in src.scheduler.running      # source released its slot

    # destination pool too occupied to host the prompt: adoption must fall
    # back to the local waiting queue, never drop the request
    dst.scheduler.bm.allocate(999, 60 * cfg.block_size)
    dst.accept_handoff(req, t_ready=0.0, payload=payload)
    assert dst.load == 1
    dst.step()
    assert dst.handoffs_refused == 1
    assert dst.handoffs_in == 0
    assert dst.scheduler.num_waiting == 1
    dst.scheduler.bm.release(999)
    while dst.has_work():
        dst.step()
    assert [r.req_id for r in dst.metrics.requests] == [0]

    # the fallback re-prefilled locally and committed the same stream a
    # colocated engine would have
    ref = build_sim_engine(cfg, "nightjar")
    ref.run([Request(0, 0.0, 100, 8)])
    assert dst.metrics.requests[0].tokens == ref.metrics.requests[0].tokens


def test_successful_adoption_is_decode_ready():
    cfg = _cfg(chunk_tokens=128)
    src = build_sim_engine(cfg, "nightjar")
    dst = build_sim_engine(cfg, "nightjar")
    src.replica_id, dst.replica_id = 0, 1
    req = Request(0, 0.0, 100, 8)
    src.submit(req)
    while not any(s.prompt_remaining == 0 and s.generated == 0
                  for s in src.scheduler.running):
        src.step()
    seq = next(s for s in src.scheduler.running if s.prompt_remaining == 0)
    payload = src.extract_for_handoff(seq)
    dst.accept_handoff(req, t_ready=2.5, payload=payload)
    dst.step()                                   # idle until the KV lands
    assert dst.clock >= 2.5
    dst.step()
    assert dst.handoffs_in == 1 and dst.handoffs_refused == 0
    assert dst.decode_count == 1                 # no re-prefill happened
    while dst.has_work():
        dst.step()
    assert [r.req_id for r in dst.metrics.requests] == [0]


# ---------------------------------------------------------------------------
# decode-pool autoscaler
# ---------------------------------------------------------------------------


def _snap(i, alloc, total=100, decode=0, tpot=0.01):
    return ReplicaSnapshot(replica_id=i, t=0.0, clock=0.0, load=0,
                           decode_count=decode, prefill_backlog_tokens=0,
                           kv_allocatable=alloc, kv_total=total,
                           ewma_ttft=0.1, ewma_tpot=tpot,
                           predicted_ttft=0.1)


def test_decode_pool_autoscaler_pressure_calm_cooldown():
    sc = DecodePoolAutoscaler(min_replicas=1, max_replicas=3,
                              kv_pressure_frac=0.15, calm_kv_frac=0.4,
                              drain_decode_per_replica=8, cooldown_s=2.0)
    # KV pressure on any one replica -> up
    assert sc.decide(0.0, [_snap(0, 10), _snap(1, 80)], n_alive=2) == "up"
    # cooldown gates the follow-up
    assert sc.decide(1.0, [_snap(0, 10), _snap(1, 80)], n_alive=3) is None
    # at max alive (active + draining) the capacity cap refuses more
    assert sc.decide(10.0, [_snap(0, 10)], n_alive=3) is None
    # calm pool whose decode work fits on one fewer replica -> down
    assert sc.decide(20.0, [_snap(0, 90, decode=2), _snap(1, 95, decode=2)],
                     n_alive=2) == "down"
    # at min_replicas it never drains further
    assert sc.decide(30.0, [_snap(0, 90)], n_alive=1) is None
    with pytest.raises(ValueError):
        DecodePoolAutoscaler(min_replicas=2, max_replicas=1)
    with pytest.raises(ValueError):
        DecodePoolAutoscaler(kv_pressure_frac=0.5, calm_kv_frac=0.2)


def test_decode_pool_autoscaler_tpot_pressure():
    sc = DecodePoolAutoscaler(tpot_slo_s=0.05, max_replicas=2,
                              cooldown_s=0.0)
    # headroom is fine but the pool's worst TPOT blew the target
    assert sc.decide(0.0, [_snap(0, 90, tpot=0.2)], n_alive=1) == "up"
    assert sc.decide(1.0, [], n_alive=1) is None      # empty pool: no-op


def test_decode_autoscaler_wired_into_cluster():
    """A disaggregated cluster under sustained load grows its decode pool
    through the wired-in DecodePoolAutoscaler (autoscale events carry the
    decode role)."""
    cfg = _cfg(chunk_tokens=128, max_batch=16, num_blocks=256)
    cl = build_sim_cluster(
        cfg, 3, "nightjar", router="jsq",
        disaggregate=dict(prefill=2, decode=1,
                          decode_autoscale=dict(min_replicas=1,
                                                max_replicas=2,
                                                kv_pressure_frac=0.3,
                                                cooldown_s=0.5)))
    reqs = mixed_requests(20.0, 120, qa_frac=0.25, seed=1)
    m = cl.run(reqs)
    assert len(m.requests) == 120
    adds = [e for e in m.autoscale_events
            if e["kind"] == "add" and e.get("role") == DECODE]
    assert adds, "decode pool never scaled under KV pressure"
    assert m.replica_roles.count(DECODE) >= 2


# ---------------------------------------------------------------------------
# slow tier: real-backend KV export/import round trip
# ---------------------------------------------------------------------------


def _real_engine(blocks=64, chunk=8):
    # chunked prefill, like the cluster requires for disaggregation: the
    # monolithic path commits the first token inside the prefill step, so
    # a prefill-complete / zero-generated migration candidate only exists
    # on the chunked path
    from repro.core.bandits import make_policy
    from repro.models import registry
    from repro.serving.engine import ServingEngine
    from repro.serving.kv_cache import BlockManager
    from repro.serving.real_backend import RealBackend
    from repro.serving.scheduler import ContinuousBatchingScheduler

    def api(draft=False):
        get = configs.get_draft_config if draft else configs.get_config
        return registry.get_model(
            configs.reduced(get("deepseek-7b")).replace(dtype="float32"))

    target, draft = api(), api(draft=True)
    bm = BlockManager(blocks, 8)
    be = RealBackend(target, draft, max_batch=4, max_seq=96, seed=0,
                     block_manager=bm)
    sched = ContinuousBatchingScheduler(bm, max_batch=4, chunk_tokens=chunk,
                                        watermark_frac=0.0)
    eng = ServingEngine(be, sched, make_policy("ar", 3, seed=0), None,
                        gamma_max=3)
    return eng, be, target.cfg.vocab_size


@pytest.mark.slow
@pytest.mark.real_backend
def test_real_tier_handoff_streams_identical():
    """Prefill on one RealBackend, migrate the physical KV blocks
    (export_handoff -> spill_blocks gather, import_handoff ->
    restore_blocks scatter), decode on another: greedy streams match a
    colocated run byte-for-byte."""
    from repro.serving.workload import tiny_requests

    out = 8
    base_eng, base_be, vocab = _real_engine()
    reqs = tiny_requests(3, rate_qps=1e6, prompt_len=12, output_len=out,
                         vocab=vocab, seed=5)
    base_eng.run(list(reqs), max_steps=3000)
    base = {r.req_id: base_be.output_tokens(r.req_id)[:out + 1]
            for r in reqs}

    src, _, _ = _real_engine()
    dst, dst_be, _ = _real_engine()
    src.replica_id, dst.replica_id = 0, 1
    for r in reqs:
        src.submit(r)
    for _ in range(3000):
        if not src.has_work():
            break
        src.step()
        for seq in list(src.scheduler.running):
            if seq.prompt_remaining == 0 and not seq.done \
                    and seq.generated == 0:
                payload = src.extract_for_handoff(seq)
                assert payload["kv"]["n_blocks"] > 0   # bytes travelled
                dst.accept_handoff(seq.request, t_ready=dst.clock,
                                   payload=payload)
    for _ in range(3000):
        if not dst.has_work():
            break
        dst.step()
    assert dst.handoffs_in == 3 and dst.handoffs_refused == 0
    got = {r.req_id: dst_be.output_tokens(r.req_id)[:out + 1] for r in reqs}
    assert got == base
