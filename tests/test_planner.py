"""Nightjar planner (Algorithm 1) invariants + regret behaviour."""
import math
import random

import numpy as np
import pytest

from repro.core.bandits import AdaBinGreedy, DSD, make_policy
from repro.core.cswitch import CSwitchTable
from repro.core.planner import NightjarPlanner


def run_planner(planner, latency_fn, T, B=8, seed=0):
    rng = np.random.default_rng(seed)
    picks = []
    for t in range(T):
        g = planner.select(B)
        lat = latency_fn(g) + rng.normal(0, 0.001)
        planner.observe(B, g, max(lat, 1e-6))
        picks.append(g)
    return picks


def test_bin_locking():
    """The arm may only change at bin boundaries."""
    pl = NightjarPlanner(5, seed=1)
    B = 4
    changes_inside_bin = 0
    prev = None
    for t in range(2000):
        st = pl.states.get(pl.bucket(B))
        at_bin_start = st is None or st.tau == 1
        g = pl.select(B)
        if prev is not None and g != prev and not at_bin_start:
            changes_inside_bin += 1
        prev = g
        pl.observe(B, g, 0.01)
    assert changes_inside_bin == 0


def test_converges_to_best_arm():
    """Stationary latencies: exploitation converges to the argmin arm."""
    pl = NightjarPlanner(5, seed=0)
    lat = {0: 0.030, 1: 0.022, 2: 0.017, 3: 0.015, 4: 0.019, 5: 0.024}
    picks = run_planner(pl, lambda g: lat[g], 6000)
    tail = picks[-1500:]
    frac_best = sum(1 for g in tail if g == 3) / len(tail)
    assert frac_best > 0.5, frac_best


def test_switch_cost_discourages_reenable():
    """With a huge C_switch, the planner avoids 0 -> gamma>0 transitions that
    a switch-blind planner would take."""
    table = CSwitchTable.constant(10.0)  # enormous
    pl = NightjarPlanner(3, table, seed=0)
    # gamma=0 slightly worse than gamma=2 — but switching costs 10s
    lat = {0: 0.020, 1: 0.019, 2: 0.018, 3: 0.019}
    run_planner(pl, lambda g: lat[g], 800)
    # eq4 from prev_gamma=0 must keep 0 (10/g penalty dwarfs 2ms gain)
    pl.prev_gamma = 0
    assert pl._eq4(pl.bucket(8), 128, 8) == 0
    # switch-blind ablation prefers 2
    ab = AdaBinGreedy(3, seed=0)
    run_planner(ab, lambda g: lat[g], 800)
    assert ab._eq4(ab.bucket(8), 128, 8) == 2


def test_gamma_locked_for_whole_bin():
    """Within one bin (tau = 1 .. sqrt(H)), select() returns the same arm at
    every round — direct unit check of the bin-locking mechanism."""
    pl = NightjarPlanner(5, seed=7)
    B = 8
    bin_arms = []
    current = []
    for _ in range(1500):
        st = pl.states.get(pl.bucket(B))
        if st is not None and st.tau == 1 and current:
            bin_arms.append(current)
            current = []
        current.append(pl.select(B))
        pl.observe(B, current[-1], 0.02)
    assert len(bin_arms) > 10
    for arms in bin_arms:
        assert len(set(arms)) == 1, arms


def test_cswitch_charged_only_on_reenable():
    """The C_switch penalty enters the loss ONLY on 0 -> gamma>0
    transitions; staying on (prev_gamma > 0) or staying off is free."""
    C = 2.0
    table = CSwitchTable.constant(C)
    lat = 0.010

    # prev_gamma > 0: observing gamma=2 records the raw latency
    pl = NightjarPlanner(3, table, seed=0)
    pl.prev_gamma = 2
    pl.observe(8, 2, lat)
    assert pl.stats[(pl.bucket(8), 2)].mean == pytest.approx(lat)

    # prev_gamma == 0 and gamma > 0: loss includes C/gamma
    pl = NightjarPlanner(3, table, seed=0)
    pl.prev_gamma = 0
    pl.observe(8, 2, lat, delta_max=64)
    assert pl.stats[(pl.bucket(8), 2)].mean == pytest.approx(lat + C / 2)

    # prev_gamma == 0 and gamma == 0: staying off is free
    pl = NightjarPlanner(3, table, seed=0)
    pl.prev_gamma = 0
    pl.observe(8, 0, lat)
    assert pl.stats[(pl.bucket(8), 0)].mean == pytest.approx(lat)

    # the same asymmetry in the exploitation rule (Eq. 4)
    pl = NightjarPlanner(3, table, seed=0)
    for g in range(4):
        s = pl._arm_stats(pl.bucket(8), g)
        s.count, s.total = 1, lat * (1 + 0.1 * g)  # gamma=0 slightly best
    pl.prev_gamma = 3
    assert pl._eq4(pl.bucket(8), 64, 8) == 0   # no penalty applied
    pl.prev_gamma = 0
    assert pl._eq4(pl.bucket(8), 64, 8) == 0   # penalty keeps it at 0


def test_per_batch_size_state_isolation():
    """Observations at one batch bucket never touch another bucket's arm
    statistics or hierarchy state."""
    pl = NightjarPlanner(3, seed=0)
    g = pl.select(2)
    pl.observe(2, g, 0.01)
    assert all(b == pl.bucket(2) for (b, _) in pl.stats)
    assert list(pl.states) == [pl.bucket(2)]
    snap2 = vars(pl.states[pl.bucket(2)]).copy()
    g64 = pl.select(64)
    pl.observe(64, g64, 0.05)
    # bucket-2 stats and hierarchy state unchanged by the bucket-64 step
    assert sum(s.count for (b, _), s in pl.stats.items()
               if b == pl.bucket(2)) == 1
    assert vars(pl.states[pl.bucket(2)]) == snap2
    assert pl.bucket(64) in pl.states


def test_per_batch_size_contexts_independent():
    pl = NightjarPlanner(3, seed=0)
    # B=2: speculation great; B=64: speculation terrible
    for t in range(3000):
        for B, lat in ((2, {0: 0.03, 1: 0.02, 2: 0.012, 3: 0.010}),
                       (64, {0: 0.010, 1: 0.02, 2: 0.03, 3: 0.04})):
            g = pl.select(B)
            pl.observe(B, g, lat[g])
    assert pl._eq4(pl.bucket(2), 0, 2) == 3
    # prev_gamma currently 3 => no switch penalty for B=64 exploitation
    assert pl._eq4(pl.bucket(64), 0, 64) == 0


def test_switch_count_sublinear():
    """Bin locking bounds switches to O(sqrt(T))."""
    pl = NightjarPlanner(4, seed=3)
    rng = np.random.default_rng(0)
    T = 20_000
    for t in range(T):
        g = pl.select(8)
        pl.observe(8, g, 0.02 + 0.001 * abs(g - 2) + rng.normal(0, 1e-4))
    # generous constant: c*sqrt(T)*log(T)
    assert pl.switch_count < 10 * math.sqrt(T) * math.log(T), pl.switch_count


def test_regret_sublinear():
    """Cumulative regret grows sublinearly (R(2T)/R(T) << 2)."""
    def regret_at(T):
        pl = NightjarPlanner(3, seed=5)
        lat = {0: 0.03, 1: 0.022, 2: 0.015, 3: 0.02}
        best = min(lat.values())
        rng = np.random.default_rng(7)
        R = 0.0
        for t in range(T):
            g = pl.select(4)
            obs = lat[g] + rng.normal(0, 0.002)
            pl.observe(4, g, max(obs, 1e-6))
            R += lat[g] - best
        return R

    r1, r2 = regret_at(4000), regret_at(16_000)
    assert r2 / r1 < 3.0, (r1, r2)  # 4x steps -> ~2x regret for sqrt(T)


def test_planner_state_roundtrip():
    """Fault tolerance: serialised planner resumes with identical behaviour."""
    import json
    pl = NightjarPlanner(4, seed=9)
    run_planner(pl, lambda g: 0.02 + 0.001 * g, 500)
    blob = json.dumps(pl.state_dict())

    pl2 = NightjarPlanner(4, seed=9)
    pl2.load_state_dict(json.loads(blob))
    seq1 = [pl.select(8) for _ in range(50)]
    seq2 = [pl2.select(8) for _ in range(50)]
    assert seq1 == seq2


def test_dsd_deadlock_reproduced():
    """DSD stops updating acceptance once it selects gamma=0 — the paper's
    motivating vulnerability (§9.1)."""
    dsd = DSD(3, ema=0.5)
    # phase 1: drafts are terrible (0 accepted) and spec steps are slow
    reached_zero = False
    for _ in range(300):
        g = dsd.select(8)
        dsd.observe(8, g, 0.05 if g else 0.02,
                    n_accepted=0 if g else None)
        if g == 0:
            reached_zero = True
            break
    assert reached_zero, "DSD should disable speculation under bad drafts"
    a_before = dsd.alpha
    # phase 2: the ENVIRONMENT improves (drafts would now be perfect), but
    # DSD can never observe it — gamma=0 collects no acceptance data
    for _ in range(500):
        g = dsd.select(8)
        assert g == 0  # stuck: the deadlock
        dsd.observe(8, g, 0.02, n_accepted=None)
    assert dsd.alpha == a_before  # never recovers


def test_exploration_probability_decays():
    pl = NightjarPlanner(3, seed=11)
    run_planner(pl, lambda g: 0.02, 5000)
    st = pl.states[pl.bucket(8)]
    assert st.j >= 3  # blocks grew
    assert st.H == 2.0 ** (st.j - 1)
