"""Speculative decoding + serving engine integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.bandits import make_policy
from repro.core.spec_decode import make_ar_step, make_spec_step
from repro.models import registry
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import BlockManager
from repro.serving.memory_manager import ElasticMemoryManager
from repro.serving.real_backend import RealBackend
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.simulator import SimConfig, build_sim_engine
from repro.serving.workload import poisson_requests, tiny_requests


def _apis(arch):
    cfg = configs.reduced(configs.get_config(arch)).replace(dtype="float32")
    dcfg = configs.reduced(configs.get_draft_config(arch)).replace(
        dtype="float32")
    return registry.get_model(cfg), registry.get_model(dcfg)


@pytest.mark.slow
@pytest.mark.real_backend
@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-780m"])
def test_spec_step_greedy_equals_ar(arch):
    """Greedy speculative decoding must emit exactly the AR greedy sequence,
    for attention AND ssm targets (state-checkpoint rollback)."""
    target, draft = _apis(arch)
    rng = jax.random.PRNGKey(0)
    tparams = target.init(rng)
    dparams = draft.init(jax.random.PRNGKey(1))
    B, S, steps, gamma = 2, 8, 6, 3
    toks = jax.random.randint(rng, (B, S), 0, target.cfg.vocab_size)
    max_len = S + steps * (gamma + 1) + 4

    # AR reference
    _, tc = target.prefill(tparams, {"tokens": toks}, max_len)
    logits0, _ = target.prefill(tparams, {"tokens": toks}, max_len)
    last = jnp.argmax(logits0[:, 0], -1)
    ar = make_ar_step(target)
    ar_out = [last]
    tc_ar = tc
    for _ in range(steps * (gamma + 1)):
        last, tc_ar = ar(rng, tparams, tc_ar, last)
        ar_out.append(last)
    ar_seq = np.stack([np.asarray(t) for t in ar_out], 1)

    # speculative
    spec = make_spec_step(target, draft)
    _, tc2 = target.prefill(tparams, {"tokens": toks}, max_len)
    _, dc2 = draft.prefill(dparams, {"tokens": toks}, max_len)
    last2 = jnp.argmax(logits0[:, 0], -1)
    out = [np.asarray(last2)[:, None]]
    total = np.zeros(B, int)
    while total.min() < steps * (gamma + 1) - (gamma + 1):
        res = spec(rng, tparams, dparams, tc2, dc2, last2, gamma=gamma)
        tc2, dc2, last2 = res.tcache, res.dcache, res.last_token
        toks_np = np.asarray(res.tokens)
        out.append(np.where(toks_np >= 0, toks_np, -1))
        total += np.asarray(res.n_committed)

    # flatten committed streams and compare prefixes
    for b in range(B):
        spec_stream = [int(out[0][b, 0])]
        for chunk in out[1:]:
            spec_stream.extend(int(t) for t in chunk[b] if t >= 0)
        n = min(len(spec_stream), ar_seq.shape[1])
        assert spec_stream[:n] == list(ar_seq[b, :n]), f"seq {b} diverged"


@pytest.mark.slow
@pytest.mark.real_backend
def test_spec_caches_stay_synced():
    target, draft = _apis("deepseek-7b")
    tparams = target.init(jax.random.PRNGKey(0))
    dparams = draft.init(jax.random.PRNGKey(1))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              target.cfg.vocab_size)
    lg, tc = target.prefill(tparams, {"tokens": toks}, 64)
    _, dc = draft.prefill(dparams, {"tokens": toks}, 64)
    last = jnp.argmax(lg[:, 0], -1)
    spec = make_spec_step(target, draft)
    for i in range(4):
        res = spec(jax.random.PRNGKey(i), tparams, dparams, tc, dc, last,
                   gamma=2)
        tc, dc, last = res.tcache, res.dcache, res.last_token
        np.testing.assert_array_equal(np.asarray(tc["length"]),
                                      np.asarray(dc["length"]))


@pytest.mark.slow
@pytest.mark.real_backend
def test_engine_lossless_across_policies():
    """End-to-end: greedy token streams identical under AR / fixed-gamma /
    Nightjar scheduling."""
    target, draft = _apis("granite-moe-1b-a400m")
    streams = {}
    for pol in ["ar", "fixed-2", "nightjar"]:
        be = RealBackend(target, draft, max_batch=4, max_seq=96, seed=0)
        bm = BlockManager(256, block_size=8)
        sched = ContinuousBatchingScheduler(bm, max_batch=4)
        eng = ServingEngine(be, sched, make_policy(pol, 3, seed=0), None,
                            gamma_max=3)
        reqs = tiny_requests(4, rate_qps=1e6, prompt_len=10, output_len=8,
                             vocab=target.cfg.vocab_size, seed=5)
        eng.run(reqs, max_steps=500)
        streams[pol] = {r.req_id: be.output_tokens(r.req_id)[:9]
                        for r in reqs}
    assert streams["ar"] == streams["fixed-2"] == streams["nightjar"]


def test_sim_crossover_exists():
    """Cost model reproduces Figure 1/2: SD beats AR at B=1, loses at B=256."""
    from repro.serving.costmodel import RooflineCostModel, RTX_4090
    t = configs.get_config("paper-7b")
    d = configs.get_draft_config("paper-7b")
    cm = RooflineCostModel(RTX_4090)
    exp_tokens = 2.5  # E[committed] per seq at alpha~0.65, gamma=3
    lo = (exp_tokens / cm.spec_step_latency(t, d, 1, 512, 3)) / \
         (1.0 / cm.ar_step_latency(t, 1, 512))
    hi = (exp_tokens / cm.spec_step_latency(t, d, 256, 512, 3)) / \
         (1.0 / cm.ar_step_latency(t, 256, 512))
    assert lo > 1.2, lo     # memory-bound regime: SD wins
    assert hi < 1.0, hi     # compute-bound regime: SD loses


def test_sim_nightjar_tracks_best_arm():
    """Nightjar ends within 10% of the better of (AR, SD) at both load
    extremes — the paper's core claim, on the analytical tier."""
    from repro.serving.costmodel import RTX_4090
    t = configs.get_config("paper-7b")
    d = configs.get_draft_config("paper-7b")
    res = {}
    for rate in (4, 30):
        row = {}
        for pol in ("ar", "sd", "nightjar"):
            eng = build_sim_engine(
                SimConfig(target=t, draft=d, hw=RTX_4090, max_batch=256,
                          seed=0), pol)
            reqs = poisson_requests(rate, min(int(rate * 15), 300),
                                    dataset="sharegpt", seed=1)
            row[pol] = eng.run(reqs, max_steps=300_000).throughput
        res[rate] = row
    for rate, row in res.items():
        best = max(row["ar"], row["sd"])
        assert row["nightjar"] > 0.85 * best, (rate, row)


def test_memory_manager_offload_reload_cycle():
    bm = BlockManager(100, block_size=4)
    events = []
    mm = ElasticMemoryManager(
        bm, draft_blocks=10, tau_low_frac=0.1, t_persist=2,
        offload_latency=0.01, reload_latency=0.01,
        offload_fn=lambda: events.append("off"),
        reload_fn=lambda: events.append("re"))
    bm.allocate(1, 370)  # 93 blocks -> free 7 < tau_low 10
    now = 0.0
    for i in range(3):
        mm.step(now, spec_disabled=True, waiting=5)
        now += 0.1
    assert not mm.draft_resident and mm.expanded
    assert bm.total_blocks == 110
    assert events == ["off"]
    # drain: release the sequence, queue empty -> contraction + reload
    bm.release(1)
    mm.step(now, spec_disabled=True, waiting=0)
    assert mm.draft_resident and not mm.expanded
    assert bm.total_blocks == 100
    assert events == ["off", "re"]


def test_memory_manager_hysteresis():
    """No reload while the waiting queue is non-empty (thrash prevention)."""
    bm = BlockManager(100, block_size=4)
    mm = ElasticMemoryManager(bm, draft_blocks=10, tau_low_frac=0.1,
                              t_persist=1)
    bm.allocate(1, 380)
    mm.step(0.0, spec_disabled=True, waiting=3)
    assert not mm.draft_resident
    bm.release(1)
    mm.step(1.0, spec_disabled=True, waiting=2)   # queue not empty
    assert not mm.draft_resident
    mm.step(2.0, spec_disabled=True, waiting=0)
    assert mm.draft_resident
