"""Paged-KV runtime for the real backend.

Tier-1 (fast, CPU): model-level paged-vs-dense logit equivalence (decode,
speculative-verify extension, ragged chunked prefill), trash-block write
isolation, BlockManager capacity reservation, pool sizing from the roofline
HBM budget, and the adaptive chunk-budget knee.

Slow tier (real execution e2e): dense-vs-paged engines emit identical
greedy token streams, chunked real prefill equals monolithic prefill, and
preempt-and-recompute under severe memory pressure stays lossless.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.bandits import make_policy
from repro.models import registry
from repro.serving.costmodel import RTX_4090, RooflineCostModel
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import BlockManager, OutOfBlocks
from repro.serving.paged_runtime import PagedKVRuntime, num_blocks_for
from repro.serving.real_backend import (DenseSlotBackend, RealBackend,
                                        make_real_backend)
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.workload import tiny_requests


def _api(arch, draft=False):
    get = configs.get_draft_config if draft else configs.get_config
    return registry.get_model(
        configs.reduced(get(arch)).replace(dtype="float32"))


# ---------------------------------------------------------------------------
# tier-1: model-level equivalence with the dense cache path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["deepseek-7b", "granite-moe-1b-a400m"])
def test_paged_decode_matches_dense(arch):
    """Paged prefill (start=0), T=1 decode and T=3 verify extensions all
    produce the same logits as the dense slot-cache path."""
    api = _api(arch)
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S + 4), 0,
                             cfg.vocab_size)

    lg_p, cache = api.prefill(params, {"tokens": tok[:, :S]}, S + 8)
    pages = api.init_paged_cache(16, 4)
    tables = jnp.asarray([[0, 1, 2, 3, 4], [5, 6, 7, 8, 9]], jnp.int32)
    lg_paged, pages = api.decode_step_paged(params, pages, tok[:, :S],
                                            tables, jnp.zeros((B,), jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_paged[:, -1]),
                               np.asarray(lg_p[:, 0]), atol=1e-4)

    lg1, cache = api.decode_step(params, cache, tok[:, S:S + 1])
    lg1p, pages = api.decode_step_paged(params, pages, tok[:, S:S + 1],
                                        tables, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg1p), np.asarray(lg1), atol=1e-4)

    lg3, cache = api.decode_step(params, cache, tok[:, S + 1:S + 4])
    lg3p, pages = api.decode_step_paged(params, pages, tok[:, S + 1:S + 4],
                                        tables,
                                        jnp.full((B,), S + 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg3p), np.asarray(lg3), atol=1e-4)


def test_paged_chunked_prefill_matches_monolithic():
    """Ragged chunked appends (per-row valid counts) reach the same
    last-position logits as one monolithic paged prefill."""
    api = _api("deepseek-7b")
    params = api.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                             api.cfg.vocab_size)
    tables = jnp.asarray([[0, 1, 2, 3], [5, 6, 7, 8]], jnp.int32)

    mono_pages = api.init_paged_cache(16, 4)
    lg_mono, _ = api.decode_step_paged(params, mono_pages, tok, tables,
                                       jnp.zeros((B,), jnp.int32))

    pages = api.init_paged_cache(16, 4)
    # seq0 chunks 5+7, seq1 chunks 7+5 (padded rows exercise the trash path)
    c1 = jnp.stack([jnp.pad(tok[0, :5], (0, 2)), tok[1, :7]])
    _, pages = api.decode_step_paged(params, pages, c1, tables,
                                     jnp.zeros((B,), jnp.int32),
                                     jnp.asarray([5, 7]))
    c2 = jnp.stack([tok[0, 5:12], jnp.pad(tok[1, 7:12], (0, 2))])
    lg, pages = api.decode_step_paged(params, pages, c2, tables,
                                      jnp.asarray([5, 7]),
                                      jnp.asarray([7, 5]))
    last = jnp.stack([lg[0, 6], lg[1, 4]])
    np.testing.assert_allclose(np.asarray(last), np.asarray(lg_mono[:, -1]),
                               atol=1e-4)


def test_paged_shared_prefix_matches_dense():
    """Two sequences SHARING physical prefix blocks (written once) produce
    the same logits as dense full-prompt prefill — the model-level
    correctness of prefix-cache admission."""
    api = _api("deepseek-7b")
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    S, P = 8, 12                               # 8 shared + 4 private tokens
    key = jax.random.PRNGKey(1)
    prefix = jax.random.randint(key, (S,), 0, cfg.vocab_size)
    sfx = jax.random.randint(jax.random.PRNGKey(2), (2, P - S), 0,
                             cfg.vocab_size)
    prompts = jnp.stack([jnp.concatenate([prefix, sfx[0]]),
                         jnp.concatenate([prefix, sfx[1]])])

    # dense baseline: both prompts prefilled independently
    lg_dense, _ = api.prefill(params, {"tokens": prompts}, P + 4)

    pages = api.init_paged_cache(16, 4)
    # seq0 writes the prefix (blocks 0,1) + its private block 2
    t0 = jnp.asarray([[0, 1, 2]], jnp.int32)
    lg0, pages = api.decode_step_paged(params, pages, prompts[:1], t0,
                                       jnp.zeros((1,), jnp.int32))
    # seq1 SHARES blocks 0,1 and only extends from the match boundary
    t1 = jnp.asarray([[0, 1, 3]], jnp.int32)
    lg1, pages = api.decode_step_paged(params, pages, sfx[1:2], t1,
                                       jnp.full((1,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg0[0, -1]),
                               np.asarray(lg_dense[0, 0]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(lg1[0, -1]),
                               np.asarray(lg_dense[1, 0]), atol=1e-4)


def test_cow_fork_copy_preserves_logits():
    """Forking a shared block (apply_copies through the block-migration
    kernel path) leaves the forked sequence's logits identical to an
    unshared run — CoW is invisible to the model."""
    api = _api("deepseek-7b")
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(0))
    P = 8                                      # exactly 2 full blocks
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, P), 0,
                             cfg.vocab_size)

    from repro.serving.kv_cache import BlockManager
    from repro.serving.paged_runtime import PagedKVRuntime
    bm = BlockManager(8, 4, prefix_caching=True)
    rt = PagedKVRuntime(api, bm)
    bm.allocate(1, P)
    tbl1 = jnp.asarray([bm.tables[1]], jnp.int32)
    _, rt.pages = api.decode_step_paged(params, rt.pages, tok, tbl1,
                                        jnp.zeros((1,), jnp.int32))
    bm.register_prefix(1, [int(t) for t in tok[0]], P)
    # seq 2: fully cached prompt -> share both blocks, fork the tail for
    # the capped last-token recompute
    blocks, matched = bm.match_prefix([int(t) for t in tok[0]])
    assert matched == P
    bm.share(2, blocks, P - 1)
    (src, dst), = bm.fork_for_write(2, P - 1, P)
    rt.apply_copies(*zip(*bm.drain_pending_copies()), use_kernel=True)
    tbl2 = jnp.asarray([bm.tables[2]], jnp.int32)
    # recompute the last prompt token into the PRIVATE copy
    lg2, rt.pages = api.decode_step_paged(params, rt.pages, tok[:, -1:],
                                          tbl2,
                                          jnp.full((1,), P - 1, jnp.int32))
    # baseline: the same last-token extension on the original table
    lg1, _ = api.decode_step_paged(params, rt.pages, tok[:, -1:], tbl1,
                                   jnp.full((1,), P - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg1), atol=1e-5)
    bm.check_invariants()


def test_invalid_slots_write_only_the_trash_block():
    """Padded/invalid token slots must never touch a live block: with
    valid=0 every non-trash page is bit-identical before and after."""
    api = _api("deepseek-7b")
    params = api.init(jax.random.PRNGKey(0))
    pages = api.init_paged_cache(8, 4)
    before = jax.tree.map(lambda x: np.asarray(x), pages)
    tok = jnp.zeros((1, 4), jnp.int32)
    tables = jnp.asarray([[0, 1]], jnp.int32)
    _, after = api.decode_step_paged(params, pages, tok, tables,
                                     jnp.zeros((1,), jnp.int32),
                                     jnp.zeros((1,), jnp.int32))
    for key in ("k_pages", "v_pages"):
        got = np.asarray(after[key])
        np.testing.assert_array_equal(got[:, :8], before[key][:, :8])
        assert np.any(got[:, 8] != before[key][:, 8])  # trash absorbed it


# ---------------------------------------------------------------------------
# tier-1: BlockManager capacity reservation + pool sizing
# ---------------------------------------------------------------------------


def test_ensure_capacity_reserves_without_length_change():
    bm = BlockManager(10, 4)
    bm.allocate(1, 6)                      # 2 blocks, length 6
    added = bm.ensure_capacity(1, 13)      # needs 4 blocks total
    assert len(added) == 2 and len(bm.tables[1]) == 4
    assert bm.lengths[1] == 6              # logical length untouched
    bm.check_invariants()
    # the later commit allocates nothing for already-covered positions
    free = bm.num_free
    bm.append_tokens(1, 7)
    assert bm.num_free == free and bm.lengths[1] == 13
    assert bm.ensure_capacity(1, 10) == [] # no-op when covered


def test_ensure_capacity_out_of_blocks():
    bm = BlockManager(3, 4)
    bm.allocate(1, 8)
    bm.allocate(2, 4)
    with pytest.raises(OutOfBlocks):
        bm.ensure_capacity(1, 16)
    bm.check_invariants()


def test_num_blocks_for_sizes_pool_from_roofline_budget():
    cm = RooflineCostModel(RTX_4090)
    t = configs.get_config("paper-7b")
    d = configs.get_draft_config("paper-7b")
    nb = num_blocks_for(cm, t, d, 16, max_blocks=10**9)
    assert nb == cm.kv_capacity_tokens(t, d) // 16
    assert num_blocks_for(cm, t, d, 16, max_blocks=512) == 512  # clamped
    tiny = configs.reduced(t)
    assert num_blocks_for(cm, tiny, configs.reduced(d), 8) == 4096


def test_runtime_batch_tables_pad_with_trash():
    bm = BlockManager(8, 4)
    rt = PagedKVRuntime(_api("deepseek-7b"), bm)
    from repro.serving.request import Request, Sequence
    s = Sequence(request=Request(1, 0.0, 6, 4))
    bm.allocate(1, 6)
    rt.ctx[1] = 6
    tables, lengths = rt.batch_tables([s], 4)
    assert tables.shape == (4, 2) and lengths.tolist() == [6, 0, 0, 0]
    assert set(tables[0]) <= set(bm.tables[1])
    assert (tables[1:] == rt.trash).all()


# ---------------------------------------------------------------------------
# tier-1: elastic PHYSICAL pool on the real tier (grow / migrate / shrink)
# ---------------------------------------------------------------------------


def test_runtime_grow_shrink_tracks_block_manager():
    """PagedKVRuntime.grow/shrink keep the physical pages, trash id and
    BlockManager pool size in lockstep — the §6.3/6.4 wiring that lets the
    elastic memory manager run on real execution."""
    bm = BlockManager(8, 4)
    api = _api("deepseek-7b")
    rt = PagedKVRuntime(api, bm)
    L = api.cfg.num_layers
    assert rt.pages["k_pages"].shape[1] == 9        # 8 + trash
    # stamp recognisable content into block 3
    rt.pages["k_pages"] = rt.pages["k_pages"].at[:, 3].set(7.0)

    bm.expand(4)
    rt.grow(4)
    assert rt.num_blocks == bm.total_blocks == 12
    assert rt.trash == 12
    assert rt.pages["k_pages"].shape[1] == 13
    # pre-existing content survives the grow
    assert float(rt.pages["k_pages"][0, 3, 0, 0, 0]) == 7.0

    # a sequence landing entirely in the expanded region (the free list
    # pops the freshly attached high ids first) round-trips batch_tables
    from repro.serving.request import Request, Sequence
    bm.allocate(2, 12)
    high = [b for b in bm.tables[2] if b >= bm.boundary]
    assert len(high) == 3                           # 11, 10, 9
    s = Sequence(request=Request(2, 0.0, 12, 4))
    rt.ctx[2] = 12
    tables, lengths = rt.batch_tables([s], 1)
    assert lengths.tolist() == [12]
    assert set(tables[0][:3].tolist()) == set(bm.tables[2])

    # §6.4: migrate the high blocks into the preserved region, then shrink
    rt.pages["k_pages"] = rt.pages["k_pages"].at[:, high[0]].set(3.0)
    plan = bm.plan_contraction()
    assert plan is not None and set(plan.src) == set(high)
    rt.apply_plan(plan)
    bm.commit_contraction(plan)
    rt.shrink(bm.base_blocks)
    assert rt.num_blocks == bm.total_blocks == 8 and rt.trash == 8
    assert all(b < bm.boundary for b in bm.tables[2])
    moved = bm.tables[2][0]                         # high[0]'s new home
    assert float(rt.pages["k_pages"][0, moved, 0, 0, 0]) == 3.0
    bm.check_invariants()


def test_memmgr_drives_physical_pool_hooks():
    """ElasticMemoryManager grow_fn/shrink_fn/migrate_fn fire in lockstep
    with the logical expand/contract cycle (recorded via stub hooks)."""
    from repro.serving.memory_manager import ElasticMemoryManager
    bm = BlockManager(8, 4)
    events = []
    mm = ElasticMemoryManager(
        bm, draft_blocks=4, t_persist=1, tau_low_frac=0.5,
        offload_fn=lambda: events.append("offload"),
        reload_fn=lambda: events.append("reload"),
        migrate_fn=lambda plan: events.append(("migrate", len(plan))) or 0.0,
        grow_fn=lambda extra: events.append(("grow", extra)),
        shrink_fn=lambda nb: events.append(("shrink", nb)))
    bm.allocate(1, 8 * 4)                 # pool full -> low-memory streak
    mm.step(0.0, spec_disabled=True, waiting=4)
    assert ("grow", 4) in events and "offload" in events
    assert bm.total_blocks == 12
    bm.release(1)                          # drained queue -> contraction
    mm.step(1.0, spec_disabled=True, waiting=0)
    assert ("shrink", 8) in events and "reload" in events
    assert bm.total_blocks == 8
    bm.check_invariants()


# ---------------------------------------------------------------------------
# tier-1: adaptive chunk budget (roofline knee)
# ---------------------------------------------------------------------------


def test_knee_chunk_tokens_is_roofline_crossover():
    cm = RooflineCostModel(RTX_4090)
    cfg = configs.get_config("paper-7b")
    knee = cm.knee_chunk_tokens(cfg)
    assert 16 <= knee <= 8192
    t_c, t_m = cm._hybrid_terms(cfg, knee, 0, 1024)
    assert t_c <= t_m                       # memory-bound at the knee...
    t_c, t_m = cm._hybrid_terms(cfg, knee + 1, 0, 1024)
    assert t_c > t_m                        # ...compute-bound just past it


def test_resolve_chunk_tokens():
    cm = RooflineCostModel(RTX_4090)
    cfg = configs.get_config("paper-7b")
    assert cm.resolve_chunk_tokens("auto", cfg) == cm.knee_chunk_tokens(cfg)
    assert cm.resolve_chunk_tokens("128", cfg) == 128
    assert cm.resolve_chunk_tokens(0, cfg) == 0
    assert cm.resolve_chunk_tokens("auto", None) == 256  # no model: fallback


def test_make_real_backend_selects_by_family():
    t, d = _api("mamba2-780m"), _api("mamba2-780m", draft=True)
    assert isinstance(make_real_backend(t, d, max_batch=2, max_seq=32),
                      DenseSlotBackend)
    with pytest.raises(NotImplementedError):
        RealBackend(t, d, max_batch=2, max_seq=32)


# ---------------------------------------------------------------------------
# slow tier: engine-level equivalence on real execution
# ---------------------------------------------------------------------------


def _run_engine(backend_kind, *, chunk=None, policy="nightjar", blocks=256,
                block_size=8, n=4, prompt=10, out=8, prefix_caching=False,
                template=0, memmgr=False):
    target, draft = _api("deepseek-7b"), _api("deepseek-7b", draft=True)
    bm = BlockManager(blocks, block_size, prefix_caching=prefix_caching)
    if backend_kind == "dense":
        be = DenseSlotBackend(target, draft, max_batch=4, max_seq=96, seed=0)
    else:
        be = RealBackend(target, draft, max_batch=4, max_seq=96, seed=0,
                         block_manager=bm)
    sched = ContinuousBatchingScheduler(bm, max_batch=4, chunk_tokens=chunk,
                                        watermark_frac=0.0)
    mm = None
    if memmgr:
        from repro.serving.memory_manager import ElasticMemoryManager
        mm = ElasticMemoryManager(
            bm, draft_blocks=4, t_persist=1, tau_low_frac=0.4,
            offload_fn=be.offload_draft, reload_fn=be.reload_draft,
            migrate_fn=be.migrate_pools, grow_fn=be.grow_pools,
            shrink_fn=be.shrink_pools)
    eng = ServingEngine(be, sched, make_policy(policy, 3, seed=0), mm,
                        gamma_max=3)
    reqs = tiny_requests(n, rate_qps=1e6, prompt_len=prompt, output_len=out,
                         vocab=target.cfg.vocab_size, seed=5,
                         template_len=template)
    m = eng.run(reqs, max_steps=3000, record_timeline=True)
    return {r.req_id: be.output_tokens(r.req_id)[:out + 1] for r in reqs}, m


@pytest.mark.slow
@pytest.mark.real_backend
def test_paged_engine_matches_dense_engine():
    """Greedy token streams identical between the dense slot backend and
    the paged runtime, across AR and adaptive-speculation policies."""
    dense, _ = _run_engine("dense")
    for pol in ("ar", "nightjar"):
        paged, _ = _run_engine("paged", policy=pol)
        assert paged == dense, pol


@pytest.mark.slow
@pytest.mark.real_backend
def test_chunked_real_execution_matches_monolithic():
    """RealBackend.hybrid_step accepts prefill chunks and the chunked token
    streams equal monolithic prefill exactly (the acceptance criterion)."""
    mono, m_mono = _run_engine("paged", prompt=24, out=8)
    for chunk in (4, 7, 16):
        chunked, m = _run_engine("paged", chunk=chunk, prompt=24, out=8)
        assert chunked == mono, chunk
    # chunked mode genuinely exercised mixed steps
    assert any(r["prefill_tokens"] > 0 for r in m.timeline)


@pytest.mark.slow
@pytest.mark.real_backend
def test_paged_preempt_recompute_under_pressure_lossless():
    """A pool far too small for the workload forces preempt-and-recompute;
    the final streams still match an unconstrained run exactly."""
    squeezed, m = _run_engine("paged", chunk=6, blocks=10, block_size=4,
                              out=16)
    roomy, _ = _run_engine("paged", out=16)
    assert squeezed == roomy
    assert len(m.requests) == 4


@pytest.mark.slow
@pytest.mark.real_backend
def test_prefix_caching_real_token_equivalence():
    """Greedy token streams are byte-identical with prefix caching on vs
    off on real execution — shared templated prompts AND fully-identical
    prompts (the capped last-token recompute + CoW fork path)."""
    # 8-token shared template, 16-token prompts: half of every prompt is
    # admitted from the cache after the first request
    base, _ = _run_engine("paged", chunk=8, prompt=16, template=8)
    cached, m = _run_engine("paged", chunk=8, prompt=16, template=8,
                            prefix_caching=True)
    assert cached == base
    assert m.prefix["hits"] > 0 and m.prefix["saved_tokens"] > 0

    # fully identical prompts: every later request shares ALL blocks and
    # forks the tail block to recompute its last prompt token
    base2, _ = _run_engine("paged", chunk=8, prompt=16, template=16)
    cached2, m2 = _run_engine("paged", chunk=8, prompt=16, template=16,
                              prefix_caching=True)
    assert cached2 == base2
    assert m2.prefix["forks"] > 0          # CoW genuinely exercised


@pytest.mark.slow
@pytest.mark.real_backend
def test_elastic_physical_pool_real_execution_lossless():
    """The elastic memory manager running ON the real backend (offload ->
    bm.expand + PagedKVRuntime.grow, contract -> migrate + shrink) keeps
    greedy token streams identical to an unmanaged run."""
    managed, m = _run_engine("paged", blocks=24, block_size=4, out=12,
                             memmgr=True)
    plain, _ = _run_engine("paged", blocks=24, block_size=4, out=12)
    assert managed == plain
    # pressure on a 24-block pool with 4 sequences genuinely triggers the
    # offload/expand path at least once
    assert m.offload_events >= 1
