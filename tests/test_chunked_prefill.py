"""Chunked-prefill hybrid batching: token-budget invariants, progress
guarantee, preempt-and-recompute of half-prefilled sequences, and the
deterministic golden e2e (chunked beats monolithic p99 TTFT at high rate
with identical committed tokens)."""
import numpy as np
import pytest

from repro import configs
from repro.serving.costmodel import RTX_4090
from repro.serving.kv_cache import BlockManager
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.simulator import SimConfig, build_sim_engine
from repro.serving.workload import poisson_requests


def _sched(blocks=1000, bsz=16, chunk=64, max_batch=64, watermark=0.0):
    bm = BlockManager(blocks, bsz)
    return ContinuousBatchingScheduler(bm, max_batch=max_batch,
                                       watermark_frac=watermark,
                                       chunk_tokens=chunk)


def _drive_step(s, batch):
    """Apply one scheduled hybrid batch: prefill chunk progress + one decode
    token per decode-ready sequence (what the engine does, minus latency)."""
    for seq, n in batch.prefill_chunks:
        seq.prefilled += n
    for seq in batch.decode:
        if seq in s.running and s.commit_tokens(seq, 1) and seq.done:
            s.finish(seq)


# ---------------------------------------------------------------------------
# token-budget invariant
# ---------------------------------------------------------------------------


def test_token_budget_never_exceeded():
    """No emitted batch's chunk tokens exceed the per-step budget, across a
    seeded mixed workload driven to completion."""
    rng = np.random.default_rng(0)
    s = _sched(blocks=400, chunk=64)
    reqs = [Request(i, i * 0.01, int(rng.integers(4, 300)),
                    int(rng.integers(1, 8))) for i in range(40)]
    for r in reqs:
        s.add_request(r)
    for _ in range(10_000):
        batch = s.schedule_chunks()
        if batch.empty and not s.num_waiting:
            break
        assert batch.prefill_tokens <= 64          # the invariant
        for seq, n in batch.prefill_chunks:        # chunks never overshoot
            assert 0 < n <= seq.request.prompt_len - seq.prefilled
        _drive_step(s, batch)
        s.bm.check_invariants()
    assert not s.running and not s.num_waiting     # drained


def test_budget_includes_new_admissions():
    """Budget is shared between continuing chunks and new admissions."""
    s = _sched(chunk=100)
    s.add_request(Request(0, 0.0, 80, 4))
    s.add_request(Request(1, 0.1, 80, 4))
    batch = s.schedule_chunks()
    # 80 to request 0, only 20 left for request 1
    assert [(c[0].req_id, c[1]) for c in batch.prefill_chunks] == \
        [(0, 80), (1, 20)]
    assert batch.prefill_tokens == 100


def test_decode_ready_sequences_in_same_step():
    """A mixed batch carries decode-ready sequences alongside chunks."""
    s = _sched(chunk=64)
    s.add_request(Request(0, 0.0, 32, 8))
    b1 = s.schedule_chunks()
    assert b1.prefill_chunks and not b1.decode
    _drive_step(s, b1)
    s.add_request(Request(1, 0.2, 200, 8))
    b2 = s.schedule_chunks()
    assert [seq.req_id for seq in b2.decode] == [0]
    assert [c[0].req_id for c in b2.prefill_chunks] == [1]


# ---------------------------------------------------------------------------
# progress guarantee (no starvation)
# ---------------------------------------------------------------------------


def test_chunked_sequence_never_starved():
    """A partially prefilled sequence finishes its prompt in exactly
    ceil(prompt / per-step prefill share) scheduling rounds even under
    constant decode load and a deep waiting queue of newer arrivals.  The 6
    decode-ready sequences each consume one token of the Sarathi-style
    total-token budget, leaving 64 - 6 prefill tokens per step."""
    s = _sched(blocks=2000, chunk=64, max_batch=8)
    # decode-heavy background: 6 long-output sequences already decode-ready
    for i in range(6):
        s.add_request(Request(i, 0.0, 8, 10_000))
    for _ in range(4):
        _drive_step(s, s.schedule_chunks())
    assert sum(1 for q in s.running if q.prompt_remaining == 0) == 6
    # the victim prompt, then a deep queue of newer arrivals behind it
    s.add_request(Request(100, 1.0, 300, 4))
    for i in range(200, 230):
        s.add_request(Request(i, 2.0, 64, 4))
    rounds = 0
    victim = None
    while True:
        batch = s.schedule_chunks()
        rounds += 1
        if victim is None:
            victim = next(seq for seq, _ in batch.prefill_chunks
                          if seq.req_id == 100)
        _drive_step(s, batch)
        if victim.prompt_remaining == 0:
            break
        assert rounds < 50, "starved"
    # ceil(300 / (64 - 6)) rounds, FIFO: never delayed by the newer arrivals
    assert rounds == -(-300 // (64 - 6)) == 6


def test_decode_tokens_count_against_budget():
    """Sarathi-style total-token budget: each decode-ready sequence consumes
    one of the step's chunk_tokens slots, so the fused step's total tokens
    stay bounded — but min_chunk_tokens stay reserved for prefill, so a
    decode-heavy batch can never stall chunk progress entirely."""
    s = _sched(blocks=4000, chunk=32, max_batch=64)
    for i in range(10):
        s.add_request(Request(i, 0.0, 4, 10_000))
    while any(q.prompt_remaining > 0 for q in s.running) or s.num_waiting:
        _drive_step(s, s.schedule_chunks())
    assert sum(1 for q in s.running if q.prompt_remaining == 0) == 10
    s.add_request(Request(100, 1.0, 500, 4))
    batch = s.schedule_chunks()
    assert len(batch.decode) == 10
    # 10 decode tokens accounted: only 22 prefill tokens this step
    assert batch.prefill_tokens == 32 - 10
    assert batch.prefill_tokens + len(batch.decode) <= 32

    # decode load past the whole budget: the floor keeps prefill alive
    s2 = _sched(blocks=4000, chunk=32, max_batch=64)
    for i in range(40):
        s2.add_request(Request(i, 0.0, 4, 10_000))
    while any(q.prompt_remaining > 0 for q in s2.running) or s2.num_waiting:
        _drive_step(s2, s2.schedule_chunks())
    s2.add_request(Request(100, 1.0, 500, 4))
    batch = s2.schedule_chunks()
    assert len(batch.decode) == 40
    assert batch.prefill_tokens == s2.min_chunk_tokens == 16  # 32 // 2
    assert batch.prefill_tokens > 0                           # never starved


# ---------------------------------------------------------------------------
# preempt-and-recompute of a half-prefilled sequence
# ---------------------------------------------------------------------------


def test_preempted_half_prefilled_releases_all_blocks():
    """Preempting a sequence mid-prefill releases exactly the blocks it had
    reserved (num_free restored), and it restarts cleanly from scratch."""
    bm = BlockManager(12, 4)   # 48-token pool
    s = ContinuousBatchingScheduler(bm, max_batch=4, watermark_frac=0.0,
                                    chunk_tokens=16)
    s.add_request(Request(0, 0.0, 8, 64))     # old: becomes decode-ready
    s.add_request(Request(1, 1.0, 40, 4))     # young: long prompt, chunked
    free0 = bm.num_free
    b = s.schedule_chunks()
    assert {c[0].req_id for c in b.prefill_chunks} == {0, 1}
    _drive_step(s, b)
    b = s.schedule_chunks()                    # seq1 continues its prefill
    _drive_step(s, b)
    young = next(q for q in s.running if q.req_id == 1)
    assert 0 < young.prefilled < 40            # genuinely half-prefilled
    # grow seq0 until the pool forces preemption of the youngest (seq1)
    old = next(q for q in s.running if q.req_id == 0)
    while young in s.running:
        assert s.commit_tokens(old, 4)
    assert s.waiting[0].req_id == 1            # requeued at the front
    bm.check_invariants()
    assert 1 not in bm.tables                  # no leaked table
    # finishing seq0 restores the ENTIRE pool: nothing leaked by the
    # half-prefilled victim
    s.finish(old)
    assert bm.num_free == free0
    # re-admission restarts prefill from zero
    b = s.schedule_chunks()
    readmitted = next(c[0] for c in b.prefill_chunks if c[0].req_id == 1)
    assert readmitted.prefilled == 0 and readmitted.generated == 0
    _drive_step(s, b)
    assert readmitted.prefilled == 16          # chunk-sized progress again
    bm.check_invariants()


def test_blocks_allocated_per_chunk_not_per_prompt():
    """Admission in chunked mode reserves blocks for the first chunk only —
    a prompt bigger than the whole pool still gets admitted and streams
    through."""
    bm = BlockManager(8, 4)    # 32-token pool
    s = ContinuousBatchingScheduler(bm, max_batch=2, watermark_frac=0.0,
                                    chunk_tokens=8)
    s.add_request(Request(0, 0.0, 1000, 1))   # prompt >> pool
    b = s.schedule_chunks()
    assert b.prefill_chunks[0][1] == 8
    assert bm.num_free == 6                    # 2 blocks for 8 tokens
    # monolithic admission would never fit: blocks_needed(1001) > 8
    assert bm.blocks_needed(1001) > bm.total_blocks


# ---------------------------------------------------------------------------
# engine-level hybrid semantics
# ---------------------------------------------------------------------------


def _cfg(chunk):
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, max_batch=256, seed=0, chunk_tokens=chunk)


def test_gamma_zero_while_chunks_in_flight():
    """Speculation is forced off for any step carrying a prefill chunk."""
    eng = build_sim_engine(_cfg(256), "nightjar")
    m = eng.run(poisson_requests(40, 120, dataset="alpaca", seed=2),
                record_timeline=True)
    mixed = [r for r in m.timeline if r["prefill_tokens"] > 0]
    assert mixed, "no hybrid steps exercised"
    assert all(r["gamma"] == 0 for r in mixed)
    # and speculation still happens on pure-decode steps
    assert any(r["gamma"] > 0 for r in m.timeline
               if r["prefill_tokens"] == 0)


# ---------------------------------------------------------------------------
# golden e2e: chunked beats monolithic p99 TTFT at high rate
# ---------------------------------------------------------------------------


def _golden_run(chunk):
    eng = build_sim_engine(_cfg(chunk), "nightjar")
    reqs = poisson_requests(80, 300, dataset="alpaca", seed=1)
    m = eng.run(reqs)
    return m, sum(r.output_len for r in reqs)


def test_chunked_beats_monolithic_p99_ttft_high_rate():
    """At a saturating arrival rate, chunked prefill strictly reduces p99
    TTFT vs monolithic prefill on the same seeded workload, commits the
    identical token total, and is bit-deterministic across two consecutive
    runs.  The budget is TOTAL tokens per step (Sarathi accounting): 384
    covers ~128 decode slots at this saturation plus a 256-token prefill
    share — the equivalent of the pre-accounting 256-token chunk config."""
    mono1, expect = _golden_run(0)
    mono2, _ = _golden_run(0)
    chunk1, _ = _golden_run(384)
    chunk2, _ = _golden_run(384)
    # determinism: two consecutive runs agree exactly
    assert mono1.summary() == mono2.summary()
    assert chunk1.summary() == chunk2.summary()
    # identical committed tokens (every request ran to completion, and
    # chunking changed WHEN tokens were produced, not HOW MANY)
    assert mono1.total_tokens == chunk1.total_tokens == expect
    assert len(mono1.requests) == len(chunk1.requests) == 300
    # the tail: strictly lower p99 (and p95) TTFT under chunking
    assert chunk1.ttft_percentile(0.99) < mono1.ttft_percentile(0.99)
    assert chunk1.ttft_percentile(0.95) < mono1.ttft_percentile(0.95)
    # SLO-aware view agrees: goodput no worse
    assert chunk1.goodput >= mono1.goodput
