"""Edge-case contracts for the metric helpers: `percentile` returns 0.0 on
empty samples and `goodput_of` returns 0.0 at zero elapsed — BY CONTRACT,
so table renderers must gate on the sample count and print ``n/a`` instead
of a fake perfect-latency cell (benchmarks/make_tables.py)."""
import os
import sys

import pytest

from repro.serving.request import (Metrics, RequestStats, goodput_of,
                                   percentile, slo_attainment_of)

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__),
                                                "..")))

from benchmarks.make_tables import fmt_ms, fmt_num  # noqa: E402


def _stat(req_id=0, ttft=0.1, tokens=10, slo=None):
    return RequestStats(req_id=req_id, arrival=0.0, ttft=ttft, tpot=0.01,
                        tokens=tokens, slo=slo)


# ---------------------------------------------------------------------------
# percentile
# ---------------------------------------------------------------------------


def test_percentile_empty_returns_zero_by_contract():
    assert percentile([], 0.5) == 0.0
    assert percentile([], 0.99) == 0.0


def test_percentile_nonempty_interpolates():
    xs = [0.1, 0.2, 0.3, 0.4]
    assert percentile(xs, 0.0) == 0.1
    assert percentile(xs, 1.0) == 0.4
    assert percentile(xs, 0.5) == pytest.approx(0.25)
    assert percentile([0.7], 0.99) == 0.7


# ---------------------------------------------------------------------------
# goodput_of
# ---------------------------------------------------------------------------


def test_goodput_zero_elapsed_returns_zero_by_contract():
    reqs = [_stat(tokens=100)]
    assert goodput_of(reqs, 0.0, 123.0) == 0.0
    assert goodput_of(reqs, -1.0, 123.0) == 0.0
    assert goodput_of([], 0.0, 123.0) == 0.0


def test_goodput_counts_only_slo_met():
    reqs = [_stat(0, ttft=0.1, tokens=10, slo=0.5),
            _stat(1, ttft=0.9, tokens=10, slo=0.5)]
    assert goodput_of(reqs, 2.0, 10.0) == pytest.approx(5.0)
    # no per-request stats: falls back to raw throughput
    assert goodput_of([], 2.0, 10.0) == 10.0
    assert slo_attainment_of(reqs) == 0.5
    assert slo_attainment_of([]) == 1.0


def test_metrics_zero_run_is_all_zero_not_crash():
    m = Metrics()
    assert m.throughput == 0.0
    assert m.goodput == 0.0
    assert m.ttft_percentile(0.99) == 0.0


# ---------------------------------------------------------------------------
# the renderer gate: zero-sample cells print n/a, never 0
# ---------------------------------------------------------------------------


def test_fmt_helpers_render_na_for_empty_cells():
    assert fmt_ms(0.0, 0) == "n/a"
    assert fmt_ms(percentile([], 0.99), 0) == "n/a"
    assert fmt_num(0.0, 0) == "n/a"
    assert fmt_num(goodput_of([], 0.0, 0.0), 0) == "n/a"


def test_fmt_helpers_render_values_when_backed_by_samples():
    assert fmt_ms(0.1234, 5) == "123ms"
    assert fmt_ms(0.0, 5) == "0ms"        # a REAL zero renders as zero
    assert fmt_num(12.34, 5) == "12.3"
    assert fmt_num(0.875, 3, ".3f") == "0.875"


# ---------------------------------------------------------------------------
# cluster metrics at zero-sample windows: n/a by contract, never fake-perfect
# ---------------------------------------------------------------------------


def test_offered_attainment_na_when_no_deadline_samples():
    """Regression: a run whose offered load carries no deadline samples
    (everything shed before any deadline-carrying request finished, or no
    request had an SLO at all) must report ``slo_attainment_offered`` as
    None — n/a by contract — not divide by zero or fake a perfect 1.0."""
    from repro.serving.cluster import ClusterMetrics
    m = ClusterMetrics(per_replica=[Metrics()],
                       shed=[{"req_id": 0, "at": 0.0, "slo": None}])
    assert m.offered_slo_count == 0
    assert m.slo_attainment_offered is None
    assert m.summary()["slo_attainment_offered"] is None
    # one offered deadline sample, shed: an honest 0.0, not n/a
    m2 = ClusterMetrics(per_replica=[Metrics()],
                        shed=[{"req_id": 0, "at": 0.0, "slo": 1.0}])
    assert m2.offered_slo_count == 1
    assert m2.slo_attainment_offered == 0.0


def test_per_replica_summary_na_for_zero_sample_replica():
    """A replica that finished zero requests (retired mid-drain, or every
    request it saw was shed upstream) has no latency samples: its summary
    row reports None for p99/attainment — the same n/a convention the
    table renderers gate on — never percentile()'s fake-perfect 0.0."""
    from repro.serving.cluster import ClusterMetrics
    m = ClusterMetrics(per_replica=[Metrics()])
    row = m.per_replica_summary()[0]
    assert row["finished"] == 0
    assert row["p99_ttft_s"] is None
    assert row["slo_attainment"] is None
    assert fmt_ms(0.0, row["finished"]) == "n/a"
