"""Flight recorder: deterministic traces, span balance, zero-cost no-op.

Pins the observability layer's three contracts:

* **determinism** — two same-seed runs emit byte-identical JSONL traces
  (the golden unit is the exported bytes, not a parsed comparison);
* **span balance** — every finished request's stage durations partition
  its end-to-end latency exactly (the stage machine closes each span as
  the next opens, so this holds by construction — the test pins it);
* **zero cost when off** — a run without a recorder (or with a disabled
  one) produces the same ``Metrics.summary()`` as the pre-recorder code
  path and records zero events.
"""
import json
import os
import sys

import pytest

from repro import configs
from repro.serving.observability import (OUTCOMES, STAGES, MetricsRegistry,
                                         TraceRecorder)
from repro.serving.request import TIMELINE_RING_CAP, Metrics
from repro.serving.simulator import SimConfig, build_sim_cluster, \
    build_sim_engine
from repro.serving.workload import poisson_requests

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.trace_report import (analyze, batch_bin,  # noqa: E402
                                     load_trace, restart_episodes,
                                     spec_surface, stage_waterfalls)
from repro.serving.costmodel import RTX_4090  # noqa: E402


def _cfg(**kw):
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, max_batch=256, seed=0, **kw)


def _cluster_run(trace=None, record_timeline=False):
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="jsq", trace=trace)
    m = cl.run(poisson_requests(20, 40, dataset="alpaca", seed=1),
               record_timeline=record_timeline)
    return m, cl


@pytest.fixture(scope="module")
def traced():
    rec = TraceRecorder()
    m, cl = _cluster_run(trace=rec)
    return rec, m, cl


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------


def test_trace_byte_identical_across_runs(traced):
    rec1, _, _ = traced
    rec2 = TraceRecorder()
    _cluster_run(trace=rec2)
    b1, b2 = rec1.jsonl_bytes(), rec2.jsonl_bytes()
    assert len(rec1.events) > 100
    assert rec1.dropped == 0
    assert b1 == b2


def test_trace_is_virtual_time_only(traced):
    """No wall-clock leaks: every timestamp is a finite non-negative
    virtual second well below any epoch-scale value."""
    rec, _, _ = traced
    for e in rec.events:
        assert 0.0 <= e["t"] < 1e6


# ---------------------------------------------------------------------------
# span balance
# ---------------------------------------------------------------------------


def test_span_balance_partitions_e2e(traced):
    """Every request with a terminal outcome: stage durations sum to the
    end-to-end latency within 1e-6, across all stages in STAGES only."""
    rec, m, _ = traced
    events = [json.loads(ln) for ln in rec.jsonl_lines()]
    wf = stage_waterfalls(events)
    assert wf, "no terminated requests in trace"
    fin = {rid: r for rid, r in wf.items() if r["outcome"] == "finished"}
    assert len(fin) >= 30
    for rid, r in wf.items():
        assert set(r["stages"]) <= set(STAGES)
        assert r["outcome"] in OUTCOMES
        total = sum(r["stages"].values())
        assert total == pytest.approx(r["e2e"], abs=1e-6), rid
    # open spans may only belong to requests without a terminal outcome
    for rid in rec.open_spans():
        assert rid not in rec.outcomes


def test_outcome_counts_match_metrics(traced):
    rec, m, _ = traced
    fin = sum(1 for o in rec.outcomes.values() if o == "finished")
    assert fin == sum(len(rm.latencies) for rm in m.per_replica)


# ---------------------------------------------------------------------------
# zero-cost no-op when disabled
# ---------------------------------------------------------------------------


def test_untraced_summary_identical_and_disabled_records_nothing(traced):
    _, m_traced, _ = traced
    m_plain, _ = _cluster_run()
    rec_off = TraceRecorder(enabled=False)
    m_off, _ = _cluster_run(trace=rec_off)
    # disabled recorder: zero events, zero registry traffic
    assert len(rec_off.events) == 0
    assert rec_off.registry._metrics == {}
    # untraced summaries are byte-identical (no spec section, same numbers)
    s_plain, s_off = m_plain.summary(), m_off.summary()
    assert json.dumps(s_plain, sort_keys=True) \
        == json.dumps(s_off, sort_keys=True)
    assert "spec" not in s_plain
    # a traced run adds ONLY the spec section on top of the same numbers
    s_traced = dict(m_traced.summary())
    assert "spec" in s_traced
    s_traced.pop("spec")
    assert json.dumps(s_plain, sort_keys=True) \
        == json.dumps(s_traced, sort_keys=True)


def test_spec_summary_section(traced):
    _, m, _ = traced
    spec = m.summary()["spec"]
    assert spec["steps"] > 0
    assert 0.0 <= spec["spec_step_fraction"] <= 1.0
    assert spec["spec_off_step_fraction"] == pytest.approx(
        1.0 - spec["spec_step_fraction"], abs=1e-9)
    for g, row in spec["per_gamma"].items():
        assert row["steps"] > 0
        if int(g) > 0 and "acceptance_rate" in row:
            assert 0.0 <= row["acceptance_rate"] <= 1.0


# ---------------------------------------------------------------------------
# exporters + analyzer round-trip
# ---------------------------------------------------------------------------


def test_jsonl_roundtrip_through_report(traced, tmp_path):
    rec, m, _ = traced
    p = str(tmp_path / "trace.jsonl")
    rec.export_jsonl(p)
    events = load_trace(p)
    assert len(events) == len(rec.events)
    report = analyze(events)
    fin = sum(1 for o in rec.outcomes.values() if o == "finished")
    assert report["waterfall"]["outcomes"]["finished"] == fin
    assert report["spec_surface"], "no engine step spans in report"
    # engine step spans carry the planner tuple
    steps = [e for e in events
             if e["cat"] == "engine" and e["name"] == "step"]
    assert steps and all(
        {"B", "gamma", "tokens", "accepted"} <= set(e["args"]) for e in steps)


def test_chrome_export(traced, tmp_path):
    rec, _, _ = traced
    p = str(tmp_path / "trace.json")
    rec.export_chrome(p)
    with open(p, "r", encoding="utf-8") as f:
        payload = json.load(f)
    evs = payload["traceEvents"]
    procs = {e["args"]["name"] for e in evs
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert {"replica 0", "replica 1"} <= procs
    assert any(e.get("ph") == "X" and e["cat"] == "request" for e in evs)


def test_unknown_format_raises(traced, tmp_path):
    rec, _, _ = traced
    with pytest.raises(ValueError):
        rec.export(str(tmp_path / "x"), fmt="protobuf")


# ---------------------------------------------------------------------------
# analyzer units
# ---------------------------------------------------------------------------


def test_batch_bin_powers_of_two():
    assert [batch_bin(b) for b in (1, 2, 3, 4, 5, 8, 9, 256)] \
        == [1, 2, 4, 4, 8, 8, 16, 256]


def test_restart_episode_detection_synthetic():
    """Hand-built trace: enter spec_off at t=1, reload at t=2, resume at
    t=3, AR step, then the first speculative commit at t=4 closes the
    episode at cost 3.5s."""
    evs = [
        {"ph": "i", "cat": "fleet", "name": "brownout", "t": 1.0, "pid": -1,
         "args": {"from": "normal", "to": "spec_off"}},
        {"ph": "X", "cat": "engine", "name": "step", "t": 1.5, "dur": 0.1,
         "pid": 0, "args": {"B": 4, "gamma": 0, "tokens": 4, "accepted": 0,
                            "prefill_tokens": 0}},
        {"ph": "i", "cat": "memmgr", "name": "reload", "t": 2.0, "pid": 0,
         "args": {}},
        {"ph": "i", "cat": "fleet", "name": "brownout", "t": 3.0, "pid": -1,
         "args": {"from": "spec_off", "to": "normal"}},
        {"ph": "X", "cat": "engine", "name": "step", "t": 3.2, "dur": 0.1,
         "pid": 0, "args": {"B": 4, "gamma": 0, "tokens": 4, "accepted": 0,
                            "prefill_tokens": 0}},
        {"ph": "X", "cat": "engine", "name": "step", "t": 4.0, "dur": 0.5,
         "pid": 0, "args": {"B": 4, "gamma": 2, "tokens": 9, "accepted": 5,
                            "prefill_tokens": 0}},
    ]
    eps = restart_episodes(evs)
    assert len(eps) == 1
    ep = eps[0]
    assert ep["reloads"] == 1
    assert ep["deepest_stage"] == "spec_off"
    assert ep["restart_cost_s"] == pytest.approx(3.5)
    assert ep["spec_off_s"] == pytest.approx(2.0)
    assert ep["recovery_s"] == pytest.approx(1.5)
    # the surface only sees the three step spans
    surf = spec_surface(evs)
    assert surf["4/2"]["acceptance_rate"] == pytest.approx(5 / 8)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_exposition_deterministic():
    def build():
        reg = MetricsRegistry()
        reg.counter("a_total", "a help").inc(3)
        reg.gauge("g").set(2.5)
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        h.observe(5.0)
        return reg
    e1, e2 = build().exposition(), build().exposition()
    assert e1 == e2
    assert "# TYPE a_total counter" in e1
    assert 'lat_seconds_bucket{le="+Inf"} 3' in e1
    assert "lat_seconds_count 3" in e1


def test_registry_type_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")


def test_registry_snapshot_series():
    reg = MetricsRegistry(series_capacity=2)
    c = reg.counter("n_total")
    for t in (1.0, 2.0, 3.0):
        c.inc()
        reg.snapshot(t)
    assert len(reg.series) == 2           # ring-bounded
    assert reg.series[-1]["t"] == 3.0
    assert reg.series[-1]["n_total"] == 3.0


# ---------------------------------------------------------------------------
# bounded timeline ring (satellite: unbounded-growth fix)
# ---------------------------------------------------------------------------


def test_timeline_ring_bounded():
    m = Metrics()
    m.use_timeline_ring(cap=8)
    for i in range(20):
        m.timeline.append({"t": float(i)})
    assert len(m.timeline) == 8
    assert m.timeline[0]["t"] == 12.0
    assert TIMELINE_RING_CAP >= 4096


def test_engine_default_records_no_timeline():
    eng = build_sim_engine(_cfg(), "nightjar")
    m = eng.run(poisson_requests(20, 10, dataset="alpaca", seed=1))
    assert m.timeline == [] or len(m.timeline) == 0
    assert "spec" not in m.summary()


def test_engine_recorder_ring_eviction():
    """A tiny-capacity recorder keeps memory bounded and counts drops."""
    rec = TraceRecorder(capacity=64)
    eng = build_sim_engine(_cfg(), "nightjar", trace=rec)
    eng.run(poisson_requests(20, 20, dataset="alpaca", seed=1))
    assert len(rec.events) == 64
    assert rec.dropped > 0
