"""Serving-correctness invariant: prefill + step-by-step decode must equal
the full-sequence forward, per model family (fp32, atol 1e-4)."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry
from repro.models.registry import _unembed_table

FAMS = ["deepseek-7b", "qwen3-14b", "grok-1-314b", "paligemma-3b",
        "mamba2-780m", "zamba2-1.2b", "whisper-medium", "gemma-7b"]


def _setup(arch, S=16):
    cfg = configs.reduced(configs.get_config(arch)).replace(dtype="float32")
    api = registry.get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = api.init(rng)
    B = 2
    tok = jax.random.randint(rng, (B, S + 4), 0, cfg.vocab_size)
    extra = {}
    if cfg.family == "encdec":
        extra["enc_emb"] = jax.random.normal(rng, (B, 12, cfg.d_model),
                                             jnp.float32)
    if cfg.family == "vlm":
        extra["image_emb"] = jax.random.normal(
            rng, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return cfg, api, params, tok, extra


def _ref_logits(cfg, api, params, tokens, extra):
    h = api.forward(params, {"tokens": tokens, **extra})
    table = _unembed_table(cfg, params)
    logits = jnp.einsum("bsd,vd->bsv", h, table,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_forward(arch):
    S = 16
    cfg, api, params, tok, extra = _setup(arch, S)
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    max_len = off + S + 8

    ref = _ref_logits(cfg, api, params, tok[:, :S + 3], extra)

    logits_p, cache = api.prefill(params, {"tokens": tok[:, :S], **extra},
                                  max_len)
    outs = [logits_p[:, 0]]
    for t in range(S, S + 3):
        lg, cache = api.decode_step(params, cache, tok[:, t:t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs[:-1] + [outs[-1]], axis=1)
    want = ref[:, off + S - 1: off + S + 3]
    assert jnp.max(jnp.abs(dec - want)) < 1e-3


@pytest.mark.parametrize("arch", FAMS)
def test_multitoken_extension_matches(arch):
    """decode_step with T=gamma+1 (the speculative verify path)."""
    S = 16
    cfg, api, params, tok, extra = _setup(arch, S)
    off = cfg.num_image_tokens if cfg.family == "vlm" else 0
    max_len = off + S + 8

    ref = _ref_logits(cfg, api, params, tok[:, :S + 3], extra)
    _, cache = api.prefill(params, {"tokens": tok[:, :S], **extra}, max_len)
    lg3, cache = api.decode_step(params, cache, tok[:, S:S + 3])
    want = ref[:, off + S: off + S + 3]
    assert jnp.max(jnp.abs(lg3 - want)) < 1e-3
    # SSM families must emit rollback checkpoints on multi-token extension
    if cfg.family in ("ssm", "hybrid"):
        assert "checkpoints" in cache
