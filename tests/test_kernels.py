"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_migration import migrate_blocks
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("nb,row_shape", [(8, (4, 2, 4)), (16, (2, 8, 16)),
                                          (5, (3, 2, 2))])
def test_block_migration_sweep(dtype, nb, row_shape):
    key = jax.random.PRNGKey(0)
    L = 3
    x = jax.random.normal(key, (L, nb) + row_shape).astype(dtype)
    m = max(nb // 2, 1)
    src = jnp.asarray(np.random.default_rng(1).choice(nb, m, replace=False),
                      jnp.int32)
    free = [i for i in range(nb) if i not in np.asarray(src)]
    dst = jnp.asarray(free[:m], jnp.int32)
    a = migrate_blocks(x, src, dst, use_kernel=False)
    b = migrate_blocks(x, src, dst, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a[:, np.asarray(dst)]),
                                  np.asarray(x[:, np.asarray(src)]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,D,bs,maxb", [
    (2, 4, 4, 64, 16, 3),    # MHA
    (3, 8, 2, 64, 16, 4),    # GQA
    (2, 8, 1, 128, 8, 5),    # MQA
])
def test_paged_attention_sweep(B, H, KH, D, bs, maxb, dtype):
    key = jax.random.PRNGKey(2)
    nblocks = maxb * B + 2
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    kp = jax.random.normal(ks[1], (nblocks, bs, KH, D)).astype(dtype)
    vp = jax.random.normal(ks[2], (nblocks, bs, KH, D)).astype(dtype)
    tables = jax.random.randint(ks[3], (B, maxb), 0, nblocks)
    lengths = jnp.asarray([1 + (7 * i) % (maxb * bs) for i in range(B)])
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    got = paged_attention(q, kp, vp, tables, lengths, interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("T", [1, 4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KH,D,bs,maxb", [
    (2, 4, 4, 64, 16, 3),    # MHA
    (3, 8, 2, 64, 16, 4),    # GQA
    (2, 8, 1, 128, 8, 5),    # MQA
])
def test_paged_attention_multiquery_sweep(B, H, KH, D, bs, maxb, dtype, T):
    """Multi-query extension (T=1 decode / T=gamma+1 verify / T=chunk
    append) vs the jnp oracle, over GQA ratios and ragged lengths."""
    key = jax.random.PRNGKey(7)
    nblocks = maxb * B + 2
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, T, H, D)).astype(dtype)
    kp = jax.random.normal(ks[1], (nblocks, bs, KH, D)).astype(dtype)
    vp = jax.random.normal(ks[2], (nblocks, bs, KH, D)).astype(dtype)
    tables = jax.random.randint(ks[3], (B, maxb), 0, nblocks)
    # ragged: every sequence's total length (incl. the T new tokens) differs
    lengths = jnp.asarray([T + (7 * i) % (maxb * bs - T + 1)
                           for i in range(B)])
    want = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    got = paged_attention(q, kp, vp, tables, lengths, interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_paged_attention_multiquery_is_causal_within_extension():
    """Query t must not see the K/V of queries t' > t: the T-token oracle
    output at row t equals a fresh single-query call at length - T + t + 1."""
    B, T, H, KH, D, bs, maxb = 2, 4, 4, 2, 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    nblocks = maxb * B + 1
    q = jax.random.normal(ks[0], (B, T, H, D))
    kp = jax.random.normal(ks[1], (nblocks, bs, KH, D))
    vp = jax.random.normal(ks[2], (nblocks, bs, KH, D))
    tables = jax.random.randint(ks[3], (B, maxb), 0, nblocks)
    lengths = jnp.asarray([maxb * bs, maxb * bs - 5])
    multi = ref.paged_attention_ref(q, kp, vp, tables, lengths)
    for t in range(T):
        single = ref.paged_attention_ref(q[:, t], kp, vp, tables,
                                         lengths - T + t + 1)
        np.testing.assert_allclose(np.asarray(multi[:, t]),
                                   np.asarray(single), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,H,KH,D", [(2, 256, 4, 4, 64),
                                        (1, 128, 8, 2, 128),
                                        (2, 384, 4, 1, 64)])
def test_flash_attention_sweep(B, S, H, KH, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D)).astype(dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = flash_attention(q, k, v, causal=causal, block_q=128, block_k=128,
                          interpret=True)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


def test_flash_matches_model_blockwise():
    """The model's chunked attention, the kernel, and the naive ref agree."""
    from repro.models.common import blockwise_attention
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, H, KH, D = 2, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KH, D))
    v = jax.random.normal(ks[2], (B, S, KH, D))
    a = ref.flash_attention_ref(q, k, v, causal=True)
    b = blockwise_attention(q, k, v, causal=True, chunk=64)
    c = blockwise_attention(q, k, v, causal=True, chunk=64, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    np.testing.assert_allclose(np.asarray(b), np.asarray(c), atol=1e-6)
