"""Overload-resilient request lifecycle: deadlines, client cancellation,
priority classes, class-weighted admission and the fleet brownout ladder.

The golden e2e here is the surge gate: cancelling or expiring a request at
ANY point of its life releases every device block, CoW pin, host-KV pin
and queue slot it holds (I8: full-pool completeness — no block stranded in
no tier), every terminal outcome is accounted per class, and surviving
streams commit byte-identical to the cancellation-free run.
"""
import hashlib

import numpy as np
import pytest

from repro import configs
from repro.serving.cluster import FAILED, ServingCluster
from repro.serving.controlplane import (AdmissionController,
                                        BROWNOUT_STAGES, BrownoutController,
                                        ReplicaSnapshot)
from repro.serving.costmodel import RTX_4090
from repro.serving.faults import (CancelStorm, FaultInjector, FaultPlan,
                                  RetryPolicy)
from repro.serving.kv_cache import BlockManager
from repro.serving.request import Request, Sequence, class_rank
from repro.serving.simulator import (SimConfig, build_sim_cluster,
                                     build_sim_engine)
from repro.serving.workload import (SURGE_CLASSES, cancellation_storm,
                                    poisson_requests, surge_requests,
                                    surge_trace)


def _cfg(**kw):
    kw.setdefault("max_batch", 256)
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, seed=0, **kw)


def _sha(m):
    stream = sorted((r.req_id, r.tokens) for r in m.requests)
    return hashlib.sha256(repr(stream).encode()).hexdigest()[:16]


def _check_all(cl: ServingCluster):
    for i, eng in enumerate(cl.replicas):
        eng.scheduler.bm.check_invariants(failed=cl.state[i] == FAILED)


def _snap(ttft=0.0, kv=1.0, decode=0):
    return ReplicaSnapshot(replica_id=0, t=0.0, clock=0.0, load=0,
                           decode_count=decode, prefill_backlog_tokens=0,
                           kv_allocatable=int(kv * 1000), kv_total=1000,
                           ewma_ttft=ttft, ewma_tpot=0.01,
                           predicted_ttft=ttft)


# ---------------------------------------------------------------------------
# engine lifecycle: cancellation releases everything (I8)
# ---------------------------------------------------------------------------


def test_cancel_running_releases_blocks_and_accounts():
    eng = build_sim_engine(_cfg(), "nightjar")
    reqs = [Request(i, 0.0, prompt_len=64, output_len=200) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    assert eng.scheduler.num_running > 0
    victim = eng.scheduler.running[0].req_id
    assert eng.cancel_request(victim) is True
    assert eng.cancel_request(victim) is False     # idempotent: already gone
    assert [c["req_id"] for c in eng.metrics.cancelled] == [victim]
    eng.scheduler.bm.check_invariants()            # I8: nothing leaked
    while eng.step() is not None:
        pass
    assert len(eng.metrics.requests) == 3
    assert victim not in {r.req_id for r in eng.metrics.requests}
    # the cancelled request's orphaned TTFT sample was withdrawn
    assert len(eng.metrics.ttfts) == 3
    eng.scheduler.bm.check_invariants()


def test_cancel_waiting_and_pending():
    eng = build_sim_engine(_cfg(), "nightjar")
    now_req = Request(0, 0.0, prompt_len=32, output_len=8)
    later = Request(1, 50.0, prompt_len=32, output_len=8)
    eng.submit(now_req)
    eng.submit(later)
    # pending (arrival not reached) is cancellable
    assert eng.cancel_request(1) is True
    eng.step()
    while eng.step() is not None:
        pass
    assert len(eng.metrics.requests) == 1
    assert len(eng.metrics.cancelled) == 1
    assert eng.cancel_request(99) is False         # unknown id
    eng.scheduler.bm.check_invariants()


# ---------------------------------------------------------------------------
# deadlines: reaped at dispatch, mid-decode, and from idle
# ---------------------------------------------------------------------------


def test_deadline_expired_mid_decode_is_reaped():
    eng = build_sim_engine(_cfg(), "nightjar")
    eng.submit(Request(0, 0.0, prompt_len=64, output_len=100_000,
                       deadline=0.5))
    eng.submit(Request(1, 0.0, prompt_len=64, output_len=32))
    steps = 0
    while eng.step() is not None and steps < 100_000:
        steps += 1
    assert [e["req_id"] for e in eng.metrics.expired] == [0]
    assert {r.req_id for r in eng.metrics.requests} == {1}
    eng.scheduler.bm.check_invariants()


def test_deadline_expiry_is_actionable_from_idle():
    """A deadline-carrying waiting request on an otherwise idle engine is
    never stranded: its expiry is the next actionable event and the reap
    fires exactly there (``>=`` boundary)."""
    eng = build_sim_engine(_cfg(max_batch=1), "nightjar")
    eng.submit(Request(0, 0.0, prompt_len=64, output_len=100_000,
                       deadline=1_000.0))
    eng.submit(Request(1, 0.0, prompt_len=64, output_len=8, deadline=2.0))
    steps = 0
    while eng.step() is not None and steps < 200_000:
        steps += 1
    # req 1 never fit the batch of 1 and expired at t=2.0; req 0 expired
    # mid-decode at t=1000 — both accounted, neither finished
    assert {e["req_id"] for e in eng.metrics.expired} == {0, 1}
    assert eng.metrics.requests == []
    eng.scheduler.bm.check_invariants()


# ---------------------------------------------------------------------------
# priority classes: preemption order
# ---------------------------------------------------------------------------


def test_class_rank_and_preemption_key_order():
    assert class_rank("interactive") < class_rank("batch") \
        < class_rank("best_effort") < class_rank("mystery")
    eng = build_sim_engine(_cfg(), "nightjar")
    key = eng.scheduler._age_key
    old_inter = Sequence(Request(0, 0.0, 8, 8, priority="interactive"))
    new_inter = Sequence(Request(1, 5.0, 8, 8, priority="interactive"))
    old_be = Sequence(Request(2, 0.0, 8, 8, priority="best_effort"))
    # preemption picks max(key): best_effort loses to ANY interactive,
    # and within a class the newest request loses first
    assert key(old_be) > key(new_inter) > key(old_inter)


# ---------------------------------------------------------------------------
# admission: class-weighted shedding
# ---------------------------------------------------------------------------


def test_admission_class_weights_shed_order_and_accounting():
    adm = AdmissionController(shed_factor=1.5, resume_factor=1.0,
                              class_weights={"interactive": 3.0,
                                             "best_effort": 0.5})
    be = Request(0, 0.0, 8, 8, slo=1.0, priority="best_effort")
    ia = Request(1, 0.0, 8, 8, slo=1.0, priority="interactive")
    # forecast 2.0: past best_effort's 0.75 threshold, under
    # interactive's 4.5 — class-ordered shedding at the same forecast
    assert adm.should_shed(be, 2.0) is True
    assert adm.should_shed(ia, 2.0) is False
    assert adm.shedding is True                    # any class latched
    assert adm.shed_by_class == {"best_effort": 1}
    # best_effort resumes when forecast drops below slo * resume * weight
    assert adm.should_shed(be, 0.4) is False
    assert adm.shedding is False
    assert adm.shed_count == 1


def test_admission_no_weights_single_class_unchanged():
    """Without class_weights every class sheds at the same threshold —
    exactly the pre-class behaviour."""
    a = AdmissionController(shed_factor=1.5)
    b = AdmissionController(shed_factor=1.5)
    r1 = Request(0, 0.0, 8, 8, slo=1.0)
    r2 = Request(1, 0.0, 8, 8, slo=1.0, priority="best_effort")
    for f in (0.5, 2.0, 2.0, 0.9, 0.5):
        assert a.should_shed(r1, f) == b.should_shed(r2, f)
    with pytest.raises(ValueError):
        AdmissionController(class_weights={"interactive": 0.0})
    with pytest.raises(ValueError):
        AdmissionController(shed_factor=1.0, resume_factor=2.0)


# ---------------------------------------------------------------------------
# brownout ladder: hysteresis, cooldowns, rung semantics
# ---------------------------------------------------------------------------


def test_brownout_ladder_climbs_one_rung_per_eval_with_cooldown():
    bo = BrownoutController(slo=1.0, cooldown_s=1.0, check_interval_s=0.0)
    hot = [_snap(ttft=5.0)]
    assert bo.evaluate(0.0, hot)["to"] == "spec_off"
    assert bo.evaluate(0.5, hot) is None           # inside cooldown
    assert bo.evaluate(1.1, hot)["to"] == "draft_offload"
    assert bo.evaluate(2.2, hot)["to"] == "output_cap"
    assert bo.evaluate(3.3, hot)["to"] == "shed"
    assert bo.evaluate(4.4, hot) is None           # top rung: nowhere to go
    assert bo.stage == len(BROWNOUT_STAGES) - 1
    # calm unwinds one rung at a time
    calm = [_snap(ttft=0.1, kv=0.9)]
    assert bo.evaluate(5.5, calm)["to"] == "output_cap"
    assert bo.evaluate(6.6, calm)["to"] == "draft_offload"
    assert bo.evaluate(7.7, calm)["to"] == "spec_off"
    assert bo.evaluate(8.8, calm)["to"] == "normal"
    assert [e["stage"] for e in bo.events] == [1, 2, 3, 4, 3, 2, 1, 0]


def test_brownout_kv_pressure_and_middle_ground_hold():
    bo = BrownoutController(slo=1.0, kv_low_frac=0.10, kv_calm_frac=0.30,
                            cooldown_s=0.0, check_interval_s=0.0)
    # KV starvation alone escalates, even at a healthy forecast
    assert bo.evaluate(0.0, [_snap(ttft=0.1, kv=0.05)])["to"] == "spec_off"
    # neither pressure nor calm (kv between low and calm): hold the rung
    assert bo.evaluate(1.0, [_snap(ttft=0.1, kv=0.2)]) is None
    assert bo.stage == 1
    # fully calm: unwind
    assert bo.evaluate(2.0, [_snap(ttft=0.1, kv=0.5)])["to"] == "normal"


def test_brownout_rung_queries_and_shed_class_order():
    bo = BrownoutController(slo=1.0, best_effort_cap=16,
                            cooldown_s=0.0, check_interval_s=0.0)
    ia = Request(0, 0.0, 8, 8, slo=0.5, priority="interactive")
    ba = Request(1, 0.0, 8, 8, slo=3.0, priority="batch")
    be = Request(2, 0.0, 8, 8, priority="best_effort")
    hot = [_snap(ttft=5.0)]
    for _ in range(3):
        bo.evaluate(bo.stage, hot)
    assert bo.spec_off and bo.offload_draft
    assert bo.output_cap_for("best_effort") == 16
    assert bo.output_cap_for("interactive") is None
    # below the shed rung nothing sheds
    assert not bo.should_shed(be, 100.0)
    bo.evaluate(3.0, hot)
    assert bo.stage_name == "shed"
    assert bo.should_shed(be, 0.0)                 # best_effort: always
    assert bo.should_shed(ba, 5.0)                 # batch: forecast > slo
    assert not bo.should_shed(ba, 1.0)             # batch: still viable
    assert not bo.should_shed(ia, 100.0)           # interactive: never
    assert bo.shed_count == 2
    with pytest.raises(ValueError):
        BrownoutController(slo=0.0)
    with pytest.raises(ValueError):
        BrownoutController(enter_factor=1.0, exit_factor=1.0)
    with pytest.raises(ValueError):
        BrownoutController(kv_low_frac=0.5, kv_calm_frac=0.1)


def test_brownout_check_interval_prefilter():
    bo = BrownoutController(check_interval_s=0.25)
    assert bo.due(0.0)
    bo.evaluate(0.0, [_snap()])
    assert not bo.due(0.1)
    assert bo.due(0.25)


# ---------------------------------------------------------------------------
# fault grammar: cancelstorm + seeded retry jitter
# ---------------------------------------------------------------------------


def test_cancelstorm_grammar_and_validation():
    plan = FaultPlan.parse("cancelstorm:0.25@2.0..6.0;crash:1@3.0")
    assert plan.cancelstorms == (CancelStorm(0.25, 2.0, 6.0),)
    assert len(plan.crashes) == 1
    assert not plan.empty
    assert FaultPlan.parse("cancelstorm:0.25@2.0..6.0") \
        == FaultPlan.parse("cancelstorm:0.25@2.0..6.0")
    with pytest.raises(ValueError):
        FaultPlan.parse("cancelstorm:0@1..2")      # frac must be > 0
    with pytest.raises(ValueError):
        FaultPlan.parse("cancelstorm:1.5@1..2")    # frac must be <= 1
    with pytest.raises(ValueError):
        FaultPlan.parse("cancelstorm:0.5@5..2")    # end must be > start
    with pytest.raises(ValueError):
        FaultPlan.parse("cancelstorm:0.5@3")       # missing window


def test_pick_cancel_victims_deterministic_and_rng_isolated():
    storm = CancelStorm(0.5, 2.0, 6.0)
    live = set(range(20))
    a = FaultInjector(FaultPlan(cancelstorms=(storm,)), seed=7)
    b = FaultInjector(FaultPlan(cancelstorms=(storm,)), seed=7)
    va, vb = a.pick_cancel_victims(storm, live), \
        b.pick_cancel_victims(storm, live)
    assert va == vb and len(va) == 10
    assert all(2.0 <= t <= 6.0 for t, _ in va)
    assert va == sorted(va)
    assert a.stats["storm_cancels"] == 10
    assert a.pick_cancel_victims(storm, set()) == []
    # dedicated RNG stream: drawing storm victims never perturbs the
    # corruption/crash draws, so adding a storm to an existing chaos plan
    # keeps its golden streams byte-identical
    c = FaultInjector(FaultPlan(cancelstorms=(storm,)), seed=7)
    before = c.rng.random(4).tolist()
    d = FaultInjector(FaultPlan(cancelstorms=(storm,)), seed=7)
    d.pick_cancel_victims(storm, live)
    assert d.rng.random(4).tolist() == before
    # the storm appears in the timed-event schedule at its start
    assert ("cancelstorm" in {k for _, k, _ in a.timed_events()})


def test_retry_backoff_jitter_optin_and_deterministic():
    plain = RetryPolicy()
    assert plain.backoff(1) == pytest.approx(0.05)   # pinned schedule
    jit = RetryPolicy(jitter_frac=0.2)
    # without an rng the jittered policy still returns the base schedule
    assert jit.backoff(1) == pytest.approx(0.05)
    r1 = np.random.default_rng(3)
    r2 = np.random.default_rng(3)
    s1 = [jit.backoff(a, rng=r1) for a in range(1, 6)]
    s2 = [jit.backoff(a, rng=r2) for a in range(1, 6)]
    assert s1 == s2                                  # seeded: replayable
    base = [plain.backoff(a) for a in range(1, 6)]
    assert s1 != base                                # jitter actually moves
    for got, b in zip(s1, base):
        assert b * 0.8 <= got <= b * 1.2             # bounded by frac
    with pytest.raises(ValueError):
        RetryPolicy(jitter_frac=1.0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter_frac=-0.1)


# ---------------------------------------------------------------------------
# contraction regression: shared prefix blocks migrate exactly once
# ---------------------------------------------------------------------------


def test_contraction_migrates_shared_blocks_once():
    """A CoW-shared prefix block (refcount > 1) above the contraction
    boundary appears in several tables but must migrate ONCE: the old
    per-reference evict list reserved one dst per REFERENCE, the mapping
    collapsed, and the surplus dst block stranded in no tier (caught by
    I8)."""
    bm = BlockManager(4, 4, prefix_caching=True)
    bm.allocate(99, 16)                # fill the base pool (blocks 0-3)
    bm.expand(4)                       # attach blocks 4-7
    toks = list(range(8))
    bm.allocate(1, 8)                  # lands above the boundary
    bm.register_prefix(1, toks, 8)
    blocks, matched = bm.match_prefix(toks)
    assert matched == 8
    bm.share(2, blocks, 8)             # refcount 2 on both high blocks
    assert all(bm.refcount[b] == 2 for b in blocks)
    bm.release(99)                     # room below the boundary
    plan = bm.plan_contraction()
    assert plan is not None
    assert len(plan.src) == len(set(plan.src)) == 2
    bm.commit_contraction(plan)
    bm.check_invariants()              # I8: no block stranded in no tier
    assert bm.total_blocks == 4
    assert bm.tables[1] == bm.tables[2]
    assert all(b < 4 for b in bm.tables[1])


# ---------------------------------------------------------------------------
# surge workload: seeded classes, deadlines, cancellation storms
# ---------------------------------------------------------------------------


def test_surge_workload_deterministic_and_classed():
    trace = surge_trace(base=10.0, surge_mult=3.0, base_s=2.0, surge_s=4.0,
                        recover_s=2.0, seed=5)
    a = surge_requests(160, trace=trace, dataset="alpaca", seed=3)
    b = surge_requests(160, trace=trace, dataset="alpaca", seed=3)
    assert [(r.req_id, r.arrival, r.priority, r.slo, r.deadline)
            for r in a] == \
        [(r.req_id, r.arrival, r.priority, r.slo, r.deadline) for r in b]
    classes = {r.priority for r in a}
    assert classes <= set(SURGE_CLASSES)
    assert len(classes) >= 2
    for r in a:
        slo, dl = SURGE_CLASSES[r.priority][1], SURGE_CLASSES[r.priority][2]
        assert r.slo == slo and r.deadline == dl
    # the plateau is actually ~3x the baseline arrival density
    mid = sum(1 for r in a if 2.0 <= r.arrival < 6.0) / 4.0
    lo = sum(1 for r in a if r.arrival < 2.0) / 2.0
    assert mid > 1.5 * max(lo, 1.0)


def test_cancellation_storm_seeded_and_bounded():
    reqs = poisson_requests(20, 40, dataset="alpaca", seed=1)
    a = cancellation_storm(reqs, frac=0.25, start=0.5, end=1.5, seed=9)
    assert a == cancellation_storm(reqs, frac=0.25, start=0.5, end=1.5,
                                   seed=9)
    assert a == sorted(a)
    ids = {r.req_id for r in reqs}
    arrivals = {r.req_id: r.arrival for r in reqs}
    for t, rid in a:
        assert rid in ids
        assert t > arrivals[rid]          # never before the client sent it
    with pytest.raises(ValueError):
        cancellation_storm(reqs, frac=0.0)
    with pytest.raises(ValueError):
        cancellation_storm(reqs, frac=0.5, start=2.0, end=1.0)


# ---------------------------------------------------------------------------
# cluster e2e: cancel-at-every-step soak + survivor stream identity
# ---------------------------------------------------------------------------


def test_cancel_at_every_step_soak():
    """Cancelling any subset of requests at ANY instant of the run leaks
    nothing and never perturbs the SURVIVORS' committed streams."""
    reqs = poisson_requests(25, 60, dataset="alpaca", seed=2)
    base = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    base_toks = {r.req_id: r.tokens for r in base.requests}
    victims = [5, 17, 33, 48]
    for t in np.arange(0.25, 3.1, 0.4):
        cl = build_sim_cluster(_cfg(), 2, "nightjar",
                               cancels=[(float(t), v) for v in victims])
        m = cl.run(list(reqs))
        cancelled = {c["req_id"] for c in m.cancelled}
        finished = {r.req_id for r in m.requests}
        # accounted: every request is in exactly one terminal bucket
        assert len(finished) + len(cancelled) == 60, f"t={t}"
        assert finished.isdisjoint(cancelled)
        # survivors commit byte-identical streams
        for r in m.requests:
            assert r.tokens == base_toks[r.req_id], f"drift at t={t}"
        _check_all(cl)


def test_cluster_cancelstorm_fault_spec_composes_with_chaos():
    """The cancelstorm grammar rides the fault injector: composable with a
    crash in the same plan, deterministic for a fixed seed, and nothing
    double-counts across terminal buckets."""
    reqs = poisson_requests(20, 80, dataset="alpaca", seed=1)
    plan = "cancelstorm:0.3@1.0..3.0;crash:1@2.0"
    runs = []
    for _ in range(2):
        cl = build_sim_cluster(_cfg(), 2, "nightjar", fault_plan=plan)
        m = cl.run(list(reqs))
        buckets = (len(m.requests), len(m.cancelled),
                   len(m.failed_requests), len(m.expired))
        assert sum(buckets) == 80
        assert len(m.crashes) == 1
        assert cl.faults.stats["storm_cancels"] > 0
        _check_all(cl)
        runs.append((_sha(m), buckets,
                     sorted(c["req_id"] for c in m.cancelled)))
    assert runs[0] == runs[1]


def test_cluster_brownout_events_observable_and_applied():
    """An aggressive ladder under a modest stream transitions observably,
    applies its rungs to every live replica, and the metrics summary
    carries the timeline."""
    bo = BrownoutController(slo=0.001, enter_factor=1.01, exit_factor=0.5,
                            cooldown_s=0.1, check_interval_s=0.05)
    reqs = poisson_requests(30, 60, dataset="alpaca", seed=3)
    cl = build_sim_cluster(_cfg(), 2, "nightjar", brownout=bo)
    m = cl.run(list(reqs))
    fired = [e["to"] for e in m.brownout_events]
    assert "spec_off" in fired and "draft_offload" in fired
    for e in m.brownout_events:
        assert set(e) >= {"at", "from", "to", "stage", "predicted_ttft",
                          "kv_headroom"}
    s = m.summary()
    assert s["brownout"]["transitions"] == len(m.brownout_events)
    assert "spec_off" in s["brownout"]["stages_entered"]
    _check_all(cl)


def test_cluster_class_summary_accounts_every_request():
    trace = surge_trace(base=15.0, surge_mult=3.0, base_s=2.0, surge_s=4.0,
                        recover_s=2.0, seed=5)
    reqs = surge_requests(100, trace=trace, dataset="alpaca", seed=3)
    cancels = cancellation_storm(reqs, frac=0.2, start=1.0, end=5.0, seed=6)
    cl = build_sim_cluster(_cfg(), 2, "nightjar", shed_factor=1.5,
                           class_weights={"interactive": 2.0,
                                          "best_effort": 0.5},
                           cancels=cancels)
    m = cl.run(list(reqs))
    pc = m.class_summary()
    assert sum(b["offered"] for b in pc.values()) == 100
    for b in pc.values():
        assert b["offered"] == (b["finished"] + b["shed"] + b["cancelled"]
                                + b["expired"] + b["failed"])
    assert sum(b["cancelled"] for b in pc.values()) == len(m.cancelled)
    _check_all(cl)
