"""Tests for rejection-sampling verification (losslessness), hypothesis-free.

The key theorem (Leviathan et al.): for any draft distribution q and target
distribution p, the committed token at each position is distributed exactly
as p.  We verify this by Monte-Carlo on enumerable vocabularies with seeded
parametrized cases; the hypothesis-generated versions live in
tests/test_verify_properties.py (optional tier).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.verify import verify_greedy, verify_rejection


def _dist(rng, V, temp):
    x = rng.normal(size=V) * temp
    e = np.exp(x - x.max())
    return e / e.sum()


@pytest.mark.parametrize("seed,vocab,temp", [(0, 2, 0.5), (1, 4, 1.0),
                                             (2, 6, 2.5), (3, 3, 0.8)])
def test_first_position_distribution_preserved(seed, vocab, temp):
    """Empirical distribution of the first committed token ~= target p."""
    rng = np.random.default_rng(seed)
    p = _dist(rng, vocab, temp)
    q = _dist(rng, vocab, temp * 2)

    N = 20_000
    g = 1
    key = jax.random.PRNGKey(seed)
    kd, kv = jax.random.split(key)
    draft_tokens = jax.random.categorical(
        kd, jnp.log(jnp.asarray(q))[None, :].repeat(N, 0))[:, None]
    draft_probs = jnp.broadcast_to(jnp.asarray(q), (N, g, vocab))
    # target gives p at the draft position and at the bonus position
    target_probs = jnp.broadcast_to(jnp.asarray(p), (N, g + 1, vocab))

    res = verify_rejection(kv, draft_tokens, draft_probs, target_probs)
    first = np.asarray(res["tokens"][:, 0])
    emp = np.bincount(first, minlength=vocab) / N
    assert np.max(np.abs(emp - p)) < 0.02, (emp, p)


@pytest.mark.parametrize("seed,vocab,g", [(0, 2, 1), (1, 4, 2), (2, 8, 4),
                                          (3, 5, 3), (4, 3, 1)])
def test_committed_structure_invariants(seed, vocab, g):
    """n_accepted in [0, g]; committed = accepted prefix + 1 sampled token;
    padding is -1 beyond n_accepted+1."""
    rng = np.random.default_rng(seed)
    B = 16
    key = jax.random.PRNGKey(seed)
    draft_tokens = jnp.asarray(rng.integers(0, vocab, size=(B, g)))
    dp = rng.dirichlet(np.ones(vocab), size=(B, g))
    tp = rng.dirichlet(np.ones(vocab), size=(B, g + 1))
    res = verify_rejection(key, draft_tokens, jnp.asarray(dp), jnp.asarray(tp))
    n = np.asarray(res["n_accepted"])
    toks = np.asarray(res["tokens"])
    assert ((0 <= n) & (n <= g)).all()
    for b in range(B):
        # accepted prefix equals the draft tokens
        assert (toks[b, :n[b]] == np.asarray(draft_tokens)[b, :n[b]]).all()
        # exactly one sampled token after the prefix
        assert toks[b, n[b]] >= 0
        assert (toks[b, n[b] + 1:] == -1).all()
        assert toks[b, n[b]] == int(res["next_token"][b])


def test_identical_models_accept_everything():
    """If q == p, every draft token is accepted (ratio = 1)."""
    V, g, B = 16, 4, 8
    rng = np.random.default_rng(0)
    p = rng.dirichlet(np.ones(V), size=(B, g + 1))
    draft_probs = jnp.asarray(p[:, :g])
    key = jax.random.PRNGKey(1)
    draft_tokens = jax.random.categorical(key, jnp.log(draft_probs))
    res = verify_rejection(key, draft_tokens, draft_probs, jnp.asarray(p))
    assert (np.asarray(res["n_accepted"]) == g).all()


def test_disjoint_support_rejects_everything():
    """If p puts zero mass on drafted tokens, n_accepted == 0 and the
    correction comes from p."""
    V, g, B = 4, 3, 64
    q = jnp.asarray([1.0, 0.0, 0.0, 0.0])
    p = jnp.asarray([0.0, 0.0, 0.5, 0.5])
    draft_tokens = jnp.zeros((B, g), jnp.int32)
    dp = jnp.broadcast_to(q, (B, g, V))
    tp = jnp.broadcast_to(p, (B, g + 1, V))
    res = verify_rejection(jax.random.PRNGKey(0), draft_tokens, dp, tp)
    assert (np.asarray(res["n_accepted"]) == 0).all()
    nxt = np.asarray(res["next_token"])
    assert np.isin(nxt, [2, 3]).all()


def test_greedy_verification_exact():
    """Greedy verify accepts exactly the matching prefix and corrects with
    the target argmax."""
    V, g = 8, 3
    B = 4
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(B, g + 1, V)).astype(np.float32))
    tgt = np.asarray(jnp.argmax(logits, -1))
    draft = tgt[:, :g].copy()
    draft[1, 1] = (draft[1, 1] + 1) % V  # inject one mismatch
    draft[3, 0] = (draft[3, 0] + 1) % V
    res = verify_greedy(jnp.asarray(draft), logits)
    n = np.asarray(res["n_accepted"])
    assert n[0] == g and n[2] == g
    assert n[1] == 1 and n[3] == 0
    assert int(res["next_token"][1]) == tgt[1, 1]
    assert int(res["next_token"][0]) == tgt[0, g]


@pytest.mark.parametrize("seed,g", [(0, 1), (1, 2), (2, 3), (3, 4)])
def test_greedy_acceptance_invariants(seed, g):
    """For random drafts, greedy verify accepts exactly the longest prefix
    matching the target argmax and corrects with the argmax after it."""
    V, B = 6, 8
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(B, g + 1, V)).astype(np.float32))
    tgt = np.asarray(jnp.argmax(logits, -1))
    draft = rng.integers(0, V, size=(B, g))
    res = verify_greedy(jnp.asarray(draft), logits)
    n = np.asarray(res["n_accepted"])
    for b in range(B):
        expect = 0
        while expect < g and draft[b, expect] == tgt[b, expect]:
            expect += 1
        assert n[b] == expect
        assert int(res["next_token"][b]) == tgt[b, n[b]]
