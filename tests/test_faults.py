"""Fault tolerance: deterministic injection, lossless crash recovery,
host-KV integrity and the chaos determinism contract.

The golden e2e here is the chaos gate: a mid-run replica crash must lose
ZERO requests — every in-flight request re-queues through the router with
backoff, re-prefills from its prompt, and finishes with a committed token
stream byte-identical to the fault-free run.  Invariants I1-I7 stay clean,
including I7 (a FAILED replica owns no blocks, no pinned host records and
no pending transfers).
"""
import hashlib

import numpy as np
import pytest

from repro import configs
from repro.serving.cluster import FAILED, ServingCluster
from repro.serving.controlplane import FailureDetector
from repro.serving.costmodel import RTX_4090
from repro.serving.faults import (CorruptionFault, CrashFault, FaultInjector,
                                  FaultPlan, HandoffFault, RetryPolicy,
                                  StragglerFault)
from repro.serving.kv_cache import (BlockManager, HostKVStore,
                                    record_checksum)
from repro.serving.simulator import SimConfig, build_sim_cluster
from repro.serving.workload import (mixed_requests, poisson_requests,
                                    session_requests)

BS = 4


def _cfg(**kw):
    kw.setdefault("max_batch", 256)
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, seed=0, **kw)


def _sha(m):
    stream = sorted((r.req_id, r.tokens) for r in m.requests)
    return hashlib.sha256(repr(stream).encode()).hexdigest()[:16]


def _check_all(cl: ServingCluster):
    for i, eng in enumerate(cl.replicas):
        eng.scheduler.bm.check_invariants(failed=cl.state[i] == FAILED)


# ---------------------------------------------------------------------------
# FaultPlan validation + spec grammar
# ---------------------------------------------------------------------------


def test_plan_validation_rejects_bad():
    with pytest.raises(ValueError):
        FaultPlan(crashes=(CrashFault(0, -1.0),))
    with pytest.raises(ValueError):
        FaultPlan(crashes=(CrashFault(-1, 1.0),))
    with pytest.raises(ValueError):          # a crashed replica stays dead
        FaultPlan(crashes=(CrashFault(0, 1.0), CrashFault(0, 2.0)))
    with pytest.raises(ValueError):
        FaultPlan(stragglers=(StragglerFault(0, 2.0, 1.0, 2.0),))
    with pytest.raises(ValueError):
        FaultPlan(stragglers=(StragglerFault(0, 1.0, 2.0, 0.5),))
    with pytest.raises(ValueError):
        FaultPlan(handoffs=(HandoffFault(1.0, 2.0, mode="explode"),))
    with pytest.raises(ValueError):
        FaultPlan(corruptions=(CorruptionFault(0, 1.0, count=0),))
    # two crashes on DIFFERENT replicas are fine
    FaultPlan(crashes=(CrashFault(0, 1.0), CrashFault(1, 2.0)))


def test_plan_parse_grammar():
    plan = FaultPlan.parse("crash:1@2.5;straggle:0@1..3x4;"
                           "handoff:timeout@2..4#2;corrupt:0@5#3")
    assert plan.crashes == (CrashFault(1, 2.5),)
    assert plan.stragglers == (StragglerFault(0, 1.0, 3.0, 4.0),)
    assert plan.handoffs == (HandoffFault(2.0, 4.0, mode="timeout", count=2),)
    assert plan.corruptions == (CorruptionFault(0, 5.0, count=3),)
    assert not plan.empty
    assert FaultPlan.parse("").empty
    with pytest.raises(ValueError):
        FaultPlan.parse("explode:0@1")
    with pytest.raises(ValueError):
        FaultPlan.parse("crash:0")          # missing @time
    with pytest.raises(ValueError):
        FaultPlan.parse("crash:0@1;crash:0@2")  # validated after parse too


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------


def test_retry_backoff_schedule():
    rp = RetryPolicy(budget=3, backoff_base=0.05, backoff_cap=1.0)
    assert rp.backoff(1) == pytest.approx(0.05)
    assert rp.backoff(2) == pytest.approx(0.10)
    assert rp.backoff(3) == pytest.approx(0.20)
    assert rp.backoff(10) == 1.0            # capped
    assert not rp.exhausted(3)
    assert rp.exhausted(4)
    with pytest.raises(ValueError):
        rp.backoff(0)                       # attempts are 1-based
    with pytest.raises(ValueError):
        RetryPolicy(budget=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_base=0.0)


# ---------------------------------------------------------------------------
# FaultInjector determinism
# ---------------------------------------------------------------------------


def test_injector_timed_events_and_multiplier():
    plan = FaultPlan.parse("crash:1@2;corrupt:0@1;"
                           "straggle:0@1..3x2;straggle:0@2..4x3")
    inj = FaultInjector(plan, seed=0)
    assert [(t, k) for t, k, _ in inj.timed_events()] == [
        (1.0, "corrupt"), (2.0, "crash")]
    assert inj.latency_multiplier(0, 0.5) == 1.0
    assert inj.latency_multiplier(0, 1.5) == 2.0
    assert inj.latency_multiplier(0, 2.5) == 6.0   # windows compound
    assert inj.latency_multiplier(0, 3.5) == 3.0
    assert inj.latency_multiplier(1, 2.5) == 1.0   # other replica untouched


def test_injector_handoff_budget_consumed():
    plan = FaultPlan.parse("handoff:fail@1..5#2")
    inj = FaultInjector(plan, seed=0)
    assert inj.next_handoff_fault(0.5) is None     # outside the window
    assert inj.next_handoff_fault(1.5) is not None
    assert inj.next_handoff_fault(2.0) is not None
    assert inj.next_handoff_fault(3.0) is None     # budget drained
    assert inj.stats["handoff_faults"] == 2
    # count <= 0 is unbounded
    inj2 = FaultInjector(FaultPlan.parse("handoff:fail@1..5"), seed=0)
    assert all(inj2.next_handoff_fault(2.0) for _ in range(10))


def test_injector_corruption_seeded():
    def store():
        hs = HostKVStore(16)
        for h in range(8):
            hs.put(h, h - 1 if h else 0, (h, h + 1))
        hs.pin(3)
        return hs

    fault = CorruptionFault(0, 1.0, count=4)
    h1, h2 = store(), store()
    assert FaultInjector(FaultPlan(), seed=7).corrupt_host_records(
        h1, fault) == 4
    FaultInjector(FaultPlan(), seed=7).corrupt_host_records(h2, fault)
    bad1 = {h for h in h1.records if not h1.verify(h)}
    bad2 = {h for h in h2.records if not h2.verify(h)}
    assert bad1 == bad2 and len(bad1) == 4         # seeded, reproducible
    assert 3 not in bad1                           # pinned: never corrupted


# ---------------------------------------------------------------------------
# FailureDetector
# ---------------------------------------------------------------------------


def test_failure_detector_semantics():
    det = FailureDetector(timeout_s=0.5)
    det.heartbeat(0, 1.0)
    det.heartbeat(0, 0.5)                          # stale: ignored
    assert det.silent_for(0, 1.4) == pytest.approx(0.4)
    assert det.suspects(1.4, [0]) == []
    assert det.suspects(1.6, [0]) == [0]
    # a never-seen replica's birth counts as its first heartbeat
    assert det.silent_for(9, 3.0) == 0.0
    assert det.suspects(3.0, [9]) == []
    assert det.suspects(3.6, [9]) == [9]
    with pytest.raises(ValueError):
        FailureDetector(timeout_s=0.0)


# ---------------------------------------------------------------------------
# Golden chaos e2e: lossless crash recovery (tier-1 gate)
# ---------------------------------------------------------------------------


def test_crash_recovery_streams_identical():
    """Mid-run crash: every affected request is re-dispatched and the
    committed streams are byte-identical to the fault-free run."""
    reqs = poisson_requests(20, 120, dataset="alpaca", seed=1)
    base = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    assert len(base.requests) == 120

    cl = build_sim_cluster(_cfg(), 2, "nightjar", fault_plan="crash:1@2.0")
    m = cl.run(list(reqs))

    assert len(m.requests) == 120                  # zero dropped
    assert _sha(m) == _sha(base)                   # byte-identical streams
    assert len(m.crashes) == 1
    c = m.crashes[0]
    assert c["replica"] == 1 and c["lost"] > 0
    assert c["detected_at"] >= c["at"] + cl.control.detector.timeout_s
    assert c["recovered_at"] >= c["detected_at"]
    assert m.requeues == c["lost"] and m.retries >= m.requeues
    assert m.failed_requests == []
    assert m.mttd is not None and m.mttd > 0
    assert m.mttr is not None and m.mttr >= m.mttd
    assert m.recovery_seconds == pytest.approx(m.mttr)
    # the crashed replica is FAILED and a replacement was spawned
    assert cl.state[1] == FAILED
    assert len(cl.replicas) == 3
    _check_all(cl)                                 # I1-I7, incl. failed=True
    s = m.summary()
    assert s["faults"]["requests_lost"] == c["lost"]
    assert s["faults"]["failed_requests"] == 0
    assert s["faults"]["mttr_s"] == pytest.approx(m.mttr, abs=1e-4)


def test_crash_run_deterministic():
    """Two runs of the same plan + seed are byte-identical."""
    reqs = poisson_requests(20, 80, dataset="alpaca", seed=1)
    runs = [build_sim_cluster(_cfg(), 2, "nightjar",
                              fault_plan="crash:0@1.5").run(list(reqs))
            for _ in range(2)]
    assert _sha(runs[0]) == _sha(runs[1])
    assert runs[0].summary() == runs[1].summary()


def test_empty_plan_is_faultfree():
    """An empty fault plan leaves the event loop byte-identical to no
    plan at all (the golden-preserving determinism contract)."""
    reqs = poisson_requests(20, 60, dataset="alpaca", seed=1)
    m0 = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    m1 = build_sim_cluster(_cfg(), 2, "nightjar", fault_plan="").run(
        list(reqs))
    assert m0.summary() == m1.summary()


def test_crash_at_every_step_soak():
    """Crashing at any point of the run never drops a request and never
    changes the committed streams."""
    reqs = poisson_requests(25, 60, dataset="alpaca", seed=2)
    base = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    sha0 = _sha(base)
    for t in np.arange(0.25, 3.1, 0.4):
        cl = build_sim_cluster(_cfg(), 2, "nightjar",
                               fault_plan=f"crash:1@{t:.2f}")
        m = cl.run(list(reqs))
        assert len(m.requests) == 60, f"dropped requests at crash t={t}"
        assert _sha(m) == sha0, f"stream drift at crash t={t}"
        assert m.failed_requests == []
        _check_all(cl)


def test_retry_budget_exhaustion_surfaces_failed():
    """With a zero retry budget every crash-lost request is surfaced as
    failed in metrics — never silently dropped."""
    reqs = poisson_requests(20, 80, dataset="alpaca", seed=1)
    cl = build_sim_cluster(_cfg(), 2, "nightjar", fault_plan="crash:1@2.0",
                           retry_policy=RetryPolicy(budget=0))
    m = cl.run(list(reqs))
    lost = m.crashes[0]["lost"]
    assert lost > 0
    assert len(m.failed_requests) == lost
    assert m.requeues == 0
    assert len(m.requests) == 80 - lost            # accounted, not dropped
    assert {f["req_id"] for f in m.failed_requests}.isdisjoint(
        {r.req_id for r in m.requests})
    assert m.summary()["faults"]["failed_requests"] == lost
    _check_all(cl)


def test_failed_replica_never_routed():
    """After the crash the FAILED replica receives no further work at any
    fallback tier (I7 stays clean through the rest of the run)."""
    reqs = poisson_requests(20, 100, dataset="alpaca", seed=3)
    cl = build_sim_cluster(_cfg(), 2, "nightjar", fault_plan="crash:0@1.0")
    m = cl.run(list(reqs))
    dead = cl.replicas[0]
    assert dead.failed and cl.state[0] == FAILED
    assert not dead.scheduler.num_running and not dead.scheduler.waiting
    bm = dead.scheduler.bm
    assert len(bm.free) == bm.total_blocks
    bm.check_invariants(failed=True)
    assert len(m.requests) == 100


# ---------------------------------------------------------------------------
# I7: force_fail releases everything (crash-release accounting)
# ---------------------------------------------------------------------------


def test_force_fail_releases_everything():
    """Killing a replica mid-flight with prefix caching + host offload in
    play leaves zero owned blocks, zero pinned host records and empty
    transfer queues (invariant I7)."""
    cfg = _cfg(chunk_tokens=256, prefix_caching=True, kv_offload=True,
               num_blocks=160, host_kv_blocks=512)
    cl = build_sim_cluster(cfg, 2, "nightjar", router="affinity")
    reqs = session_requests(8, rate_qps=2.0, seed=2)
    for r in reqs:
        cl.submit(r, now=r.arrival)
    # step both replicas into a busy mid-run state
    for _ in range(60):
        evs = [(e.peek_next_event(), i) for i, e in enumerate(cl.replicas)]
        evs = [(t, i) for t, i in evs if t is not None]
        if not evs:
            break
        _, i = min(evs)
        cl.replicas[i].step()
    eng = max(cl.replicas, key=lambda e: e.scheduler.num_running)
    lost = eng.force_fail()
    assert eng.failed
    assert [r.req_id for r in lost] == sorted(r.req_id for r in lost)
    bm = eng.scheduler.bm
    assert len(bm.free) == bm.total_blocks
    assert not bm.pending_spills and not bm.pending_restores
    assert not bm.pending_copies
    assert not bm.host_store.pinned
    bm.check_invariants(failed=True)
    # lost requests re-run from scratch on the OTHER replica just fine
    other = next(e for e in cl.replicas if e is not eng)
    for r in lost:
        other.submit(r)
    while other.peek_next_event() is not None:
        other.step()
    other.scheduler.bm.check_invariants()


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------


def test_straggler_injects_latency_streams_unchanged():
    reqs = poisson_requests(20, 60, dataset="alpaca", seed=1)
    base = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    cl = build_sim_cluster(_cfg(), 2, "nightjar",
                           fault_plan="straggle:0@0.5..2.5x4")
    m = cl.run(list(reqs))
    assert cl.replicas[0].metrics.fault_injected_s > 0
    assert cl.replicas[1].metrics.fault_injected_s == 0
    assert len(m.requests) == 60
    assert _sha(m) == _sha(base)                   # latency-only fault
    inj = cl.replicas[0].metrics.fault_injected_s
    assert cl.replicas[0].metrics.summary()["fault_injected_s"] == \
        pytest.approx(inj, abs=1e-4)


# ---------------------------------------------------------------------------
# Handoff transfer faults (disaggregated fleets)
# ---------------------------------------------------------------------------


def test_handoff_fault_retry_then_abort():
    cfg = _cfg(chunk_tokens=128, max_batch=48)
    reqs = mixed_requests(10.0, 60, seed=3)
    # unbounded failure window covering the whole run: every candidate
    # handoff exhausts its retries and falls back to colocated decode
    cl = build_sim_cluster(cfg, 4, "nightjar",
                           disaggregate=dict(prefill=2, decode=2),
                           fault_plan="handoff:fail@0..1e9")
    m = cl.run(list(reqs))
    assert len(m.requests) == 60                   # fallback loses nothing
    assert m.handoff_aborts > 0
    assert len(m.handoffs) == 0                    # nothing ever transferred
    assert m.handoff_failures == m.handoff_aborts * (cl.handoff_max_retries
                                                     + 1)
    assert m.handoff_retries == m.handoff_aborts * cl.handoff_max_retries
    s = m.summary()
    assert s["disagg"]["transfer_aborts"] == m.handoff_aborts


def test_handoff_fault_bounded_budget_is_outlasted():
    cfg = _cfg(chunk_tokens=128, max_batch=48)
    reqs = mixed_requests(10.0, 60, seed=3)
    base = build_sim_cluster(cfg, 4, "nightjar",
                             disaggregate=dict(prefill=2, decode=2))
    mb = base.run(list(reqs))
    cl = build_sim_cluster(cfg, 4, "nightjar",
                           disaggregate=dict(prefill=2, decode=2),
                           fault_plan="handoff:timeout@0..1e9#2")
    m = cl.run(list(reqs))
    assert len(m.requests) == 60
    assert m.handoff_timeouts == 2                 # budget fully consumed
    assert m.handoff_aborts == 0                   # retries outlasted it
    assert len(m.handoffs) == len(mb.handoffs)     # same transfers land
    assert _sha(m) == _sha(mb)


# ---------------------------------------------------------------------------
# Host-KV integrity: checksums, corruption, restore-time drop
# ---------------------------------------------------------------------------


def test_record_checksum_sensitivity():
    data = {"k": np.arange(8, dtype=np.float32)}
    c = record_checksum(5, (1, 2, 3), data)
    assert c == record_checksum(5, (1, 2, 3), data)
    assert c != record_checksum(6, (1, 2, 3), data)
    assert c != record_checksum(5, (1, 2, 4), data)
    bad = {"k": data["k"].copy()}
    bad["k"][0] += 1
    assert c != record_checksum(5, (1, 2, 3), bad)


def test_host_store_corrupt_verify_drop():
    hs = HostKVStore(8)
    hs.put(1, 0, (10, 11, 12, 13))
    assert hs.verify(1)
    assert hs.corrupt(1)
    assert not hs.verify(1)
    hs.put(2, 1, (20, 21))
    hs.pin(2)
    assert not hs.corrupt(2)                       # pinned: refused
    assert hs.verify(2)
    hs.drop_corrupt(1)
    assert 1 not in hs.records
    assert hs.stats["corrupt_dropped"] == 1
    assert not hs.verify(1)                        # gone = not verifiable


def test_corrupt_record_dropped_on_restore():
    """A corrupted host record is detected by its checksum at restore
    time, dropped (counted), and the prefix cold-re-prefills instead of
    serving poisoned KV."""
    rng = np.random.default_rng(0)
    hs = HostKVStore(64)
    bm = BlockManager(8, BS, prefix_caching=True, host_store=hs)
    tokens = [int(t) for t in rng.integers(0, 1000, size=3 * BS)]
    bm.allocate(0, len(tokens))
    bm.register_prefix(0, tokens, len(tokens))
    bm.release(0)
    bm.allocate(1, 8 * BS)                         # evict all 3 to host
    bm.drain_pending_spills()
    bm.release(1)
    assert len(hs.records) == 3

    victim = next(iter(hs.records))                # head of the chain walk
    assert hs.corrupt(victim)
    blocks, cached = bm.match_prefix(tokens)
    assert hs.stats["corrupt_dropped"] >= 1
    assert victim not in hs.records                # dropped, not served
    assert cached < len(tokens)                    # chain walk broke early
    bm.check_invariants()
    # cold re-admission of the un-cached tail works as usual
    if blocks:
        bm.share(2, blocks, cached)
        bm.grow_to(2, len(tokens))
    else:
        bm.allocate(2, len(tokens))
    bm.register_prefix(2, tokens, len(tokens))
    bm.check_invariants()


def test_corruption_fault_e2e_streams_unchanged():
    cfg = _cfg(chunk_tokens=256, prefix_caching=True, kv_offload=True,
               num_blocks=192, host_kv_blocks=512)
    reqs = session_requests(10, rate_qps=1.0, seed=2)
    base = build_sim_cluster(cfg, 2, "nightjar", router="affinity")
    mb = base.run(list(reqs))
    cl = build_sim_cluster(cfg, 2, "nightjar", router="affinity",
                           fault_plan="corrupt:0@20#8;corrupt:1@20#8")
    m = cl.run(list(reqs))
    assert cl.faults.stats["corrupted_records"] > 0
    assert len(m.requests) == len(mb.requests)
    assert _sha(m) == _sha(mb)                     # corruption never served
    _check_all(cl)


# ---------------------------------------------------------------------------
# n/a-by-contract: recovery metrics without faults
# ---------------------------------------------------------------------------


def test_mttr_na_when_no_faults():
    reqs = poisson_requests(20, 40, dataset="alpaca", seed=1)
    m = build_sim_cluster(_cfg(), 2, "nightjar").run(list(reqs))
    assert m.mttd is None and m.mttr is None
    assert m.recovery_seconds is None
    assert "faults" not in m.summary()             # nothing fired: no section


# ---------------------------------------------------------------------------
# CLI seed threading
# ---------------------------------------------------------------------------


def test_serve_cli_fault_plan(capsys, monkeypatch):
    """`--fault-plan` forces the cluster path even at --replicas 1 and the
    summary carries the fault section; same spec + seed reproduces."""
    import json

    from repro.launch import serve

    argv = ["serve", "--tier", "sim", "--arch", "paper-7b",
            "--hw", "rtx-4090", "--rate", "20", "--requests", "60",
            "--dataset", "alpaca", "--replicas", "2", "--seed", "0",
            "--fault-plan", "crash:1@1.5"]
    outs = []
    for _ in range(2):
        monkeypatch.setattr("sys.argv", list(argv))
        serve.main()
        outs.append(json.loads(capsys.readouterr().out))
    assert outs[0] == outs[1]
    assert outs[0]["faults"]["crashes"] == 1
    assert outs[0]["faults"]["failed_requests"] == 0


def test_serve_cli_rejects_bad_plan(monkeypatch):
    from repro.launch import serve
    monkeypatch.setattr("sys.argv", ["serve", "--tier", "sim",
                                     "--fault-plan", "crash:0@-1"])
    with pytest.raises(SystemExit):
        serve.main()
