"""Workload generation: seed determinism, length-distribution sanity,
dynamic-rate trace shape, per-dataset SLO attachment, templated prompts."""
import numpy as np
import pytest

from repro.serving.workload import (DATASETS, bursty_trace, dataset_slo,
                                    dynamic_rate_trace, poisson_requests,
                                    split_requests, templated_requests,
                                    tiny_requests)


def _fields(reqs):
    return [(r.req_id, r.arrival, r.prompt_len, r.output_len, r.alpha, r.slo)
            for r in reqs]


# ---------------------------------------------------------------------------
# seed determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dataset", sorted(DATASETS))
def test_poisson_requests_seed_deterministic(dataset):
    a = poisson_requests(12.0, 80, dataset=dataset, seed=7)
    b = poisson_requests(12.0, 80, dataset=dataset, seed=7)
    assert _fields(a) == _fields(b)
    c = poisson_requests(12.0, 80, dataset=dataset, seed=8)
    assert _fields(a) != _fields(c)


def test_split_requests_seed_deterministic():
    reqs = poisson_requests(10, 50, dataset="sharegpt", seed=3)
    a = split_requests(reqs, 4)
    b = split_requests(poisson_requests(10, 50, dataset="sharegpt", seed=3), 4)
    assert [[r.req_id for r in s] for s in a] == \
           [[r.req_id for r in s] for s in b]
    # every request lands in exactly one shard, shard sizes differ by <= 1
    ids = sorted(r.req_id for s in a for r in s)
    assert ids == sorted(r.req_id for r in reqs)
    sizes = [len(s) for s in a]
    assert max(sizes) - min(sizes) <= 1


def test_tiny_requests_deterministic():
    a = tiny_requests(8, seed=5)
    b = tiny_requests(8, seed=5)
    assert _fields(a) == _fields(b)
    assert [r.prompt_tokens for r in a] == [r.prompt_tokens for r in b]


# ---------------------------------------------------------------------------
# length-distribution sanity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dataset", sorted(DATASETS))
def test_length_distribution_bounds(dataset):
    reqs = poisson_requests(20.0, 300, dataset=dataset, seed=0,
                            max_prompt=512, max_output=256)
    for r in reqs:
        assert 4 <= r.prompt_len <= 512      # clipping bounds respected
        assert 4 <= r.output_len <= 256
        assert 0.0 < r.alpha < 1.0           # Beta acceptance in (0,1)
    # the clip must not collapse the distribution to a point
    assert len({r.prompt_len for r in reqs}) > 10
    assert len({r.output_len for r in reqs}) > 10


def test_arrivals_sorted_and_rate_scaled():
    reqs = poisson_requests(50.0, 400, dataset="alpaca", seed=2)
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    assert arr[0] > 0.0
    # 400 arrivals at 50 qps span roughly 8s (Poisson, generous bounds)
    assert 4.0 < arr[-1] < 16.0


# ---------------------------------------------------------------------------
# per-dataset SLO
# ---------------------------------------------------------------------------


def test_slo_attached_per_dataset():
    for ds, d in DATASETS.items():
        reqs = poisson_requests(10, 20, dataset=ds, seed=0)
        assert all(r.slo == d["slo_ttft"] for r in reqs)


def test_slo_override_and_disable():
    assert dataset_slo("sharegpt") == DATASETS["sharegpt"]["slo_ttft"]
    assert dataset_slo("sharegpt", 0.25) == 0.25
    assert dataset_slo("sharegpt", 0.0) is None     # <=0 disables
    reqs = poisson_requests(10, 10, dataset="alpaca", seed=0, slo=2.0)
    assert all(r.slo == 2.0 for r in reqs)
    reqs = poisson_requests(10, 10, dataset="alpaca", seed=0, slo=-1.0)
    assert all(r.slo is None for r in reqs)


# ---------------------------------------------------------------------------
# templated workload (prefix-sharing)
# ---------------------------------------------------------------------------


def test_templated_requests_share_exact_prefix():
    reqs = templated_requests(20, 40, template_len=128, seed=3)
    template = reqs[0].prompt_tokens[:128]
    for r in reqs:
        assert r.prompt_tokens[:128] == template       # byte-identical
        assert r.prompt_len == len(r.prompt_tokens) >= 128 + 4
        assert r.slo == DATASETS["templated"]["slo_ttft"]
    # suffixes genuinely vary (lognormal draw per request)
    assert len({len(r.prompt_tokens) for r in reqs}) > 5


def test_templated_requests_deterministic_and_disjoint_mode():
    a = templated_requests(15, 30, seed=7)
    b = templated_requests(15, 30, seed=7)
    assert [(r.arrival, r.prompt_tokens, r.output_len) for r in a] == \
        [(r.arrival, r.prompt_tokens, r.output_len) for r in b]
    # default template length comes from the dataset entry
    assert a[0].prompt_tokens[:512] == a[1].prompt_tokens[:512]
    # template_len=0: fully disjoint prompts of the same shape
    d = templated_requests(15, 30, template_len=0, seed=7)
    assert d[0].prompt_tokens[:4] != d[1].prompt_tokens[:4]


def test_templated_requests_multi_template():
    """num_templates > 1: every prompt starts with one of exactly K
    distinct template prefixes (the sticky-routing workload)."""
    reqs = templated_requests(20, 60, template_len=64, num_templates=4,
                              seed=5)
    prefixes = {tuple(r.prompt_tokens[:64]) for r in reqs}
    assert len(prefixes) == 4
    # the template id draw is seeded: identical across constructions
    again = templated_requests(20, 60, template_len=64, num_templates=4,
                               seed=5)
    assert [r.prompt_tokens for r in reqs] == \
        [r.prompt_tokens for r in again]
    # every template is actually used (60 draws over 4 ids)
    counts = {}
    for r in reqs:
        counts[tuple(r.prompt_tokens[:64])] = \
            counts.get(tuple(r.prompt_tokens[:64]), 0) + 1
    assert min(counts.values()) >= 1


def test_tiny_requests_template_prefix():
    reqs = tiny_requests(6, prompt_len=16, template_len=8, seed=2)
    t = reqs[0].prompt_tokens[:8]
    assert all(r.prompt_tokens[:8] == t for r in reqs)
    assert all(len(r.prompt_tokens) == 16 for r in reqs)
    suffixes = {tuple(r.prompt_tokens[8:]) for r in reqs}
    assert len(suffixes) > 1


# ---------------------------------------------------------------------------
# dynamic-rate trace
# ---------------------------------------------------------------------------


def test_dynamic_rate_trace_shape():
    trace = dynamic_rate_trace(duration_s=120.0, low=2.0, high=30.0,
                               period_s=40.0, seed=0)
    # sampled every period/8 seconds over the duration
    assert len(trace.times) == len(trace.rates) == 24
    assert list(trace.times) == sorted(trace.times)
    # rates stay inside the jittered [0.8*low, 1.2*high] envelope
    assert trace.rates.min() >= 0.8 * 2.0
    assert trace.rates.max() <= 1.2 * 30.0
    # both phases are represented
    assert trace.rates.min() < 2.0 * 1.2 < trace.rates.max()
    # rate_at is piecewise-constant lookup incl. before-first-knot clamping
    assert trace.rate_at(-1.0) == trace.rates[0]
    assert trace.rate_at(1e9) == trace.rates[-1]


def test_bursty_trace_phases_and_determinism():
    """Regime-shift trace: baseline -> spike -> drain, knots every knot_s,
    jittered rates inside the phase envelopes, fully seed-deterministic."""
    tr = bursty_trace(base=4.0, spike=40.0, base_s=10.0, spike_s=5.0,
                      drain_s=10.0, drain=2.0, jitter=0.1, seed=7)
    assert len(tr.times) == 25                    # (10 + 5 + 10) / 1s knots
    assert list(tr.times) == sorted(tr.times)
    for t, r in zip(tr.times, tr.rates):
        if t < 10.0:
            lo, hi = 4.0, 4.0
        elif t < 15.0:
            lo, hi = 40.0, 40.0
        else:
            lo, hi = 2.0, 2.0
        assert lo * 0.9 <= r <= hi * 1.1
    # the spike phase is the clear maximum regime
    assert tr.rates.max() >= 40.0 * 0.9 > tr.rates[:10].max()
    # seed determinism, trace and sampled arrivals alike
    tr2 = bursty_trace(base=4.0, spike=40.0, base_s=10.0, spike_s=5.0,
                       drain_s=10.0, drain=2.0, jitter=0.1, seed=7)
    assert list(tr.rates) == list(tr2.rates)
    a = tr.sample_requests(60, dataset="alpaca", seed=9)
    b = tr2.sample_requests(60, dataset="alpaca", seed=9)
    assert _fields(a) == _fields(b)
    assert [r.arrival for r in a] == sorted(r.arrival for r in a)
    # default drain rate is half the baseline
    tr3 = bursty_trace(base=8.0, spike=40.0, base_s=2.0, spike_s=2.0,
                       drain_s=4.0, jitter=0.0, seed=0)
    assert tr3.rates[-1] == pytest.approx(4.0)


def test_dynamic_trace_sampling_deterministic():
    trace = dynamic_rate_trace(duration_s=60.0, seed=4)
    a = trace.sample_requests(50, dataset="specbench", seed=9)
    b = trace.sample_requests(50, dataset="specbench", seed=9)
    assert _fields(a) == _fields(b)
    arr = [r.arrival for r in a]
    assert arr == sorted(arr)
    assert len(a) == 50
    assert all(r.slo == DATASETS["specbench"]["slo_ttft"] for r in a)


# ---------------------------------------------------------------------------
# multi-turn session workload (host-offload / prefix-restore scenario)
# ---------------------------------------------------------------------------


def test_session_requests_deterministic():
    from repro.serving.workload import session_requests
    a = session_requests(6, turns=4, rate_qps=0.5, seed=11)
    b = session_requests(6, turns=4, rate_qps=0.5, seed=11)
    assert _fields(a) == _fields(b)
    assert [r.prompt_tokens for r in a] == [r.prompt_tokens for r in b]
    assert [(r.session, r.turn) for r in a] == \
        [(r.session, r.turn) for r in b]
    c = session_requests(6, turns=4, rate_qps=0.5, seed=12)
    assert _fields(a) != _fields(c)


def test_session_prompts_grow_by_exact_prefix():
    """Turn k's prompt extends turn k-1's prompt exactly (history = previous
    prompt + synthesised response), which is what makes warm turns restore
    cached prefix blocks byte-for-byte."""
    from repro.serving.workload import session_requests
    reqs = session_requests(5, turns=4, context_len=64, seed=3)
    by_session = {}
    for r in reqs:
        by_session.setdefault(r.session, []).append(r)
    assert set(by_session) == set(range(5))
    for sid, rs in by_session.items():
        rs.sort(key=lambda r: r.turn)
        assert [r.turn for r in rs] == [0, 1, 2, 3]
        assert len(rs[0].prompt_tokens) >= 64 + 4     # context + user msg
        for prev, cur in zip(rs, rs[1:]):
            n = len(prev.prompt_tokens)
            assert cur.prompt_tokens[:n] == prev.prompt_tokens
            assert len(cur.prompt_tokens) > n         # response + new user
            assert cur.arrival >= prev.arrival + 1.0  # think-time floor


def test_session_requests_arrival_order_and_tags():
    from repro.serving.workload import DATASETS, session_requests
    reqs = session_requests(8, turns=3, rate_qps=1.0, seed=0)
    assert len(reqs) == 24
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)                 # global arrival order
    assert [r.req_id for r in reqs] == list(range(24))
    assert all(r.slo == DATASETS["sessions"]["slo_ttft"] for r in reqs)
    # turn-0 requests are each session's first arrival
    first = {r.session: r for r in reversed(sorted(reqs, key=lambda r: r.arrival))}
    for sid, r in first.items():
        assert r.turn == 0
    # non-session datasets leave the tags at their defaults
    other = poisson_requests(10, 5, dataset="sharegpt", seed=0)
    assert all(r.session is None and r.turn == 0 for r in other)
