"""Prefix-sharing copy-on-write paged KV: BlockManager hash-index /
share / fork invariants, LRU eviction of cached-reusable blocks, the
shared-write hardening, scheduler cached-prefix admission, and the
deterministic golden e2e (prefix caching strictly beats no-caching p99
TTFT on the templated workload with identical committed tokens)."""
from collections import OrderedDict

import numpy as np
import pytest

from repro import configs
from repro.serving.costmodel import RTX_4090
from repro.serving.kv_cache import (BlockManager, OutOfBlocks,
                                    SharedBlockWrite)
from repro.serving.request import Request
from repro.serving.scheduler import ContinuousBatchingScheduler
from repro.serving.simulator import SimConfig, build_sim_engine
from repro.serving.workload import templated_requests


def _bm(blocks=32, bs=4):
    return BlockManager(blocks, bs, prefix_caching=True)


def _prefill(bm, seq_id, tokens):
    """Allocate + register a fully materialised prompt."""
    bm.allocate(seq_id, len(tokens))
    bm.register_prefix(seq_id, tokens, len(tokens))


# ---------------------------------------------------------------------------
# hash index: match / share / register
# ---------------------------------------------------------------------------


def test_match_share_refcounts_and_stats():
    bm = _bm()
    toks = list(range(12))                 # 3 full blocks
    _prefill(bm, 1, toks)
    blocks, matched = bm.match_prefix(toks + [99])
    assert matched == 12 and blocks == bm.tables[1]
    bm.share(2, blocks, 12)
    assert all(bm.refcount[b] == 2 for b in blocks)
    assert bm.lengths[2] == 12
    bm.check_invariants()
    assert bm.stats["hits"] == 1 and bm.stats["saved_tokens"] == 12
    assert bm.stats["shared_blocks"] == 3


def test_match_requires_full_blocks_and_exact_tokens():
    bm = _bm()
    toks = list(range(10))                 # 2 full blocks + 2 leftover
    _prefill(bm, 1, toks)
    _, matched = bm.match_prefix(toks)
    assert matched == 8                    # partial block never cached
    # a diverging token inside a block breaks the chain at that block
    _, matched = bm.match_prefix([0, 1, 2, 3, 4, 99, 6, 7, 8, 9])
    assert matched == 4
    assert bm.match_prefix([7, 7, 7, 7]) == ([], 0)
    assert bm.match_prefix(None) == ([], 0)


def test_register_only_upto_materialised_tokens():
    bm = _bm()
    toks = list(range(16))
    bm.allocate(1, 16)
    assert bm.register_prefix(1, toks, 7) == 1   # only block 0 is complete
    _, matched = bm.match_prefix(toks)
    assert matched == 4
    assert bm.register_prefix(1, toks, 16) == 3  # idempotent completion
    assert bm.register_prefix(1, toks, 16) == 0
    _, matched = bm.match_prefix(toks)
    assert matched == 16


def test_caching_off_is_inert():
    bm = BlockManager(16, 4)
    toks = list(range(8))
    bm.allocate(1, 8)
    assert bm.register_prefix(1, toks, 8) == 0
    assert bm.match_prefix(toks) == ([], 0)
    assert bm.num_allocatable == bm.num_free
    bm.release(1)
    assert bm.num_free == 16               # nothing parked in the LRU tier


# ---------------------------------------------------------------------------
# copy-on-write fork: no cross-sequence contamination
# ---------------------------------------------------------------------------


def test_shared_write_raises_without_fork():
    bm = _bm()
    toks = list(range(8))
    _prefill(bm, 1, toks)
    bm.share(2, bm.tables[1], 7)           # capped: last token recomputed
    with pytest.raises(SharedBlockWrite):
        bm.append_tokens(2, 1)             # position 7 is in a shared block
    bm.check_invariants()


def test_fork_privatizes_and_queues_copy():
    bm = _bm()
    toks = list(range(8))
    _prefill(bm, 1, toks)
    shared = list(bm.tables[1])
    bm.share(2, shared, 7)
    copies = bm.fork_for_write(2, 7, 8)
    assert len(copies) == 1
    src, dst = copies[0]
    assert src == shared[1] and bm.tables[2][1] == dst != shared[1]
    assert bm.tables[1] == shared          # seq 1's table untouched
    assert bm.refcount[src] == 1 and bm.refcount[dst] == 1
    assert bm.pending_copies == [(src, dst)]
    bm.append_tokens(2, 1)                 # now legal
    bm.check_invariants()
    assert bm.drain_pending_copies() == [(src, dst)]
    assert bm.pending_copies == []
    # fork is idempotent: the range is already private
    assert bm.fork_for_write(2, 7, 8) == []


def test_partial_fork_on_exhaustion_keeps_queued_copies():
    """OutOfBlocks halfway through a multi-block fork must not lose the
    (src, dst) pairs of blocks already privatised — their physical copies
    are still owed (the caller preempts a victim and retries)."""
    bm = BlockManager(8, 4, prefix_caching=True)
    toks = list(range(16))
    _prefill(bm, 1, toks)                          # 4 registered blocks
    bm.allocate(3, 12)                             # unrelated victim: 3 blocks
    bm.share(2, list(bm.tables[1]), 16)
    # privatising positions [0, 16) needs 4 fresh blocks; only 1 exists
    with pytest.raises(OutOfBlocks):
        bm.fork_for_write(2, 0, 16)
    assert len(bm.pending_copies) == 1             # first fork survived
    src, dst = bm.pending_copies[0]
    assert bm.tables[2][0] == dst and bm.tables[1][0] == src
    assert bm.refcount[dst] == 1
    bm.check_invariants()
    # preempting the victim frees capacity; the retry forks only the still-
    # shared blocks, and every pair is queued exactly once
    bm.release(3)
    bm.fork_for_write(2, 0, 16)
    assert len(bm.pending_copies) == 4
    assert len({d for _, d in bm.pending_copies}) == 4
    assert all(bm.refcount[d] == 1 for _, d in bm.pending_copies)
    bm.check_invariants()


def test_contraction_remaps_pending_copies():
    """An elastic contraction between fork time and copy execution must
    remap queued (src, dst) pairs to the blocks' post-migration homes."""
    bm = BlockManager(8, 4, prefix_caching=True)
    bm.expand(4)
    toks = list(range(8))
    bm.allocate(1, 8)                              # pops high ids 11, 10
    assert all(b >= bm.boundary for b in bm.tables[1])
    bm.register_prefix(1, toks, 8)
    blocks, _ = bm.match_prefix(toks)
    bm.share(2, blocks, 7)
    (src, dst), = bm.fork_for_write(2, 7, 8)       # dst pops high id 9
    plan = bm.plan_contraction()
    assert plan is not None
    mapping = dict(zip(plan.src, plan.dst))
    assert src in mapping and dst in mapping       # both lived high
    bm.commit_contraction(plan)
    assert bm.pending_copies == [(mapping[src], mapping[dst])]
    assert bm.pending_copies[0][1] == bm.tables[2][1] < bm.boundary
    bm.check_invariants()
    # the hash index followed the migration: a fresh match still shares,
    # and it hands out the POST-migration block ids
    blocks2, matched = bm.match_prefix(toks)
    assert matched == 8 and all(b < bm.boundary for b in blocks2)
    assert blocks2 == bm.tables[1]


def test_release_drops_moot_pending_copies():
    """A CoW copy whose target block was freed (forking sequence preempted)
    must not survive — executing it later could clobber a reallocated
    block."""
    bm = _bm()
    toks = list(range(8))
    _prefill(bm, 1, toks)
    bm.share(2, list(bm.tables[1]), 7)
    (src, dst), = bm.fork_for_write(2, 7, 8)
    bm.release(2)                          # preempt-and-recompute
    assert bm.pending_copies == []
    assert dst in bm.free
    bm.check_invariants()


# ---------------------------------------------------------------------------
# cached-reusable LRU tier: free vs cached vs pinned
# ---------------------------------------------------------------------------


def test_release_parks_registered_blocks_in_lru_not_free():
    bm = _bm()
    toks = list(range(12))
    _prefill(bm, 1, toks)                  # 3 registered blocks
    free0 = bm.num_free
    bm.release(1)
    assert bm.num_free == free0            # nothing freed eagerly...
    assert len(bm.cached) == 3             # ...parked as cached-reusable
    assert bm.num_allocatable == free0 + 3
    bm.check_invariants()
    # a later admission still matches the parked content
    blocks, matched = bm.match_prefix(toks)
    assert matched == 12
    bm.share(2, blocks, 12)
    assert len(bm.cached) == 0             # pinned again
    bm.check_invariants()


def test_eviction_is_lru_and_unregisters():
    bm = _bm(blocks=6, bs=4)
    a, b = [0, 1, 2, 3], [4, 5, 6, 7]
    _prefill(bm, 1, a)
    _prefill(bm, 2, b)
    bm.release(1)                          # a parked first (LRU victim)
    bm.release(2)
    assert len(bm.cached) == 2 and bm.num_free == 4
    bm.allocate(3, 5 * 4)                  # needs 5 blocks: evicts ONE
    assert bm.match_prefix(a) == ([], 0)   # a evicted (least recent)
    _, matched = bm.match_prefix(b)
    assert matched == 4                    # b survived
    assert bm.stats["evictions"] == 1
    bm.check_invariants()


def test_share_refreshes_lru_order():
    bm = _bm(blocks=6, bs=4)
    a, b = [0, 1, 2, 3], [4, 5, 6, 7]
    _prefill(bm, 1, a)
    _prefill(bm, 2, b)
    bm.release(1)
    bm.release(2)
    # touch a: share + release moves it to the MRU end
    blocks, _ = bm.match_prefix(a)
    bm.share(3, blocks, 4)
    bm.release(3)
    bm.allocate(4, 5 * 4)
    _, matched = bm.match_prefix(a)
    assert matched == 4                    # a survived the eviction
    assert bm.match_prefix(b) == ([], 0)   # b was the LRU victim
    bm.check_invariants()


def test_no_leaked_blocks_under_random_share_fork_release():
    """I1/I2/I5 under seeded random op sequences with caching on: every
    block is free, cached, or referenced — and the three sets partition the
    pool."""
    for seed in range(6):
        rng = np.random.default_rng(seed)
        bm = _bm(blocks=24, bs=4)
        prompts = {i: rng.integers(0, 50, 16).tolist() for i in range(4)}
        live = {}
        next_id = 0
        for _ in range(120):
            kind = int(rng.integers(0, 4))
            try:
                if kind == 0:              # admit (shared when possible)
                    toks = prompts[int(rng.integers(0, 4))]
                    blocks, matched = bm.match_prefix(toks)
                    cached = min(matched, len(toks) - 1)
                    try:
                        if blocks:
                            bm.share(next_id, blocks, cached)
                            bm.fork_for_write(next_id, cached, cached + 1)
                            bm.grow_to(next_id, cached + 1)
                        else:
                            bm.allocate(next_id, 4)
                    except OutOfBlocks:
                        # roll back the partial admission (scheduler policy)
                        bm.release(next_id)
                        next_id += 1
                        continue
                    live[next_id] = toks
                    next_id += 1
                elif kind == 1 and live:   # prefill progress + register
                    sid = int(rng.choice(list(live)))
                    toks = live[sid]
                    target = min(bm.lengths[sid] + 4, len(toks))
                    if target > bm.lengths[sid]:
                        bm.fork_for_write(sid, bm.lengths[sid], target)
                        bm.grow_to(sid, target)
                    bm.register_prefix(sid, toks, bm.lengths[sid])
                elif kind == 2 and live:   # decode append
                    sid = int(rng.choice(list(live)))
                    bm.fork_for_write(sid, bm.lengths[sid],
                                      bm.lengths[sid] + 2)
                    bm.append_tokens(sid, 2)
                elif kind == 3 and live:   # finish / preempt
                    sid = int(rng.choice(list(live)))
                    bm.release(sid)
                    del live[sid]
            except OutOfBlocks:
                pass
            bm.check_invariants()
            referenced = {b for t in bm.tables.values() for b in t}
            assert len(referenced) + len(bm.cached) + bm.num_free \
                == bm.total_blocks
        # drain everything: the whole pool is reusable again
        for sid in list(live):
            bm.release(sid)
        assert bm.num_allocatable == bm.total_blocks
        bm.check_invariants()


# ---------------------------------------------------------------------------
# scheduler: cached-prefix admission skips prefill and shares blocks
# ---------------------------------------------------------------------------


def _sched(blocks=64, bs=4, chunk=32, **kw):
    bm = BlockManager(blocks, bs, prefix_caching=True)
    return ContinuousBatchingScheduler(bm, max_batch=8, watermark_frac=0.0,
                                       chunk_tokens=chunk, **kw)


def _drive(s, batch, *, draft_ok=True):
    """Chunk progress + registration + one decode token (engine minus
    latency)."""
    for seq, n in batch.prefill_chunks:
        seq.prefilled += n
        s.note_prefill_progress(seq, draft_ok=draft_ok)
    for seq in batch.decode:
        if seq in s.running and s.commit_tokens(seq, 1) and seq.done:
            s.finish(seq)
    s.bm.drain_pending_copies()


def test_admission_shares_cached_prefix_and_skips_prefill():
    s = _sched()
    toks = list(range(16))
    s.add_request(Request(0, 0.0, 16, 2, prompt_tokens=toks + [77] * 4))
    while s.running or s.num_waiting:          # run req 0 to completion
        _drive(s, s.schedule_chunks())
    assert len(s.bm.cached) > 0                # its prefix blocks parked
    s.add_request(Request(1, 1.0, 20, 2, prompt_tokens=toks + [88] * 4))
    b = s.schedule_chunks()
    (seq, n), = b.prefill_chunks
    assert seq.cached_tokens == 16             # 4 shared blocks
    assert seq.prefilled == 16                 # chunk starts at the boundary
    assert n == 4                              # only the suffix prefills
    assert b.prefill_tokens == 4
    s.bm.check_invariants()


def test_fully_cached_prompt_recomputes_last_token_with_fork():
    """A prompt exactly equal to a cached template shares every block but
    must recompute its last token — which forks the tail shared block."""
    s = _sched()
    toks = list(range(16))
    s.add_request(Request(0, 0.0, 16, 2, prompt_tokens=toks))
    b0 = s.schedule_chunks()
    _drive(s, b0)
    forks0 = s.bm.stats["forks"]
    s.add_request(Request(1, 1.0, 16, 2, prompt_tokens=list(toks)))
    b = s.schedule_chunks()
    chunk = next((c for c in b.prefill_chunks if c[0].req_id == 1), None)
    assert chunk is not None
    seq, n = chunk
    assert seq.cached_tokens == 15 and n == 1  # one-token recompute
    assert s.bm.stats["forks"] == forks0 + 1   # CoW fork of the tail block
    assert s.bm.tables[0][3] != s.bm.tables[1][3]   # private tail copies
    assert s.bm.tables[0][:3] == s.bm.tables[1][:3]  # shared prefix intact
    s.bm.check_invariants()


def test_preempted_cached_sequence_leaks_nothing():
    """Preempting a sequence admitted from the cache releases its private
    blocks to the free list and parks registered ones — pool conserved."""
    bm = BlockManager(16, 4, prefix_caching=True)
    s = ContinuousBatchingScheduler(bm, max_batch=4, watermark_frac=0.0,
                                    chunk_tokens=16)
    toks = list(range(8))
    s.add_request(Request(0, 0.0, 8, 64, prompt_tokens=toks))
    _drive(s, s.schedule_chunks())
    s.add_request(Request(1, 1.0, 12, 4, prompt_tokens=toks + [9] * 4))
    b = s.schedule_chunks()
    young = next(seq for seq, _ in b.prefill_chunks if seq.req_id == 1)
    assert young.cached_tokens == 8
    _drive(s, b)
    old = next(q for q in s.running if q.req_id == 0)
    while young in s.running:                  # grow old until preemption
        assert s.commit_tokens(old, 4)
    bm.check_invariants()
    assert 1 not in bm.tables
    referenced = {b for t in bm.tables.values() for b in t}
    assert len(referenced) + len(bm.cached) + bm.num_free == bm.total_blocks
    s.finish(old)
    assert bm.num_allocatable == bm.total_blocks   # nothing leaked
    bm.check_invariants()


def test_hit_rate_accounting_reaches_metrics():
    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, max_batch=64, seed=0, chunk_tokens=128,
                    prefix_caching=True)
    eng = build_sim_engine(cfg, "nightjar")
    reqs = templated_requests(20, 40, template_len=64, seed=3)
    m = eng.run(reqs)
    assert m.prefix["queries"] > 0
    assert m.prefix["hits"] > 0
    assert 0.0 < m.prefix_hit_rate <= 1.0
    assert m.prefix["saved_tokens"] > 0
    s = m.summary()
    assert s["prefix_saved_tokens"] == m.prefix["saved_tokens"]
    assert s["blocks_allocated"] == m.blocks_allocated > 0


# ---------------------------------------------------------------------------
# golden e2e: caching strictly beats no-caching on the templated workload
# ---------------------------------------------------------------------------


def _golden_run(caching):
    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, max_batch=256, seed=0, chunk_tokens=384,
                    prefix_caching=caching)
    eng = build_sim_engine(cfg, "nightjar")
    reqs = templated_requests(80, 160, template_len=512, seed=1)
    m = eng.run(reqs, max_steps=500_000)
    return m, reqs


def test_prefix_caching_beats_nocache_p99_ttft_templated():
    """At a saturating rate on the templated workload, prefix caching
    strictly reduces p99 (and p50) TTFT and total allocated blocks vs
    caching-off, finishes every request with identical per-request committed
    tokens, and is bit-deterministic across consecutive runs."""
    off1, reqs = _golden_run(False)
    off2, _ = _golden_run(False)
    on1, _ = _golden_run(True)
    on2, _ = _golden_run(True)
    # determinism: two consecutive runs agree exactly
    assert off1.summary() == off2.summary()
    assert on1.summary() == on2.summary()
    # identical committed token streams (every request ran to completion;
    # caching changed WHEN prefill work happened, not WHAT was generated)
    stream_on = sorted((r.req_id, r.tokens) for r in on1.requests)
    stream_off = sorted((r.req_id, r.tokens) for r in off1.requests)
    assert stream_on == stream_off
    assert len(on1.requests) == len(reqs)
    # the headline: strictly lower tail latency AND block consumption
    assert on1.ttft_percentile(0.99) < off1.ttft_percentile(0.99)
    assert on1.ttft_percentile(0.50) < off1.ttft_percentile(0.50)
    assert on1.blocks_allocated < off1.blocks_allocated
    assert on1.goodput >= off1.goodput
    assert on1.prefix_hit_rate > 0.9
