"""Host-memory KV offload tier: HostKVStore LRU/pinning semantics, the
spill-on-evict / restore-on-match flow through BlockManager, admission
accounting for restorable blocks, the contraction bugfix (below-boundary
cached blocks survive a pool shrink), a randomized spill/restore soak with
a physical-pool byte-identity oracle, and the sim-engine e2e on the
multi-turn session workload."""
import numpy as np
import pytest

from repro import configs
from repro.serving.costmodel import RTX_4090
from repro.serving.kv_cache import (BlockManager, HostKVStore, OutOfBlocks,
                                    PhysicalKVPool, chain_hash, CHAIN_ROOT)
from repro.serving.simulator import SimConfig, build_sim_engine
from repro.serving.workload import session_requests

BS = 4  # block size for the logical tests


def _bm(nb=16, host_blocks=64, prefix_caching=True, host=True):
    hs = HostKVStore(host_blocks) if host else None
    return BlockManager(nb, BS, prefix_caching=prefix_caching, host_store=hs)


def _prompt(rng, n_blocks):
    return [int(t) for t in rng.integers(0, 1000, size=n_blocks * BS)]


def _admit(bm, seq_id, tokens):
    """Materialise a prompt the way the scheduler does: match, share,
    grow to full length, register."""
    blocks, cached = bm.match_prefix(tokens)
    if blocks:
        bm.share(seq_id, blocks, cached)
        bm.grow_to(seq_id, len(tokens))
    else:
        bm.allocate(seq_id, len(tokens))
    bm.register_prefix(seq_id, tokens, len(tokens))
    return cached


def _sim_drain(bm):
    """The simulated tier's transfer drain: spills are already indexed at
    eviction time; restores consume their record (move semantics)."""
    bm.drain_pending_spills()
    for h, _ in bm.drain_pending_restores():
        bm.host_store.take(h)


# ---------------------------------------------------------------------------
# HostKVStore unit semantics
# ---------------------------------------------------------------------------


def test_host_store_lru_eviction_and_reput():
    hs = HostKVStore(2)
    hs.put(1, 0, (1,) * BS)
    hs.put(2, 0, (2,) * BS)
    hs.put(1, 0, (1,) * BS)            # re-put refreshes LRU, no new record
    assert hs.stats["spills"] == 2
    hs.put(3, 0, (3,) * BS)            # capacity 2: LRU (hash 2) evicted
    assert set(hs.records) == {1, 3}
    assert hs.stats["host_evictions"] == 1
    assert hs.get(2) is None and hs.get(1) is not None


def test_host_store_pinned_records_survive_capacity():
    hs = HostKVStore(2)
    hs.put(1, 0, (1,) * BS)
    hs.put(2, 0, (2,) * BS)
    hs.pin(1)
    hs.put(3, 0, (3,) * BS)            # 1 is LRU but pinned: 2 goes instead
    assert set(hs.records) == {1, 3}
    hs.pin(3)
    hs.put(4, 0, (4,) * BS)            # every older record pinned: the new
    assert set(hs.records) == {1, 3}   # (unpinned) spill is the one dropped
    assert 4 not in hs.pinned


def test_host_store_take_moves_and_unpins():
    hs = HostKVStore(4)
    hs.put(7, 0, (7,) * BS)
    hs.pin(7)
    rec = hs.take(7)
    assert rec is not None and 7 not in hs.records and 7 not in hs.pinned
    assert hs.stats["restores"] == 1
    assert hs.take(7) is None          # second take: record is gone


# ---------------------------------------------------------------------------
# spill on eviction, restore on match
# ---------------------------------------------------------------------------


def test_eviction_spills_and_match_restores():
    rng = np.random.default_rng(0)
    bm = _bm(nb=8)
    hs = bm.host_store
    tokens = _prompt(rng, 3)
    _admit(bm, 0, tokens)
    bm.release(0)                       # 3 registered blocks park cached
    assert len(bm.cached) == 3

    # allocation pressure evicts the whole cached tier → host records
    bm.allocate(1, 8 * BS)
    assert len(hs.records) == 3 and len(bm.pending_spills) == 3
    assert not bm.hash_index            # device index emptied
    bm.check_invariants()
    _sim_drain(bm)
    bm.release(1)

    # the next admission's match walks into the host tier
    blocks, cached = bm.match_prefix(tokens)
    assert cached == 3 * BS and len(blocks) == 3
    assert bm.stats["restored_blocks"] == 3
    assert len(bm.pending_restores) == 3
    assert all(h in hs.pinned for h, _ in bm.pending_restores)
    # restored blocks are registered AND cached → admission counts them
    assert all(b in bm.cached for b in blocks)
    bm.check_invariants()
    _sim_drain(bm)
    assert len(hs.records) == 0         # move semantics: host copy consumed
    bm.check_invariants()

    # and they are shareable like any cached prefix
    bm.share(2, blocks, cached)
    bm.check_invariants()
    assert bm.lengths[2] == cached


def test_restore_needs_a_free_block():
    rng = np.random.default_rng(1)
    bm = _bm(nb=4)
    tokens = _prompt(rng, 2)
    _admit(bm, 0, tokens)
    bm.release(0)
    bm.allocate(1, 4 * BS)              # evict + occupy the whole pool
    _sim_drain(bm)
    assert len(bm.host_store.records) == 2
    blocks, cached = bm.match_prefix(tokens)
    assert blocks == [] and cached == 0   # no free block: restore refused
    assert not bm.pending_restores
    bm.check_invariants()


def test_register_prefix_supersedes_host_record():
    """A prompt re-prefilled on device (restore skipped) drops the host
    record at registration — the tiers stay disjoint (I6)."""
    rng = np.random.default_rng(2)
    bm = _bm(nb=4)
    hs = bm.host_store
    tokens = _prompt(rng, 2)
    _admit(bm, 0, tokens)
    bm.release(0)
    bm.allocate(1, 4 * BS)              # spill both blocks
    _sim_drain(bm)
    bm.release(1)
    assert len(hs.records) == 2
    # re-materialise WITHOUT matching first (monolithic re-prefill)
    bm.allocate(2, len(tokens))
    bm.register_prefix(2, tokens, len(tokens))
    assert len(hs.records) == 0         # device copy superseded the host's
    bm.check_invariants()


def test_evicting_restore_target_cancels_restore():
    """When allocation pressure evicts a block that is itself a pending
    restore TARGET, the restore is cancelled and the host record (still the
    content's only owner) survives, unpinned."""
    rng = np.random.default_rng(3)
    bm = _bm(nb=4)
    hs = bm.host_store
    tokens = _prompt(rng, 2)
    _admit(bm, 0, tokens)
    bm.release(0)
    bm.allocate(1, 4 * BS)
    _sim_drain(bm)
    bm.release(1)
    blocks, cached = bm.match_prefix(tokens)
    assert cached == 2 * BS and len(bm.pending_restores) == 2
    # pressure again: the restore targets are LRU-cached, so they evict
    bm.allocate(2, 4 * BS)
    assert not bm.pending_restores       # both restores cancelled
    assert len(hs.records) == 2          # records kept — sole content owner
    assert not hs.pinned                 # and unpinned
    bm.check_invariants()
    # no spurious spills of never-materialised targets
    spilled = {h for _, h in bm.pending_spills}
    for h, _ in list(hs.records.items()):
        assert h not in spilled or hs.records[h] is not None


# ---------------------------------------------------------------------------
# contraction: the below-boundary preservation bugfix + spill-on-contract
# ---------------------------------------------------------------------------


def test_contraction_preserves_below_boundary_cached():
    """Regression (pre-fix: plan_contraction evicted EVERY cached block,
    cold-restarting the prefix cache on each contraction).  Warm cached
    blocks below the boundary must keep their registrations, and the next
    templated admission must still hit."""
    rng = np.random.default_rng(4)
    bm = BlockManager(8, BS, prefix_caching=True)
    tokens = _prompt(rng, 3)
    _admit(bm, 0, tokens)               # occupies low ids
    bm.release(0)                       # → cached, below boundary
    cached_hashes = set(bm.hash_index)
    assert len(cached_hashes) == 3

    bm.expand(4)                        # boundary stays 8, total 12
    plan = bm.plan_contraction()
    assert plan is not None and len(plan) == 0
    bm.commit_contraction(plan)
    bm.check_invariants()

    # the fix: warm below-boundary registrations survived the shrink
    assert set(bm.hash_index) == cached_hashes
    blocks, cached = bm.match_prefix(tokens)
    assert cached == 3 * BS
    bm.check_invariants()


def test_contraction_evicts_above_boundary_to_host():
    """Cached blocks living in the doomed region spill to the host tier at
    plan time and restore after the shrink."""
    rng = np.random.default_rng(5)
    bm = _bm(nb=4)
    hs = bm.host_store
    bm.allocate(0, 4 * BS)              # pin the base region
    bm.expand(4)                        # ids 4..7
    tokens = _prompt(rng, 2)
    _admit(bm, 1, tokens)               # lands in the expanded region
    high = list(bm.tables[1])
    assert all(b >= 4 for b in high)
    bm.release(1)                       # → cached, above boundary
    bm.release(0)

    plan = bm.plan_contraction()
    assert plan is not None
    bm.commit_contraction(plan)
    bm.check_invariants()
    assert len(hs.records) == 2          # spilled, not discarded
    _sim_drain(bm)

    blocks, cached = bm.match_prefix(tokens)
    assert cached == 2 * BS              # restored into the shrunk pool
    bm.check_invariants()


def test_contraction_evicts_minimum_low_cached_for_targets():
    """When the preserved region has too few free slots for the migration,
    only the minimum number of low cached blocks are evicted (LRU-first) —
    the rest keep their registrations."""
    rng = np.random.default_rng(6)
    bm = _bm(nb=6)
    t_a, t_b = _prompt(rng, 2), _prompt(rng, 2)
    _admit(bm, 0, t_a)
    _admit(bm, 1, t_b)
    bm.release(0)
    bm.release(1)                       # 4 low cached blocks, 2 free low
    bm.expand(2)
    bm.allocate(2, 2 * BS)              # pins ids 4,5... wherever free
    high = [b for b in bm.tables[2] if b >= bm.boundary]
    if not high:                        # allocation came from low free ids:
        pytest.skip("allocator gave low ids; nothing to migrate")
    plan = bm.plan_contraction()
    assert plan is not None
    bm.commit_contraction(plan)
    bm.check_invariants()
    # at most len(high) low cached evictions; the other registrations live
    assert len(bm.hash_index) >= 4 - len(high)


# ---------------------------------------------------------------------------
# randomized spill/restore soak with a physical byte-identity oracle
# ---------------------------------------------------------------------------

L, KH, HD = 2, 1, 2   # tiny physical pool geometry


def _block_payload(tokens):
    """Deterministic per-block K/V content derived from the token ids —
    the oracle for byte-identity through spill→restore round trips."""
    t = np.asarray(tokens, np.float32)
    k = np.broadcast_to(t[None, :, None, None], (L, len(tokens), KH, HD))
    return k, k * 2.0 + 1.0


def _flush(bm, pool):
    """The physical tier's transfer drain (mirrors
    RealBackend.apply_host_transfers): gather spills into their records,
    then scatter pinned restore payloads into their target blocks."""
    hs = bm.host_store
    spills = [(b, h) for b, h in bm.drain_pending_spills()
              if h in hs.records]
    if spills:
        kpay, vpay = pool.spill_blocks([b for b, _ in spills])
        for i, (_, h) in enumerate(spills):
            hs.records[h].data = {"k": np.asarray(kpay[:, i]),
                                  "v": np.asarray(vpay[:, i])}
            hs.seal(h)   # re-stamp the checksum over the filled pages
    restores = bm.drain_pending_restores()
    if restores:
        recs = [hs.take(h) for h, _ in restores]
        assert all(r is not None and r.data for r in recs), \
            "pinned host record lost before its restore drained"
        pool.restore_blocks([b for _, b in restores],
                            np.stack([r.data["k"] for r in recs], axis=1),
                            np.stack([r.data["v"] for r in recs], axis=1))


def _write_range(pool, table, tokens, start):
    """Materialise prompt positions [start, len(tokens)) into the pool."""
    if start >= len(tokens):
        return
    k, v = _block_payload(tokens[start:])
    pool.write_tokens(k, v, table, start)


def _assert_registered_bytes(bm, pool):
    """Every registered device block (restores drained) holds exactly the
    content its token chain dictates."""
    assert not bm.pending_restores
    for b, (_, toks) in bm.block_chain.items():
        ek, ev = _block_payload(toks)
        np.testing.assert_array_equal(np.asarray(pool.k[:, b]), ek)
        np.testing.assert_array_equal(np.asarray(pool.v[:, b]), ev)


def _assert_no_leaks(bm):
    owned = set(bm.free) | set(bm.cached) | set(bm.refcount) | bm.reserved
    assert owned == set(range(bm.total_blocks)), \
        f"leaked blocks: {set(range(bm.total_blocks)) - owned}"


def test_randomized_spill_restore_soak():
    rng = np.random.default_rng(42)
    nb = 24
    bm = _bm(nb=nb, host_blocks=96)
    pool = PhysicalKVPool(L, nb, BS, KH, HD, dtype=np.float32)
    prompts = []          # grown session-style so prefixes repeat
    live = {}             # seq_id -> prompt
    next_seq = 0

    for step in range(140):
        op = rng.choice(["admit", "release", "flush", "contract_cycle"],
                        p=[0.45, 0.25, 0.2, 0.1])
        if op == "admit":
            if prompts and rng.uniform() < 0.6:
                base = prompts[int(rng.integers(len(prompts)))]
                tokens = base + _prompt(rng, int(rng.integers(1, 3)))
            else:
                tokens = _prompt(rng, int(rng.integers(1, 4)))
            need = bm.blocks_needed(len(tokens))
            if need > bm.num_allocatable:
                continue
            sid = next_seq
            next_seq += 1
            cached = _admit(bm, sid, tokens)
            # drain BEFORE writing, exactly like the engine step: evictions
            # queued by this admission spill the pre-overwrite content, and
            # queued restores land before the new suffix is written
            _flush(bm, pool)
            _write_range(pool, bm.tables[sid], tokens, cached)
            live[sid] = tokens
            if len(prompts) < 40:
                prompts.append(tokens)
        elif op == "release" and live:
            sid = list(live)[int(rng.integers(len(live)))]
            bm.release(sid)
            del live[sid]
        elif op == "flush":
            _flush(bm, pool)
        elif op == "contract_cycle":
            _flush(bm, pool)
            bm.expand(8)
            pool.grow(8)
            # park some load in the expanded region, then shrink back
            if bm.num_allocatable >= 2:
                sid = next_seq
                next_seq += 1
                tokens = _prompt(rng, 2)
                cached = _admit(bm, sid, tokens)
                _flush(bm, pool)
                _write_range(pool, bm.tables[sid], tokens, cached)
                live[sid] = tokens
            plan = bm.plan_contraction()
            if plan is not None:
                _flush(bm, pool)         # capture plan-time spills FIRST
                pool.migrate(plan, use_kernel=False)
                bm.commit_contraction(plan)
                pool.shrink(bm.total_blocks)
            # plan can legitimately fail under load (not enough low free
            # slots): the pool simply stays expanded until a later cycle
        bm.check_invariants()
        _assert_no_leaks(bm)
        if step % 10 == 0:
            _flush(bm, pool)
            _assert_registered_bytes(bm, pool)

    _flush(bm, pool)
    bm.check_invariants()
    _assert_no_leaks(bm)
    _assert_registered_bytes(bm, pool)
    # the soak actually exercised the tier both ways
    hs = bm.host_store
    assert hs.stats["spills"] > 0 and hs.stats["restores"] > 0


# ---------------------------------------------------------------------------
# sim-engine e2e: the multi-turn session workload
# ---------------------------------------------------------------------------


def _sessions_run(kv_offload):
    cfg = SimConfig(target=configs.get_config("paper-7b"),
                    draft=configs.get_draft_config("paper-7b"),
                    hw=RTX_4090, chunk_tokens=384, prefix_caching=True,
                    enable_offload=False, num_blocks=256,
                    kv_offload=kv_offload, seed=0)
    eng = build_sim_engine(cfg, "nightjar")
    reqs = session_requests(6, turns=4, rate_qps=0.5, seed=1)
    m = eng.run(reqs, record_timeline=False)
    eng.scheduler.bm.check_invariants()
    return m


def test_sessions_engine_offload_improves_cross_turn_hits():
    m_on = _sessions_run(True)
    m_off = _sessions_run(False)

    def hit_rate(m):
        warm = [r for r in m.requests if r.turn > 0]
        return sum(1 for r in warm if r.cached_tokens > 0) / len(warm)

    assert len(m_on.requests) == len(m_off.requests) > 0
    assert m_on.host["restores"] > 0
    assert m_on.host["restore_s"] > 0          # priced at host_link_bw
    assert hit_rate(m_on) > hit_rate(m_off)
    # restores move bytes, never change computation: identical streams
    assert sorted((r.req_id, r.tokens) for r in m_on.requests) == \
        sorted((r.req_id, r.tokens) for r in m_off.requests)
    # metrics surface the tier
    s = m_on.summary()
    assert s["host_spills"] > 0 and s["host_restores"] > 0
    assert "host" not in m_off.summary().get("host", {})  # off → no keys
    assert "host_spills" not in m_off.summary()


def test_sessions_engine_restored_blocks_counted_cached():
    """Admission accounting: restored prefix blocks show up as
    cached_tokens on the requests that hit them (the scheduler's
    match→share path treats them like any cached block)."""
    m = _sessions_run(True)
    warm_hits = [r for r in m.requests if r.turn > 0 and r.cached_tokens > 0]
    assert warm_hits, "no warm request admitted with cached prefix"
    assert m.prefix.get("restored_blocks", 0) > 0
