"""Block manager + elastic pool invariants (§6.3/6.4), hypothesis-free tier.

The randomised property versions of these tests live in
tests/test_kv_cache_properties.py (skipped when hypothesis is missing);
here the same invariants are exercised with seeded, parametrized
plain-pytest equivalents so tier-1 coverage never depends on optional
dependencies.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_cache import (BlockManager, OutOfBlocks,
                                    PhysicalKVPool)


def test_allocate_release_roundtrip():
    bm = BlockManager(32, block_size=4)
    bm.allocate(1, 10)  # 3 blocks
    bm.allocate(2, 4)   # 1 block
    bm.check_invariants()
    assert bm.num_free == 28
    bm.release(1)
    assert bm.num_free == 31
    bm.check_invariants()


@pytest.mark.parametrize("tokens,blocks", [(1, 1), (4, 1), (5, 2),
                                           (16, 4), (17, 5)])
def test_alloc_free_roundtrip_parametrized(tokens, blocks):
    """Round-trip at block boundaries: allocation size and full recovery."""
    bm = BlockManager(16, block_size=4)
    got = bm.allocate(7, tokens)
    assert len(got) == blocks
    assert bm.num_free == 16 - blocks
    bm.check_invariants()
    bm.release(7)
    assert bm.num_free == 16
    assert bm.refcount == {} and bm.tables == {}
    bm.check_invariants()


def test_append_allocates_on_boundary():
    bm = BlockManager(8, block_size=4)
    bm.allocate(1, 4)
    assert len(bm.tables[1]) == 1
    bm.append_tokens(1, 1)          # crosses into block 2
    assert len(bm.tables[1]) == 2
    bm.append_tokens(1, 3)          # fills block 2
    assert len(bm.tables[1]) == 2
    bm.check_invariants()


def test_out_of_blocks_raises():
    bm = BlockManager(2, block_size=4)
    bm.allocate(1, 8)
    with pytest.raises(OutOfBlocks):
        bm.allocate(2, 1)


def test_expand_contract_cycle():
    bm = BlockManager(8, block_size=4)
    bm.allocate(1, 32)  # all 8 blocks
    assert bm.num_free == 0
    start, end = bm.expand(4)
    assert (start, end) == (8, 12)
    assert bm.num_free == 4
    bm.allocate(2, 16)  # uses the extended region
    used_high = [b for b in bm.tables[2] if b >= bm.boundary]
    assert used_high, "expansion blocks should be used"
    bm.release(1)       # free the low region
    plan = bm.plan_contraction()
    assert plan is not None
    assert sorted(plan.src) == sorted(used_high)
    assert all(b < bm.boundary for b in plan.dst)
    bm.commit_contraction(plan)
    bm.check_invariants()
    assert bm.total_blocks == bm.base_blocks
    assert all(b < bm.boundary for t in bm.tables.values() for b in t)


@pytest.mark.parametrize("seed", range(8))
def test_invariants_under_seeded_random_ops(seed):
    """I1/I2: refcounts and free list stay consistent under arbitrary op
    sequences including expansion/contraction (seeded plain-pytest
    equivalent of the hypothesis property)."""
    rng = np.random.default_rng(seed)
    bm = BlockManager(16, block_size=4)
    live = {}
    next_id = 0
    expanded = False
    for _ in range(80):
        kind = int(rng.integers(0, 4))
        arg = int(rng.integers(1, 31))
        try:
            if kind == 0:  # allocate
                bm.allocate(next_id, arg)
                live[next_id] = arg
                next_id += 1
            elif kind == 1 and live:  # append
                sid = int(rng.choice(list(live)))
                bm.append_tokens(sid, arg % 8 + 1)
            elif kind == 2 and live:  # release
                sid = int(rng.choice(list(live)))
                bm.release(sid)
                del live[sid]
            elif kind == 3:
                if not expanded:
                    bm.expand(4)
                    expanded = True
                else:
                    plan = bm.plan_contraction()
                    if plan is not None:
                        bm.commit_contraction(plan)
                        expanded = False
        except OutOfBlocks:
            pass
        bm.check_invariants()


def test_migration_preserves_logical_contents():
    """I4: expansion -> writes into high blocks -> contraction + kernel
    migration leaves every sequence's gathered KV bit-identical."""
    rng = np.random.default_rng(0)
    L, bs, kh, hd = 2, 4, 2, 8
    bm = BlockManager(6, block_size=bs)
    pool = PhysicalKVPool(L, 6, bs, kh, hd, dtype=jnp.float32)

    bm.allocate(1, 20)          # 5 blocks
    bm.expand(4)
    pool.grow(4)
    bm.allocate(2, 12)          # 3 blocks: 1 low + high blocks

    writes = {}
    for sid, n in ((1, 20), (2, 12)):
        vals = rng.normal(size=(L, n, kh, hd)).astype(np.float32)
        pool.write_tokens(jnp.asarray(vals), jnp.asarray(2 * vals),
                          bm.tables[sid], 0)
        writes[sid] = vals

    before = {sid: pool.gather_sequence(bm.tables[sid], bm.lengths[sid])
              for sid in (1, 2)}
    bm.release(1)               # free low blocks so contraction has room
    plan = bm.plan_contraction()
    assert plan is not None and len(plan) > 0
    pool.migrate(plan, use_kernel=True)   # Pallas kernel (interpret mode)
    bm.commit_contraction(plan)
    pool.shrink(bm.base_blocks)
    bm.check_invariants()

    k_after, v_after = pool.gather_sequence(bm.tables[2], bm.lengths[2])
    np.testing.assert_array_equal(np.asarray(before[2][0]),
                                  np.asarray(k_after))
    np.testing.assert_array_equal(np.asarray(before[2][1]),
                                  np.asarray(v_after))
    assert all(b < bm.boundary for b in bm.tables[2])
