"""Hypothesis property tests for the block manager (optional tier).

Skipped wholesale when hypothesis is not installed; the seeded plain-pytest
equivalents in tests/test_kv_cache.py keep the invariants covered in tier-1.
Install via requirements-dev.txt to enable this module.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.kv_cache import BlockManager, OutOfBlocks  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 30)),
                    min_size=1, max_size=60),
       seed=st.integers(0, 100))
def test_invariants_under_random_ops(ops, seed):
    """I1/I2: refcounts and free list stay consistent under arbitrary op
    sequences including expansion/contraction."""
    rng = np.random.default_rng(seed)
    bm = BlockManager(16, block_size=4)
    live = {}
    next_id = 0
    expanded = False
    for kind, arg in ops:
        try:
            if kind == 0:  # allocate
                bm.allocate(next_id, arg)
                live[next_id] = arg
                next_id += 1
            elif kind == 1 and live:  # append
                sid = int(rng.choice(list(live)))
                bm.append_tokens(sid, arg % 8 + 1)
            elif kind == 2 and live:  # release
                sid = int(rng.choice(list(live)))
                bm.release(sid)
                del live[sid]
            elif kind == 3:
                if not expanded:
                    bm.expand(4)
                    expanded = True
                else:
                    plan = bm.plan_contraction()
                    if plan is not None:
                        bm.commit_contraction(plan)
                        expanded = False
        except OutOfBlocks:
            pass
        bm.check_invariants()


@settings(max_examples=25, deadline=None)
@given(tokens=st.integers(1, 60), block_size=st.integers(1, 8))
def test_alloc_free_roundtrip(tokens, block_size):
    """Allocation uses ceil(tokens/block_size) blocks; release recovers all."""
    bm = BlockManager(64, block_size=block_size)
    got = bm.allocate(0, tokens)
    assert len(got) == -(-tokens // block_size)
    bm.check_invariants()
    bm.release(0)
    assert bm.num_free == 64
    bm.check_invariants()
