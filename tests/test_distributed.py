"""Distributed correctness on an 8-device host mesh (subprocess so the
device-count flag applies before jax initialises)."""
import json
import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.real_backend]

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import registry
from repro.distributed import sharding as shd
from repro.launch.mesh import make_debug_mesh, mesh_context

mesh = make_debug_mesh(4, 2)
out = {}

# 1. every assigned arch's param specs are mesh-valid (this would raise on
#    a non-divisible sharding) and a reduced train step matches 1-device.
arch = "deepseek-7b"
cfg = configs.reduced(configs.get_config(arch)).replace(
    dtype="float32", num_layers=2)
api = registry.get_model(cfg)
params = api.init(jax.random.PRNGKey(0))
pspecs = shd.param_specs(cfg, params, mesh)
p_sh = shd.to_named(pspecs, mesh)

tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tok, "labels": tok}

def loss_fn(p, b):
    l, _ = api.loss(p, b)
    return l

ref = float(loss_fn(params, batch))

with mesh_context(mesh):
    b_sh = shd.to_named(shd.data_specs(cfg, batch, mesh), mesh)
    f = jax.jit(loss_fn, in_shardings=(p_sh, b_sh),
                out_shardings=NamedSharding(mesh, P()))
    def run():
        with shd.activation_sharding(("data",), "model"):
            return f(jax.device_put(params, p_sh),
                     jax.device_put(batch, b_sh))
    got = float(run())
out["loss_match"] = abs(got - ref) < 1e-3
out["ref"] = ref
out["got"] = got

# 2. decode with context-parallel KV (seq over model) matches 1-device
_, cache = api.prefill(params, {"tokens": tok}, 64)
lg_ref, _ = api.decode_step(params, cache, tok[:, :1])
with mesh_context(mesh):
    c_sh = shd.to_named(shd.cache_specs(cfg, cache, mesh), mesh)
    t_sh = shd.to_named(shd.token_specs(tok[:, :1], mesh), mesh)
    g = jax.jit(lambda p, c, t: api.decode_step(p, c, t),
                in_shardings=(p_sh, c_sh, t_sh))
    lg_sh, _ = g(jax.device_put(params, p_sh),
                 jax.device_put(cache, c_sh),
                 jax.device_put(tok[:, :1], t_sh))
out["decode_match"] = bool(jnp.max(jnp.abs(lg_sh - lg_ref)) < 1e-3)

# 3. all assigned archs produce valid (constructible) NamedShardings
ok = []
for a in configs.ASSIGNED_ARCHS:
    c = configs.get_config(a)
    specs = shd.param_specs(c, registry.param_specs(c), mesh)
    shd.to_named(specs, mesh)
    ok.append(a)
out["spec_archs"] = len(ok)
print("RESULT:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_result():
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout[-2000:]
    return json.loads(line[0][len("RESULT:"):])


def test_sharded_loss_matches_single_device(dist_result):
    assert dist_result["loss_match"], dist_result


def test_context_parallel_decode_matches(dist_result):
    assert dist_result["decode_match"]


def test_all_arch_specs_valid(dist_result):
    assert dist_result["spec_archs"] == 10
