"""Cluster control plane: telemetry estimators, stable routing hashes,
admission control, elastic autoscaling and the golden control-plane e2e.

The e2e tests pin the PR's acceptance criteria on seeded workloads:
  * sticky prefix-affinity routing strictly beats KV-headroom routing on
    aggregate prefix hit-rate AND p99 TTFT on the templated multi-template
    workload, with identical per-request committed token counts;
  * the elastic fleet (autoscale + admission control) strictly beats the
    static 2-replica fleet on SLO attainment of admitted traffic on the
    bursty trace, at equal peak replica count;
  * two independently constructed clusters produce byte-identical routing
    decisions for an identical request stream (and the template hash is
    stable across PYTHONHASHSEED values — subprocess-checked).
"""
import hashlib
import os
import subprocess
import sys

import pytest

from repro import configs
from repro.serving.cluster import ACTIVE, DRAINING, RETIRED, ServingCluster
from repro.serving.controlplane import (AdmissionController,
                                        AutoscaleController, ControlPlane,
                                        EWMA, template_key)
from repro.serving.costmodel import RTX_4090
from repro.serving.kv_cache import CHAIN_ROOT, chain_hash
from repro.serving.request import Request
from repro.serving.router import (PrefixAffinityRouter, SLOAwareRouter,
                                  make_router)
from repro.serving.simulator import (SimConfig, build_sim_cluster,
                                     build_sim_engine)
from repro.serving.workload import (bursty_trace, poisson_requests,
                                    templated_requests)


def _cfg(**kw):
    return SimConfig(target=configs.get_config("paper-7b"),
                     draft=configs.get_draft_config("paper-7b"),
                     hw=RTX_4090, max_batch=256, seed=0, **kw)


# ---------------------------------------------------------------------------
# EWMA estimators
# ---------------------------------------------------------------------------


def test_ewma_converges_to_constant():
    e = EWMA(alpha=0.3)
    assert e.value is None and e.get(1.23) == 1.23
    for _ in range(60):
        e.update(5.0)
    assert e.value == pytest.approx(5.0)
    assert e.n == 60


def test_ewma_tracks_level_shift():
    e = EWMA(alpha=0.5)
    for _ in range(20):
        e.update(1.0)
    assert e.value == pytest.approx(1.0)
    for _ in range(20):
        e.update(3.0)
    assert e.value == pytest.approx(3.0, abs=1e-3)
    with pytest.raises(ValueError):
        EWMA(alpha=0.0)


def test_telemetry_learns_from_finished_requests():
    """After a run, the replica's telemetry holds converged TTFT/TPOT and
    slope estimators (fed purely by completed-request stats)."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="slo")
    reqs = poisson_requests(10, 40, dataset="alpaca", seed=3)
    cl.run(reqs)
    for eng in cl.replicas:
        tel = cl.control.tel(eng.replica_id)
        assert tel.ewma_ttft.n == len(eng.metrics.requests) > 0
        assert tel.ewma_ttft.value > 0
        assert tel.ewma_slope.value > 0
        assert not tel._forecasts      # every dispatch got matched


def test_replica_snapshot_observability():
    """ReplicaSnapshot exposes exactly the observable decision state —
    queue/backlog/KV/telemetry — and stays consistent with the forecast."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="slo")
    reqs = poisson_requests(10, 30, dataset="alpaca", seed=4)
    cl.run(reqs)
    eng = cl.replicas[0]
    for i in range(5):
        eng.submit(Request(900 + i, eng.clock, 128, 8))
    snap = cl.control.snapshot(eng, eng.clock, draining=True)
    assert snap.replica_id == 0 and snap.draining
    assert snap.load == eng.load == 5
    assert snap.prefill_backlog_tokens == 5 * 128
    assert snap.decode_count == eng.decode_count == 0
    assert 0.0 < snap.kv_headroom_frac <= 1.0
    assert snap.kv_allocatable <= snap.kv_total
    assert snap.ewma_ttft > 0 and snap.ewma_tpot > 0   # fed by the run
    # the snapshot's nominal forecast is the req=None forecast
    assert snap.predicted_ttft == \
        cl.control.forecast_ttft(eng, None, eng.clock)


def test_forecast_monotone_in_backlog():
    """The predicted TTFT grows with the replica's committed backlog —
    the property deadline-headroom routing relies on."""
    cp = ControlPlane()
    e1 = build_sim_engine(_cfg(), "ar")
    e2 = build_sim_engine(_cfg(), "ar")
    probe = Request(99, 0.0, 64, 8, slo=1.0)
    empty = cp.forecast_ttft(e1, probe, 0.0)
    for i in range(20):
        e2.submit(Request(i, 0.0, 512, 8))
    loaded = cp.forecast_ttft(e2, probe, 0.0)
    assert loaded > empty > 0


# ---------------------------------------------------------------------------
# stable template hashing (satellite: never Python's salted hash())
# ---------------------------------------------------------------------------

# golden values below: chain_hash is a documented cross-process contract —
# if these move, every routing decision and prefix-cache index changes too
def test_chain_hash_golden_values():
    assert chain_hash(CHAIN_ROOT, [0]) == 0x36594F3778015CEB
    assert chain_hash(CHAIN_ROOT, [1, 2, 3, 4]) == 0x9987D60CD5DA12D5
    # chained: parent commits to the whole prefix
    a = chain_hash(chain_hash(CHAIN_ROOT, [1, 2]), [3, 4])
    b = chain_hash(chain_hash(CHAIN_ROOT, [1, 3]), [3, 4])
    assert a != b


def test_template_key_properties():
    assert template_key(None) is None
    assert template_key([]) is None
    t = list(range(100))
    assert template_key(t) == template_key(list(t))
    # only the first window_tokens matter (suffixes don't break stickiness)
    assert template_key(t + [7], 64) == template_key(t + [8], 64)
    assert template_key([1] + t[1:], 64) != template_key(t, 64)


def test_template_key_stable_across_hash_seeds():
    """The routing hash must not depend on PYTHONHASHSEED: two interpreter
    processes with different seeds agree on every template key."""
    code = ("import sys; sys.path.insert(0, 'src');"
            "from repro.serving.controlplane import template_key;"
            "print([template_key(list(range(i, i + 80))) "
            "for i in range(8)])")
    outs = []
    for seed in ("0", "4242"):
        env = dict(os.environ, PYTHONHASHSEED=seed)
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=240,
                             cwd=os.path.join(os.path.dirname(__file__),
                                              ".."))
        assert res.returncode == 0, res.stderr[-1000:]
        outs.append(res.stdout)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# admission control (shed hysteresis)
# ---------------------------------------------------------------------------


def test_admission_shed_hysteresis():
    ac = AdmissionController(shed_factor=1.5, resume_factor=1.0)
    req = Request(0, 0.0, 16, 8, slo=1.0)
    assert not ac.should_shed(req, 1.2)      # over slo but under 1.5x
    assert ac.should_shed(req, 1.6)          # crosses the high threshold
    # hysteresis: keeps shedding in the band even though 1.2 < 1.5x
    assert ac.should_shed(req, 1.2)
    assert ac.should_shed(req, 1.05)
    # resumes only under resume_factor * slo
    assert not ac.should_shed(req, 0.9)
    assert not ac.should_shed(req, 1.2)      # and stays admitting in-band
    assert ac.shed_count == 3


def test_admission_never_sheds_deadline_free():
    ac = AdmissionController(shed_factor=1.5)
    req = Request(0, 0.0, 16, 8, slo=None)
    assert not ac.should_shed(req, 1e9)
    with pytest.raises(ValueError):
        AdmissionController(shed_factor=1.0, resume_factor=1.5)


# ---------------------------------------------------------------------------
# autoscale controller
# ---------------------------------------------------------------------------


def test_autoscaler_windowed_attainment_min_samples():
    sc = AutoscaleController(min_replicas=1, max_replicas=4, window_s=5.0,
                             min_window_samples=4)
    assert sc.window_attainment(0.0) is None
    for t in (0.5, 1.0):
        sc.record_finish(t, True)
    assert sc.window_attainment(1.0) is None      # below min samples
    sc.record_finish(1.5, False)
    sc.record_shed(2.0)                           # shed counts as a miss
    assert sc.window_attainment(2.0) == pytest.approx(2 / 4)
    # old samples age out of the window...
    sc.record_finish(5.8, True)
    sc.record_finish(5.9, True)
    assert sc.window_attainment(6.2) == pytest.approx(2 / 4)
    # ...until the signal thins below min samples and abstains again
    assert sc.window_attainment(10.5) is None


def test_autoscaler_up_on_pressure_down_when_calm_with_cooldown():
    sc = AutoscaleController(min_replicas=1, max_replicas=2, window_s=5.0,
                             cooldown_s=2.0, min_window_samples=2)
    # pressure path: every replica's forecast past the deadline
    assert sc.decide(0.0, 1, [10], min_forecast=2.0, slo=0.5) == "up"
    # cooldown blocks an immediate follow-up
    assert sc.decide(0.5, 2, [10, 10], min_forecast=2.0, slo=0.5) is None
    # calm + attained window + low load -> drain
    for t in (2.5, 2.6, 2.7):
        sc.record_finish(t, True)
    assert sc.decide(3.0, 2, [1, 0], min_forecast=0.1, slo=0.5) == "down"
    # at min_replicas it never drains further
    assert sc.decide(6.0, 1, [0], min_forecast=0.1, slo=0.5) is None


# ---------------------------------------------------------------------------
# elastic fleet mechanics
# ---------------------------------------------------------------------------


def test_drain_never_drops_running_requests():
    """A drained replica finishes everything it owns, then retires."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="rr")
    reqs = poisson_requests(20, 30, dataset="alpaca", seed=5)
    for r in reqs[:10]:
        cl._handle_arrival(r)
    owned = [rid for rid, idx in cl.assignments.items() if idx == 0]
    assert owned and cl.replicas[0].has_work()
    cl.drain_replica(0, now=reqs[9].arrival)
    assert cl.state[0] == DRAINING
    m = cl.run(reqs[10:])
    assert cl.state[0] == RETIRED
    # every request the drained replica owned completed there
    done = {r.req_id for r in cl.replicas[0].metrics.requests}
    assert set(owned) <= done
    # and the whole stream completed exactly once across the fleet
    assert sorted(r.req_id for r in m.requests) == \
        sorted(r.req_id for r in reqs)


def test_no_routing_to_draining_replica():
    cl = build_sim_cluster(_cfg(), 3, "nightjar", router="rr")
    cl.drain_replica(1, now=0.0)
    for i in range(12):
        cl.submit(Request(i, 0.0, 16, 4))
    assert set(cl.assignments.values()) == {0, 2}
    # retire is immediate when the drained replica holds no work
    assert cl.state[1] == RETIRED


def test_fully_drained_fleet_still_serves():
    """Draining every replica by hand must not crash routing: arrivals
    fall back to the drained fleet and still complete."""
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="jsq")
    cl.drain_replica(0, now=0.0)
    cl.drain_replica(1, now=0.0)
    assert cl.state == [RETIRED, RETIRED]    # idle at drain time
    reqs = poisson_requests(5, 6, dataset="alpaca", seed=8)
    m = cl.run(reqs)
    assert len(m.requests) == 6


def test_autoscaler_caps_on_alive_not_active():
    """A draining replica still occupies capacity: the max-replica cap
    counts it, so drain->pressure cannot push the fleet past max."""
    sc = AutoscaleController(min_replicas=1, max_replicas=2, window_s=5.0,
                             cooldown_s=0.0, min_window_samples=2)
    # 1 active + 1 draining = 2 alive: scale-up must be refused even
    # under pressure...
    assert sc.decide(0.0, 1, [10], min_forecast=9.0, slo=0.5,
                     n_alive=2) is None
    # ...and allowed again once the draining replica retires
    assert sc.decide(1.0, 1, [10], min_forecast=9.0, slo=0.5,
                     n_alive=1) == "up"


def test_add_replica_joins_at_virtual_now():
    cl = build_sim_cluster(_cfg(), 1, "nightjar", router="jsq")
    cl.submit(Request(90, 7.5, 16, 4), now=7.5)   # load on the old replica
    rid = cl.add_replica(now=7.5)
    assert rid == 1 and cl.replicas[1].clock == 7.5
    assert cl.state == [ACTIVE, ACTIVE]
    cl.submit(Request(0, 7.5, 16, 4), now=7.5)
    assert cl.assignments[0] == 1        # empty new replica wins JSQ
    assert cl.autoscale_events[0]["kind"] == "add"


# ---------------------------------------------------------------------------
# drain-time host-transfer flush (regression)
# ---------------------------------------------------------------------------


def test_drain_flushes_stranded_host_transfers():
    """Regression (pre-fix: a replica drained with host-tier transfers
    still queued retired with ``pending_spills``/``pending_restores``
    non-empty — spilled payloads were lost and restore-pinned
    ``HostKVStore`` records leaked forever).  The drain-to-retire
    transition must flush both queues, keep invariant I6, and charge the
    modelled restore latency to the replica clock."""
    import numpy as np
    cfg = _cfg(chunk_tokens=384, prefix_caching=True, kv_offload=True,
               num_blocks=8, host_kv_blocks=64, enable_offload=False)
    cl = build_sim_cluster(cfg, 2, "nightjar", router="jsq")
    eng = cl.replicas[0]
    bm = eng.scheduler.bm
    rng = np.random.default_rng(0)
    tokens = [int(t) for t in rng.integers(0, 1000, 3 * bm.block_size)]
    bm.allocate(900, len(tokens))
    bm.register_prefix(900, tokens, len(tokens))
    bm.release(900)                       # 3 blocks park cached
    bm.allocate(901, 8 * bm.block_size)   # evict them -> queued spills
    assert bm.pending_spills
    bm.release(901)
    blocks, cached = bm.match_prefix(tokens)   # host hit -> queued restores
    assert cached == len(tokens) and bm.pending_restores
    assert bm.host_store.pinned

    clock_before = eng.clock
    cl.drain_replica(0, now=0.0)
    # idle at drain time -> retired immediately, with the transfer queues
    # flushed rather than stranded
    assert cl.state[0] == RETIRED
    assert not bm.pending_spills and not bm.pending_restores
    assert not bm.host_store.pinned       # no pinned record leaked
    bm.check_invariants()                 # I6 holds across the drain
    assert eng.clock > clock_before       # restore bytes priced, not free


# ---------------------------------------------------------------------------
# routers on the control-plane signals
# ---------------------------------------------------------------------------


def test_slo_router_prefers_headroom():
    cp = ControlPlane()
    engines = [build_sim_engine(_cfg(), "ar") for _ in range(2)]
    for i, e in enumerate(engines):
        e.replica_id = i
    for i in range(10):
        engines[0].submit(Request(100 + i, 0.0, 512, 8))
    r = SLOAwareRouter(cp)
    assert r.route(Request(0, 0.0, 32, 8, slo=1.0), engines, now=0.0) == 1


def test_affinity_router_sticky_and_spill():
    cp = ControlPlane()
    engines = [build_sim_engine(_cfg(), "ar") for _ in range(2)]
    for i, e in enumerate(engines):
        e.replica_id = i
    r = PrefixAffinityRouter(cp, spill_slack=2.0, default_slo=0.5)
    tmpl = list(range(80))
    req = lambda i, toks: Request(i, 0.0, len(toks), 8,  # noqa: E731
                                  prompt_tokens=toks, slo=0.5)
    home = r.route(req(0, tmpl + [1]), engines, now=0.0)
    # same template sticks to its home regardless of load ordering
    assert r.route(req(1, tmpl + [2]), engines, now=0.0) == home
    # overload the home replica far past the deadline -> spillover, but
    # the home mapping survives for when pressure clears
    for i in range(400):
        engines[home].submit(Request(500 + i, 0.0, 1024, 8))
    spill = r.route(req(2, tmpl + [3]), engines, now=0.0)
    assert spill != home and r.spills == 1
    assert r.home[template_key(tmpl)] == engines[home].replica_id


def test_affinity_route_never_sticks_to_dead_home():
    """Regression (pre-fix: draining a replica never reached the router,
    so the sticky home map kept pointing at the corpse — any caller whose
    replica set still contained it, e.g. an external dispatcher or the
    cluster's fully-drained fallback tier, had traffic routed straight to
    a DRAINING/RETIRED replica)."""
    cp = ControlPlane()
    engines = [build_sim_engine(_cfg(), "ar") for _ in range(3)]
    for i, e in enumerate(engines):
        e.replica_id = i
    r = PrefixAffinityRouter(cp)
    tmpl = list(range(80))
    req = lambda i: Request(i, 0.0, 81, 8,  # noqa: E731
                            prompt_tokens=tmpl + [i])
    home = r.route(req(0), engines, now=0.0)
    assert r.route(req(1), engines, now=0.0) == home
    r.note_replica_dead(engines[home].replica_id)
    # the stale home entry is purged immediately...
    assert template_key(tmpl) not in r.home
    assert r.rehomes == 1
    # ...and the template re-homes STICKILY on a live replica even though
    # this caller's set still contains the dead one
    new = r.route(req(2), engines, now=0.0)
    assert new != home
    assert r.route(req(3), engines, now=0.0) == new
    assert r.home[template_key(tmpl)] == engines[new].replica_id


def test_affinity_rehomes_after_drain_midtrace():
    """Drain a home replica mid-trace through the cluster: no later
    arrival lands on the DRAINING/RETIRED replica, its templates re-home,
    and the fleet's aggregate prefix hit-rate recovers on the new homes."""
    cfg = _cfg(chunk_tokens=384, prefix_caching=True)
    cl = build_sim_cluster(cfg, 3, "nightjar", router="affinity")
    reqs = templated_requests(60, 140, num_templates=8, seed=1)
    pending = sorted(reqs, key=lambda r: (r.arrival, r.req_id))
    cut = 40
    for r in pending[:cut]:
        cl._handle_arrival(r)
    # drain the replica hosting the most sticky homes
    homes = list(cl.router.home.values())
    assert homes
    victim = max(set(homes), key=lambda rid: (homes.count(rid), rid))
    cl.drain_replica(victim, now=pending[cut].arrival)
    m = cl.run(pending[cut:])
    assert cl.state[victim] == RETIRED
    # every post-drain arrival avoided the drained replica
    later = {r.req_id for r in pending[cut:]}
    assert all(idx != victim for rid, idx in m.assignments.items()
               if rid in later)
    # its templates re-homed and stuck to live replicas
    assert victim not in set(cl.router.home.values())
    assert cl.router.rehomes > 0
    # hit-rate recovers: followers share the re-homed caches
    assert m.prefix_hit_rate > 0.5
    # nothing dropped across the drain
    assert sorted(r.req_id for r in m.requests) == \
        sorted(r.req_id for r in reqs)


def test_make_router_names_and_back_compat():
    from repro.serving.router import (JoinShortestQueue, KVHeadroomRouter,
                                      RoundRobinRouter)
    assert isinstance(make_router("rr"), RoundRobinRouter)
    assert isinstance(make_router("jsq"), JoinShortestQueue)
    assert isinstance(make_router("kv"), KVHeadroomRouter)
    assert isinstance(make_router("slo"), SLOAwareRouter)
    assert isinstance(make_router("affinity"), PrefixAffinityRouter)
    with pytest.raises(KeyError):
        make_router("nope")
    # legacy positional route() signature still works
    engines = [build_sim_engine(_cfg(), "ar") for _ in range(2)]
    assert make_router("jsq").route(Request(0, 0.0, 8, 4), engines) == 0


# ---------------------------------------------------------------------------
# golden control-plane e2e (the PR's acceptance criteria)
# ---------------------------------------------------------------------------


def _stream_sha(m):
    stream = sorted((r.req_id, r.tokens) for r in m.requests)
    return hashlib.sha256(repr(stream).encode()).hexdigest()


def _run_templated(router):
    cfg = _cfg(chunk_tokens=384, prefix_caching=True)
    cl = build_sim_cluster(cfg, 2, "nightjar", router=router)
    reqs = templated_requests(60, 140, num_templates=8, seed=1)
    return cl.run(reqs), cl


def test_affinity_beats_kv_on_templated_golden():
    """Sticky template routing specialises the replicas' prefix caches:
    strictly higher aggregate hit-rate AND strictly lower p99 TTFT than
    KV-headroom routing, with identical per-request committed token
    counts."""
    m_kv, _ = _run_templated("kv")
    m_aff, _ = _run_templated("affinity")
    assert len(m_kv.requests) == len(m_aff.requests) == 140
    assert _stream_sha(m_aff) == _stream_sha(m_kv)
    assert m_aff.prefix_hit_rate > m_kv.prefix_hit_rate
    assert m_aff.ttft_percentile(0.99) < m_kv.ttft_percentile(0.99)


def _run_bursty(elastic):
    trace = bursty_trace(base=4, spike=160, base_s=8, spike_s=5,
                         drain_s=12, drain=2, seed=2)
    reqs = trace.sample_requests(860, dataset="alpaca", seed=3)
    kw = {}
    if elastic:
        kw = dict(shed_factor=1.5,
                  autoscale=dict(min_replicas=1, max_replicas=2,
                                 window_s=8.0))
    cl = build_sim_cluster(_cfg(), 2, "nightjar", router="slo", **kw)
    return cl.run(reqs), cl


def test_autoscale_beats_static_on_bursty_golden():
    """The elastic fleet (autoscale to the same peak + admission control)
    strictly beats the always-on 2-replica fleet on SLO attainment of
    admitted traffic — shed requests are accounted separately, and the
    elastic fleet pays fewer replica-seconds."""
    m_st, _ = _run_bursty(elastic=False)
    m_el, cl = _run_bursty(elastic=True)
    assert m_st.shed_count == 0
    assert m_el.peak_replicas == 2       # equal peak replica count
    assert m_el.slo_attainment > m_st.slo_attainment
    assert m_el.replica_seconds < m_st.replica_seconds
    assert m_el.shed_count > 0
    # the fleet actually scaled (1 -> 2) under the spike
    assert any(e["kind"] == "add" for e in m_el.autoscale_events)
    # honest offered-load accounting is also reported
    assert 0.0 < m_el.slo_attainment_offered < m_el.slo_attainment


def test_routing_decisions_byte_identical_across_runs():
    """Two independently constructed clusters given the same stream make
    byte-identical routing / shedding decisions (the determinism
    acceptance criterion), including under the full control plane."""
    a, _ = _run_templated("affinity")
    b, _ = _run_templated("affinity")
    assert a.assignments == b.assignments
    assert _stream_sha(a) == _stream_sha(b)
    x, _ = _run_bursty(elastic=True)
    y, _ = _run_bursty(elastic=True)
    assert x.assignments == y.assignments
    assert [s["req_id"] for s in x.shed] == [s["req_id"] for s in y.shed]
    assert x.autoscale_events == y.autoscale_events


def test_cluster_summary_per_replica_breakdown():
    m, _ = _run_templated("affinity")
    s = m.summary()
    assert len(s["per_replica"]) == 2
    for row in s["per_replica"]:
        assert {"replica", "state", "requests", "slo_attainment",
                "offloads", "p99_ttft_s"} <= set(row)
        assert "prefix_hit_rate" in row      # caching was on
    assert s["prefix_hit_rate"] > 0
    m2, _ = _run_bursty(elastic=True)
    s2 = m2.summary()
    assert s2["shed_count"] == m2.shed_count > 0
    assert s2["peak_replicas"] == 2
    assert s2["autoscale"]["adds"] >= 1
    assert s2["replica_seconds"] > 0


# ---------------------------------------------------------------------------
# slope-estimator regression: idle dispatches must not poison the forecast
# ---------------------------------------------------------------------------


def _feed_telemetry(tel, observations):
    """Drive ReplicaTelemetry through (backlog_tokens, observed_ttft)
    dispatch/finish pairs via a stub engine."""
    from types import SimpleNamespace

    from repro.serving.controlplane import ReplicaTelemetry  # noqa: F401
    from repro.serving.request import RequestStats

    stats = []
    eng = SimpleNamespace(metrics=SimpleNamespace(requests=stats))
    for i, (backlog, ttft) in enumerate(observations):
        tel.note_dispatch(i, forecast=0.0, backlog_tokens=backlog)
        stats.append(RequestStats(req_id=i, arrival=0.0, ttft=ttft,
                                  tpot=0.01, tokens=8, slo=None))
        tel.consume_finished(eng)


def test_idle_dispatches_do_not_poison_slope():
    """Regression (pre-fix: a zero-backlog dispatch updated ewma_slope with
    ttft / max(backlog, 1) = the replica's BASELINE TTFT, teaching the
    forecaster a seconds-per-backlog-token four orders of magnitude too
    large).  Alternating idle/busy dispatches must keep the learned slope
    within tolerance of the busy-only estimate."""
    from repro.serving.controlplane import ReplicaTelemetry

    floor, slope, backlog = 0.05, 2e-5, 4000
    busy = (backlog, floor + slope * backlog)       # ttft = 0.13
    idle = (0, floor)

    tel_busy = ReplicaTelemetry(alpha=0.3)
    _feed_telemetry(tel_busy, [busy] * 40)
    tel_alt = ReplicaTelemetry(alpha=0.3)
    _feed_telemetry(tel_alt, [idle, busy] * 40)

    ref = tel_busy.ewma_slope.value
    alt = tel_alt.ewma_slope.value
    assert ref == pytest.approx((floor + slope * backlog) / backlog)
    # pre-fix the alternating estimate converges toward ~floor/1 = 0.05
    # seconds-per-token (>1500x the busy-only slope); post-fix the idle
    # dispatches are skipped and the estimates agree
    assert alt == pytest.approx(ref, rel=0.05)
    # and idle observations still feed the residual/level estimators
    assert tel_alt.ewma_ttft.n == 80
