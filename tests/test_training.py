"""Training substrate: loss goes down, checkpoint/restart is exact."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.training import checkpoint as ckpt
from repro.training.data import SyntheticLM, make_batch_iter
from repro.training.optimizer import adamw_init
from repro.training.train_loop import make_train_step, train


def _tiny_cfg():
    return configs.reduced(configs.get_config("deepseek-7b")).replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128)


def test_loss_decreases():
    cfg = _tiny_cfg()
    it = make_batch_iter(cfg.vocab_size, batch=4, seq=32, seed=0)
    out = train(cfg, steps=40, batch_iter=it, checkpoint_dir=None,
                base_lr=3e-3, warmup=2)
    losses = [h["loss"] for h in out["history"]]
    assert min(losses) < losses[0] - 0.2, losses


def test_data_pipeline_deterministic():
    ds = SyntheticLM(128, seed=3)
    a = ds.batch_at(7, 4, 16)
    b = ds.batch_at(7, 4, 16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = ds.batch_at(8, 4, 16)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_checkpoint_restart_exact(tmp_path):
    """Fault tolerance: kill after step 20, resume, final state identical to
    an uninterrupted run."""
    cfg = _tiny_cfg()
    it = make_batch_iter(cfg.vocab_size, batch=4, seq=32, seed=1)

    d1 = str(tmp_path / "uninterrupted")
    full = train(cfg, steps=24, batch_iter=it, checkpoint_dir=d1,
                 checkpoint_every=8)

    d2 = str(tmp_path / "crashy")
    train(cfg, steps=16, batch_iter=it, checkpoint_dir=d2, checkpoint_every=8)
    # "crash" here; resume to 24
    resumed = train(cfg, steps=24, batch_iter=it, checkpoint_dir=d2,
                    checkpoint_every=8, resume=True)

    flat1 = jax.tree.leaves(full["params"])
    flat2 = jax.tree.leaves(resumed["params"])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_checkpoint_atomic_pointer(tmp_path):
    """A half-written checkpoint directory never becomes LATEST."""
    cfg = _tiny_cfg()
    api, _ = make_train_step(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    d = str(tmp_path / "ck")
    ckpt.save(d, params, opt, {"step": 5})
    # simulate a crash leaving a stale tmp dir
    os.makedirs(os.path.join(d, "step_9.tmp"), exist_ok=True)
    p_t = jax.eval_shape(lambda: params)
    o_t = jax.eval_shape(lambda: opt)
    restored = ckpt.restore_latest(d, template={"params": p_t, "opt": o_t})
    assert restored is not None
    _, _, meta = restored
    assert meta["step"] == 5


def test_grad_accumulation_equivalence():
    """accum=2 over a split batch == accum=1 over the full batch (same loss
    direction; grads averaged)."""
    cfg = _tiny_cfg()
    api, step1 = make_train_step(cfg, accum=1)
    _, step2 = make_train_step(cfg, accum=2)
    params = api.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    it = make_batch_iter(cfg.vocab_size, batch=8, seq=32, seed=2)
    batch = it(0)
    m1, p1, _ = step1(params, opt, batch)
    micro = {k: v.reshape(2, 4, *v.shape[1:]) for k, v in batch.items()}
    m2, p2, _ = step2(params, opt, micro)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
