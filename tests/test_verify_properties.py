"""Hypothesis property tests for rejection-sampling verification (optional).

Skipped wholesale when hypothesis is not installed; the seeded parametrized
equivalents in tests/test_verify.py keep the invariants covered in tier-1.
Install via requirements-dev.txt to enable this module.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.verify import verify_rejection  # noqa: E402


def _dist(rng, V, temp):
    x = rng.normal(size=V) * temp
    e = np.exp(x - x.max())
    return e / e.sum()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), vocab=st.integers(2, 6),
       temp=st.floats(0.3, 3.0))
def test_first_position_distribution_preserved(seed, vocab, temp):
    """Empirical distribution of the first committed token ~= target p."""
    rng = np.random.default_rng(seed)
    p = _dist(rng, vocab, temp)
    q = _dist(rng, vocab, temp * 2)

    N = 20_000
    g = 1
    key = jax.random.PRNGKey(seed)
    kd, kv = jax.random.split(key)
    draft_tokens = jax.random.categorical(
        kd, jnp.log(jnp.asarray(q))[None, :].repeat(N, 0))[:, None]
    draft_probs = jnp.broadcast_to(jnp.asarray(q), (N, g, vocab))
    target_probs = jnp.broadcast_to(jnp.asarray(p), (N, g + 1, vocab))

    res = verify_rejection(kv, draft_tokens, draft_probs, target_probs)
    first = np.asarray(res["tokens"][:, 0])
    emp = np.bincount(first, minlength=vocab) / N
    assert np.max(np.abs(emp - p)) < 0.02, (emp, p)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), vocab=st.integers(2, 8),
       g=st.integers(1, 4))
def test_committed_structure_invariants(seed, vocab, g):
    """n_accepted in [0, g]; committed = accepted prefix + 1 sampled token;
    padding is -1 beyond n_accepted+1."""
    rng = np.random.default_rng(seed)
    B = 16
    key = jax.random.PRNGKey(seed)
    draft_tokens = jnp.asarray(rng.integers(0, vocab, size=(B, g)))
    dp = rng.dirichlet(np.ones(vocab), size=(B, g))
    tp = rng.dirichlet(np.ones(vocab), size=(B, g + 1))
    res = verify_rejection(key, draft_tokens, jnp.asarray(dp), jnp.asarray(tp))
    n = np.asarray(res["n_accepted"])
    toks = np.asarray(res["tokens"])
    assert ((0 <= n) & (n <= g)).all()
    for b in range(B):
        assert (toks[b, :n[b]] == np.asarray(draft_tokens)[b, :n[b]]).all()
        assert toks[b, n[b]] >= 0
        assert (toks[b, n[b] + 1:] == -1).all()
        assert toks[b, n[b]] == int(res["next_token"][b])
