"""Continuous batching scheduler: admission, block gating, preemption,
deadline-aware prefill ordering."""
import pytest

from repro.serving.kv_cache import BlockManager
from repro.serving.request import Request, Sequence
from repro.serving.scheduler import ContinuousBatchingScheduler


def _reqs(n, prompt=8, out=8):
    return [Request(i, float(i) * 0.01, prompt, out) for i in range(n)]


def test_admission_respects_max_batch():
    bm = BlockManager(1000, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=3)
    for r in _reqs(5):
        s.add_request(r)
    admitted = s.schedule()
    assert len(admitted) == 3
    assert s.num_waiting == 2


def test_admission_respects_blocks():
    bm = BlockManager(8, 4)   # 32 tokens capacity
    s = ContinuousBatchingScheduler(bm, max_batch=10, watermark_frac=0.0)
    for r in _reqs(5, prompt=11):  # 3 blocks each (11+1 tokens)
        s.add_request(r)
    admitted = s.schedule()
    assert len(admitted) == 2      # 3rd would need 3 blocks, only 2 left
    # finishing one frees blocks for the next
    s.finish(admitted[0])
    assert len(s.schedule()) == 1


def test_admission_blocked_below_watermark():
    """Admission stops when it would push free blocks under the watermark,
    even though the allocation itself would fit."""
    bm = BlockManager(100, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=64, watermark_frac=0.1)
    # each request needs 3 blocks (9 tokensized: 8+1 -> 3 blocks of 4)
    for r in _reqs(40, prompt=8):
        s.add_request(r)
    admitted = s.schedule()
    # watermark = 10 blocks: admissions stop once free - 3 < 10
    assert 0 < len(admitted) < 40
    assert bm.num_free >= 10
    assert bm.num_free - 3 < 10   # the next one WOULD have crossed it
    assert s.num_waiting == 40 - len(admitted)
    # with the watermark off, the same state admits more
    s.watermark_frac = 0.0
    assert len(s.schedule()) > 0


def test_preempt_evicts_youngest_on_out_of_blocks():
    """When commit_tokens hits OutOfBlocks, the victim is the YOUNGEST
    running sequence (latest arrival), not the committing one."""
    bm = BlockManager(9, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=8, watermark_frac=0.0)
    for r in _reqs(3, prompt=7):   # 2 blocks each -> 6 used, 3 free
        s.add_request(r)
    oldest, middle, youngest = s.schedule()
    assert youngest.request.arrival > middle.request.arrival
    # oldest grows by 12 tokens -> needs 3 new blocks, only 3 free: first
    # append succeeds; keep growing until eviction triggers
    for _ in range(4):
        ok = s.commit_tokens(oldest, 4)
        assert ok   # the committing sequence itself survives
        if youngest not in s.running:
            break
    assert youngest not in s.running          # youngest evicted first
    assert middle in s.running                # older survivor untouched
    assert oldest in s.running
    assert s.waiting[0] is youngest.request   # requeued at the FRONT
    bm.check_invariants()


def test_freed_blocks_reusable_same_step():
    """Blocks released by finish() are allocatable in the same scheduling
    step (no deferred reclamation)."""
    bm = BlockManager(4, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=8, watermark_frac=0.0)
    for r in _reqs(2, prompt=7):   # 2 blocks each
        s.add_request(r)
    (a, b) = s.schedule()
    assert bm.num_free == 0
    s.add_request(_reqs(3, prompt=7)[2])
    assert s.schedule() == []      # pool exhausted, c cannot enter
    s.finish(a)                    # frees 2 blocks...
    admitted = s.schedule()        # ...immediately reusable
    assert len(admitted) == 1
    assert bm.num_free == 0
    bm.check_invariants()


# ---------------------------------------------------------------------------
# deadline-aware prefill admission (prefill_order="slo")
# ---------------------------------------------------------------------------


def _slo_sched(chunk=32, order="slo"):
    bm = BlockManager(1000, 4)
    return ContinuousBatchingScheduler(bm, max_batch=8, watermark_frac=0.0,
                                       chunk_tokens=chunk,
                                       prefill_order=order)


def test_slo_order_admits_earliest_deadline_first():
    """Under budget contention the tightest TTFT deadline wins admission,
    regardless of arrival order; deadline-free requests sort last."""
    s = _slo_sched(chunk=40)
    s.add_request(Request(0, 0.0, 40, 2, slo=None))      # no deadline
    s.add_request(Request(1, 0.1, 40, 2, slo=5.0))       # deadline 5.1
    s.add_request(Request(2, 0.2, 40, 2, slo=1.0))       # deadline 1.2 (!)
    batch = s.schedule_chunks()
    assert [c[0].req_id for c in batch.prefill_chunks] == [2]
    batch2 = s.schedule_chunks()
    assert [c[0].req_id for c in batch2.prefill_chunks][0] == 2  # continues
    # FIFO among the rest once 2 finishes its prompt
    ids = [c[0].req_id for c in batch2.prefill_chunks]
    assert ids in ([2], [2, 1])


def test_slo_order_fifo_among_equal_deadlines():
    s = _slo_sched(chunk=16)
    s.add_request(Request(0, 0.0, 16, 2, slo=1.0))
    s.add_request(Request(1, 0.0, 16, 2, slo=1.0))       # same deadline
    batch = s.schedule_chunks()
    assert [c[0].req_id for c in batch.prefill_chunks] == [0]


def test_fifo_order_is_default_and_unchanged():
    s = _slo_sched(chunk=40, order="fifo")
    s.add_request(Request(0, 0.0, 40, 2, slo=None))
    s.add_request(Request(1, 0.1, 40, 2, slo=0.1))
    batch = s.schedule_chunks()
    assert [c[0].req_id for c in batch.prefill_chunks] == [0]


def test_slo_order_keeps_midprefill_progress_guarantee():
    """A running mid-prefill sequence is still served before ANY admission,
    even when a newer arrival has a tighter deadline (no starvation)."""
    s = _slo_sched(chunk=16)
    s.add_request(Request(0, 0.0, 64, 2, slo=10.0))
    b = s.schedule_chunks()
    assert [c[0].req_id for c in b.prefill_chunks] == [0]
    for seq, n in b.prefill_chunks:
        seq.prefilled += n
    s.add_request(Request(1, 0.5, 8, 2, slo=0.1))        # urgent newcomer
    b2 = s.schedule_chunks()
    ids = [c[0].req_id for c in b2.prefill_chunks]
    assert ids[0] == 0                                   # continue first
    assert b2.prefill_chunks[0][1] == 16


def test_invalid_prefill_order_rejected():
    bm = BlockManager(8, 4)
    with pytest.raises(ValueError):
        ContinuousBatchingScheduler(bm, chunk_tokens=8,
                                    prefill_order="deadline")


def test_preemption_recompute():
    bm = BlockManager(6, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=4, watermark_frac=0.0)
    for r in _reqs(2, prompt=7):   # 2 blocks each
        s.add_request(r)
    a, b = s.schedule()
    # grow sequence a until the pool is exhausted -> b preempted (youngest)
    ok = True
    for _ in range(20):
        ok = s.commit_tokens(a, 4)
        if b not in s.running:
            break
    assert b not in s.running
    assert s.num_waiting == 1     # b requeued for recompute
    assert a in s.running
    bm.check_invariants()
