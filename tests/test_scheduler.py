"""Continuous batching scheduler: admission, block gating, preemption."""
import pytest

from repro.serving.kv_cache import BlockManager
from repro.serving.request import Request, Sequence
from repro.serving.scheduler import ContinuousBatchingScheduler


def _reqs(n, prompt=8, out=8):
    return [Request(i, float(i) * 0.01, prompt, out) for i in range(n)]


def test_admission_respects_max_batch():
    bm = BlockManager(1000, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=3)
    for r in _reqs(5):
        s.add_request(r)
    admitted = s.schedule()
    assert len(admitted) == 3
    assert s.num_waiting == 2


def test_admission_respects_blocks():
    bm = BlockManager(8, 4)   # 32 tokens capacity
    s = ContinuousBatchingScheduler(bm, max_batch=10, watermark_frac=0.0)
    for r in _reqs(5, prompt=11):  # 3 blocks each (11+1 tokens)
        s.add_request(r)
    admitted = s.schedule()
    assert len(admitted) == 2      # 3rd would need 3 blocks, only 2 left
    # finishing one frees blocks for the next
    s.finish(admitted[0])
    assert len(s.schedule()) == 1


def test_preemption_recompute():
    bm = BlockManager(6, 4)
    s = ContinuousBatchingScheduler(bm, max_batch=4, watermark_frac=0.0)
    for r in _reqs(2, prompt=7):   # 2 blocks each
        s.add_request(r)
    a, b = s.schedule()
    # grow sequence a until the pool is exhausted -> b preempted (youngest)
    ok = True
    for _ in range(20):
        ok = s.commit_tokens(a, 4)
        if b not in s.running:
            break
    assert b not in s.running
    assert s.num_waiting == 1     # b requeued for recompute
    assert a in s.running
    bm.check_invariants()
