"""Uniform model API over all families + streamed cross-entropy loss.

get_model(cfg) returns a :class:`ModelAPI` with
  init(rng) -> params
  forward(params, batch) -> final hidden states (B, S, d)
  loss(params, batch) -> (scalar loss, metrics)
  init_cache(batch_size, max_len) -> cache pytree
  prefill(params, batch, max_len) -> (last_logits, cache)
  decode_step(params, cache, tokens[, positions]) -> (logits, cache)

The training loss streams the unembedding over sequence chunks (never
materialising a (B, S, V) logits tensor) — essential for 256k-row vocabs at
4k sequence length.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec, hybrid, mamba2, transformer


@dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    # paged-KV serving path (attention families only — SSM state is O(1)):
    #   init_paged_cache(num_blocks, block_size) -> pages pytree
    #   decode_step_paged(params, pages, tokens, tables, start[, valid])
    #     -> (logits, pages)
    init_paged_cache: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None

    @property
    def supports_paged(self) -> bool:
        return self.decode_step_paged is not None


def _unembed_table(cfg, params):
    if cfg.family == "encdec" or cfg.tie_embeddings or "lm_head" not in params:
        return params["embed"]
    return params["lm_head"]


def streamed_xent(cfg, params, hidden, labels):
    """Chunked softmax cross-entropy. hidden: (B, S, d); labels: (B, S).

    Label value -100 is ignored (masked)."""
    table = _unembed_table(cfg, params)
    B, S, d = hidden.shape
    chunk = min(cfg.xent_chunk, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    hc = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_fn(h, y):
        logits = jnp.einsum("bsd,vd->bsv", h, table,
                            preferred_element_type=jnp.float32)
        if cfg.logit_softcap > 0.0:
            logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = y >= 0
        safe_y = jnp.maximum(y, 0)
        gold = jnp.take_along_axis(logits, safe_y[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - gold, 0.0)
        return jnp.sum(nll), jnp.sum(mask)

    def body(carry, xs):
        tot, cnt = carry
        h, y = xs
        s, c = chunk_fn(h, y)
        return (tot + s, cnt + c), None

    if cfg.unroll_scans:
        carry = (jnp.float32(0.0), jnp.int32(0))
        for i in range(n):
            carry, _ = body(carry, (hc[i], lc[i]))
        tot, cnt = carry
    else:
        (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.int32(0)),
                                     (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def _make_loss(cfg, fwd):
    def loss(params, batch):
        hidden = fwd(cfg, params, batch)
        l = streamed_xent(cfg, params, hidden, batch["labels"])
        return l, {"loss": l}
    return loss


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba2,
    "hybrid": hybrid,
    "encdec": encdec,
}


def get_model(cfg: ModelConfig) -> ModelAPI:
    mod = _FAMILY_MODULES[cfg.family]
    fwd = mod.forward

    def loss(params, batch):
        hidden = fwd(cfg, params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm" and "image_emb" in batch:
            hidden = hidden[:, cfg.num_image_tokens:, :]
        return streamed_xent(cfg, params, hidden, labels), {}

    paged = {}
    if mod is transformer:
        paged = {
            "init_paged_cache": functools.partial(transformer.init_paged_cache,
                                                  cfg),
            "decode_step_paged": functools.partial(
                transformer.decode_step_paged, cfg),
        }
    return ModelAPI(
        cfg=cfg,
        init=functools.partial(_init, mod, cfg),
        forward=functools.partial(fwd, cfg),
        loss=loss,
        init_cache=functools.partial(mod.init_cache, cfg),
        prefill=functools.partial(mod.prefill, cfg),
        decode_step=functools.partial(mod.decode_step, cfg),
        **paged,
    )


def _init(mod, cfg, rng):
    return mod.init_params(rng, cfg)


def param_specs(cfg: ModelConfig):
    """ShapeDtypeStructs of the parameter pytree — no allocation."""
    mod = _FAMILY_MODULES[cfg.family]
    return jax.eval_shape(lambda k: mod.init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    import numpy as np
    return int(sum(np.prod(s.shape) for s in jax.tree.leaves(specs)))


def param_bytes(cfg: ModelConfig) -> int:
    specs = param_specs(cfg)
    import numpy as np
    return int(sum(np.prod(s.shape) * s.dtype.itemsize
                   for s in jax.tree.leaves(specs)))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE counts top-k experts only)."""
    total = param_count(cfg)
    if not cfg.moe_num_experts:
        return total
    # subtract the inactive experts' MLP weights
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = (cfg.moe_num_experts - cfg.moe_top_k) * per_expert * cfg.num_layers
    return total - inactive
