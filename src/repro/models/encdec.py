"""Whisper-style encoder-decoder backbone.

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings ``(B, S_enc, d_model)``.  The encoder
is bidirectional self-attention; the decoder has causal self-attention plus
cross-attention over the encoder output.  LayerNorm + GELU (whisper).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activations
from .common import (
    apply_mlp,
    attn_output,
    blockwise_attention,
    cache_write,
    decode_attention,
    embed_init,
    init_attention,
    init_mlp,
    layer_norm,
    qkv_project,
)

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init_ln(cfg, d, dt):
    return {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}


def _ln(x, p):
    return layer_norm(x, p["scale"], p["bias"])


def init_enc_layer(cfg, key):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(cfg, k1, dt),
        "mlp": init_mlp(cfg, k2, dt),
        "ln1": _init_ln(cfg, cfg.d_model, dt),
        "ln2": _init_ln(cfg, cfg.d_model, dt),
    }


def init_dec_layer(cfg, key):
    dt = _dtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_attn": init_attention(cfg, k1, dt),
        "cross_attn": init_attention(cfg, k3, dt, cross=True),
        "mlp": init_mlp(cfg, k2, dt),
        "ln1": _init_ln(cfg, cfg.d_model, dt),
        "ln2": _init_ln(cfg, cfg.d_model, dt),
        "ln3": _init_ln(cfg, cfg.d_model, dt),
    }


def init_params(rng, cfg) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(rng, 6)
    enc_keys = jax.random.split(keys[0], cfg.enc_layers)
    dec_keys = jax.random.split(keys[1], cfg.dec_layers)
    if cfg.scan_layers:
        enc_layers = jax.vmap(lambda k: init_enc_layer(cfg, k))(enc_keys)
        dec_layers = jax.vmap(lambda k: init_dec_layer(cfg, k))(dec_keys)
    else:
        enc_layers = [init_enc_layer(cfg, k) for k in enc_keys]
        dec_layers = [init_dec_layer(cfg, k) for k in dec_keys]
    return {
        "embed": embed_init(keys[2], (cfg.vocab_size, cfg.d_model), dt),
        "enc_pos": embed_init(keys[3], (cfg.max_position_embeddings, cfg.d_model), dt),
        "dec_pos": embed_init(keys[4], (cfg.max_position_embeddings, cfg.d_model), dt),
        "enc_layers": enc_layers,
        "dec_layers": dec_layers,
        "enc_norm": _init_ln(cfg, cfg.d_model, dt),
        "dec_norm": _init_ln(cfg, cfg.d_model, dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(cfg, params, enc_emb):
    B, S, _ = enc_emb.shape
    h = enc_emb.astype(_dtype(cfg)) + params["enc_pos"][jnp.arange(S)][None]
    positions = jnp.arange(S)[None, :]

    def body(hh, layer):
        x = _ln(hh, layer["ln1"])
        q, k, v = qkv_project(cfg, layer["attn"], x, positions, use_rope=False)
        o = blockwise_attention(q, k, v, causal=False, chunk=cfg.attn_chunk, unroll=cfg.unroll_scans)
        hh = hh + attn_output(layer["attn"], o)
        x = _ln(hh, layer["ln2"])
        return shard_activations(hh + apply_mlp(cfg, layer["mlp"], x)), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
    else:
        for layer in params["enc_layers"]:
            h, _ = body(h, layer)
    return _ln(h, params["enc_norm"])


# ---------------------------------------------------------------------------
# Decoder (full sequence — teacher forcing / prefill)
# ---------------------------------------------------------------------------


def _cross_kv(layer, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wv"])
    return k, v


def _dec_layer_full(cfg, layer, h, enc_out, positions):
    x = _ln(h, layer["ln1"])
    q, k, v = qkv_project(cfg, layer["self_attn"], x, positions, use_rope=False)
    o = blockwise_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, unroll=cfg.unroll_scans)
    h = h + attn_output(layer["self_attn"], o)
    x = _ln(h, layer["ln2"])
    qc = jnp.einsum("bsd,dhk->bshk", x, layer["cross_attn"]["wq"])
    kc, vc = _cross_kv(layer, enc_out)
    oc = blockwise_attention(qc, kc, vc, causal=False, chunk=cfg.attn_chunk, unroll=cfg.unroll_scans)
    h = h + attn_output(layer["cross_attn"], oc)
    x = _ln(h, layer["ln3"])
    return h + apply_mlp(cfg, layer["mlp"], x), k, v, kc, vc


def forward(cfg, params, batch):
    enc_out = encode(cfg, params, batch["enc_emb"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"][tokens] + params["dec_pos"][jnp.arange(S)][None]
    positions = jnp.arange(S)[None, :]

    def body(hh, layer):
        hh, *_ = _dec_layer_full(cfg, layer, hh, enc_out, positions)
        return shard_activations(hh), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["dec_layers"])
    else:
        for layer in params["dec_layers"]:
            h, _ = body(h, layer)
    return _ln(h, params["dec_norm"])


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int, enc_len: int | None = None):
    dt = _dtype(cfg)
    KH, hd, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.dec_layers
    enc_len = enc_len or cfg.enc_context
    return {
        "k": jnp.zeros((L, batch_size, max_len, KH, hd), dt),
        "v": jnp.zeros((L, batch_size, max_len, KH, hd), dt),
        "cross_k": jnp.zeros((L, batch_size, enc_len, KH, hd), dt),
        "cross_v": jnp.zeros((L, batch_size, enc_len, KH, hd), dt),
        "enc_len": jnp.zeros((batch_size,), jnp.int32),
        "length": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(cfg, params, batch, max_len: int):
    enc_out = encode(cfg, params, batch["enc_emb"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    S_enc = enc_out.shape[1]
    h = params["embed"][tokens] + params["dec_pos"][jnp.arange(S)][None]
    positions = jnp.arange(S)[None, :]
    cache = init_cache(cfg, B, max_len, enc_len=S_enc)

    def body(hh, layer):
        hh, k, v, kc, vc = _dec_layer_full(cfg, layer, hh, enc_out, positions)
        return hh, (k, v, kc, vc)

    if cfg.scan_layers:
        h, (ks, vs, kcs, vcs) = jax.lax.scan(body, h, params["dec_layers"])
    else:
        outs = []
        for layer in params["dec_layers"]:
            h, k, v, kc, vc = _dec_layer_full(cfg, layer, h, enc_out, positions)
            outs.append((k, v, kc, vc))
        ks, vs, kcs, vcs = (jnp.stack([o[i] for o in outs]) for i in range(4))

    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["cross_k"] = kcs.astype(cache["cross_k"].dtype)
    cache["cross_v"] = vcs.astype(cache["cross_v"].dtype)
    cache["enc_len"] = jnp.full((B,), S_enc, jnp.int32)
    cache["length"] = jnp.full((B,), S, jnp.int32)
    h = _ln(h, params["dec_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h[:, -1:], params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, cache


def decode_step(cfg, params, cache, tokens, positions=None):
    B, T = tokens.shape
    if positions is None:
        positions = cache["length"][:, None] + jnp.arange(T)[None, :]
    h = params["embed"][tokens] + params["dec_pos"][positions]
    enc_positions = jnp.broadcast_to(
        (cache["enc_len"] - 1)[:, None], (B, T))  # full visibility over enc

    def layer_step(hh, xs):
        layer, kc, vc, ck, cv = xs
        x = _ln(hh, layer["ln1"])
        q, k, v = qkv_project(cfg, layer["self_attn"], x, positions, use_rope=False)
        from ..distributed.sharding import replicate_new_kv, shard_kv_cache
        start = positions[:, 0]
        kc = shard_kv_cache(cache_write(kc, replicate_new_kv(k), start))
        vc = shard_kv_cache(cache_write(vc, replicate_new_kv(v), start))
        o = decode_attention(q, kc, vc, positions)
        hh = hh + attn_output(layer["self_attn"], o)
        x = _ln(hh, layer["ln2"])
        qc = jnp.einsum("bsd,dhk->bshk", x, layer["cross_attn"]["wq"])
        oc = decode_attention(qc, ck, cv, enc_positions)
        hh = hh + attn_output(layer["cross_attn"], oc)
        x = _ln(hh, layer["ln3"])
        return hh + apply_mlp(cfg, layer["mlp"], x), kc, vc

    if cfg.scan_layers:
        def body(hh, xs):
            layer, kc, vc, ck, cv = xs
            hh, kc, vc = layer_step(hh, (layer, kc, vc, ck, cv))
            return hh, (kc, vc)
        h, (ks, vs) = jax.lax.scan(
            body, h, (params["dec_layers"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, k=ks, v=vs, length=cache["length"] + T)
    else:
        ks_l, vs_l = [], []
        for i, layer in enumerate(params["dec_layers"]):
            h, kc, vc = layer_step(h, (layer, cache["k"][i], cache["v"][i],
                                       cache["cross_k"][i], cache["cross_v"][i]))
            ks_l.append(kc)
            vs_l.append(vc)
        cache = dict(cache, k=jnp.stack(ks_l), v=jnp.stack(vs_l),
                     length=cache["length"] + T)
    h = _ln(h, params["dec_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, cache
