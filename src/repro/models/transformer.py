"""Decoder-only transformer covering the dense / moe / vlm families.

Provides the uniform LM interface used by the registry:
  init_params(rng, cfg)                         -> params
  forward(cfg, params, batch)                   -> logits          (training)
  prefill(cfg, params, batch, max_len)          -> (last_logits, cache)
  decode_step(cfg, params, cache, tokens, pos)  -> (logits, cache)

Layer parameters are stacked on a leading axis and iterated with
``jax.lax.scan`` (MaxText-style) so 80-layer configs compile quickly; the KV
cache is likewise stacked ``(L, B, S, KH, hd)``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .common import (
    apply_mlp,
    apply_norm,
    attn_output,
    blockwise_attention,
    cache_write,
    decode_attention,
    embed_init,
    init_attention,
    init_mlp,
    init_norm,
    qkv_project,
)
from .moe import apply_moe, init_moe
from ..distributed.sharding import shard_activations

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(cfg, key):
    dt = _dtype(cfg)
    k_attn, k_mlp = jax.random.split(key)
    layer = {
        "attn": init_attention(cfg, k_attn, dt),
        "ln1": init_norm(cfg, cfg.d_model, dt),
        "ln2": init_norm(cfg, cfg.d_model, dt),
    }
    if cfg.moe_num_experts:
        layer["moe"] = init_moe(cfg, k_mlp, dt)
    else:
        layer["mlp"] = init_mlp(cfg, k_mlp, dt)
    return layer


def init_params(rng, cfg) -> Params:
    dt = _dtype(cfg)
    k_embed, k_layers, k_head, k_pos = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    if cfg.scan_layers:
        layers = jax.vmap(lambda k: init_layer(cfg, k))(layer_keys)
    else:
        layers = [init_layer(cfg, k) for k in layer_keys]
    params = {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "layers": layers,
        "final_norm": init_norm(cfg, cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(k_head, (cfg.vocab_size, cfg.d_model), dt)
    if cfg.pos_embedding == "learned":
        params["pos_embed"] = embed_init(k_pos, (cfg.max_position_embeddings, cfg.d_model), dt)
    if cfg.num_image_tokens:
        # stubbed modality frontend: a single projection applied to the
        # precomputed patch embeddings supplied by input_specs()
        params["image_proj"] = embed_init(k_pos, (cfg.d_model, cfg.d_model), dt)
    return params


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(cfg, params, tokens):
    h = params["embed"][tokens]
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def embed_inputs(cfg, params, batch, *, positions):
    """tokens (+ optional image embeddings prefix) -> (B, S, d)."""
    h = embed_tokens(cfg, params, batch["tokens"])
    if cfg.num_image_tokens and "image_emb" in batch:
        img = batch["image_emb"].astype(h.dtype) @ params["image_proj"]
        h = jnp.concatenate([img, h], axis=1)
    if cfg.pos_embedding == "learned":
        h = h + params["pos_embed"][positions]
    return h


def unembed(cfg, params, h):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", h, table, preferred_element_type=jnp.float32)
    if cfg.logit_softcap > 0.0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------


def apply_layer_full(cfg, layer, h, positions, *, prefix_len=0):
    """Full-sequence (train / prefill) pass through one block. Returns (h, k, v)."""
    x = apply_norm(cfg, h, layer["ln1"])
    q, k, v = qkv_project(cfg, layer["attn"], x, positions)
    o = blockwise_attention(
        q, k, v, causal=True, prefix_len=prefix_len, chunk=cfg.attn_chunk,
        unroll=cfg.unroll_scans,
    )
    h = h + attn_output(layer["attn"], o)
    x = apply_norm(cfg, h, layer["ln2"])
    if cfg.moe_num_experts:
        y, _aux = apply_moe(cfg, layer["moe"], x)
    else:
        y = apply_mlp(cfg, layer["mlp"], x)
    return h + y, k, v


def apply_layer_decode(cfg, layer, h, k_cache, v_cache, positions):
    """Decode/extend: h (B, T, d); caches (B, S, KH, hd); positions (B, T).

    T=1 is plain autoregressive decode; T=gamma+1 is the speculative-verify
    extension.  New K/V are written into the cache at ``positions`` first,
    then every query attends to all cache slots at or before its position.
    """
    x = apply_norm(cfg, h, layer["ln1"])
    q, k, v = qkv_project(cfg, layer["attn"], x, positions)
    from ..distributed.sharding import replicate_new_kv, shard_kv_cache
    start = positions[:, 0]  # contiguous T-token span per sequence
    k_cache = shard_kv_cache(cache_write(k_cache, replicate_new_kv(k), start))
    v_cache = shard_kv_cache(cache_write(v_cache, replicate_new_kv(v), start))
    o = decode_attention(q, k_cache, v_cache, positions)
    h = h + attn_output(layer["attn"], o)
    x = apply_norm(cfg, h, layer["ln2"])
    if cfg.moe_num_experts:
        y, _aux = apply_moe(cfg, layer["moe"], x,
                            capacity_factor=max(cfg.moe_capacity_factor, 2.0))
    else:
        y = apply_mlp(cfg, layer["mlp"], x)
    return h + y, k_cache, v_cache


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return fn


# ---------------------------------------------------------------------------
# Full forward (training)
# ---------------------------------------------------------------------------


def forward(cfg, params, batch) -> jnp.ndarray:
    tokens = batch["tokens"]
    B, S_txt = tokens.shape
    S = S_txt + (cfg.num_image_tokens if "image_emb" in batch else 0)
    positions = jnp.arange(S)[None, :]
    h = embed_inputs(cfg, params, batch, positions=positions)
    prefix = cfg.num_image_tokens if "image_emb" in batch else 0

    h = shard_activations(h)
    if cfg.scan_layers:
        step = _maybe_remat(cfg, lambda hh, layer: (
            shard_activations(apply_layer_full(
                cfg, layer, hh, positions, prefix_len=prefix)[0]), None))
        h, _ = jax.lax.scan(step, h, params["layers"])
    else:
        blk = _maybe_remat(cfg, lambda hh, layer: shard_activations(
            apply_layer_full(cfg, layer, hh, positions, prefix_len=prefix)[0]))
        for layer in params["layers"]:
            h = blk(h, layer)
    h = apply_norm(cfg, h, params["final_norm"])
    return h  # hidden states; loss fn does streamed unembed+xent


def logits_from_hidden(cfg, params, h):
    return unembed(cfg, params, h)


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg, batch_size: int, max_len: int):
    dt = _dtype(cfg)
    KH, hd, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    return {
        "k": jnp.zeros((L, batch_size, max_len, KH, hd), dt),
        "v": jnp.zeros((L, batch_size, max_len, KH, hd), dt),
        "length": jnp.zeros((batch_size,), jnp.int32),
    }


def prefill(cfg, params, batch, max_len: int):
    """Run the full prompt, returning last-position logits and a filled cache."""
    tokens = batch["tokens"]
    B, S_txt = tokens.shape
    S = S_txt + (cfg.num_image_tokens if "image_emb" in batch else 0)
    positions = jnp.arange(S)[None, :]
    h = embed_inputs(cfg, params, batch, positions=positions)
    prefix = cfg.num_image_tokens if "image_emb" in batch else 0

    cache = init_cache(cfg, B, max_len)

    def body(hh, xs):
        layer = xs
        hh, k, v = apply_layer_full(cfg, layer, hh, positions, prefix_len=prefix)
        return shard_activations(hh), (k, v)

    if cfg.scan_layers:
        h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    else:
        ks_list, vs_list = [], []
        for layer in params["layers"]:
            h, k, v = apply_layer_full(cfg, layer, h, positions, prefix_len=prefix)
            ks_list.append(k)
            vs_list.append(v)
        ks, vs = jnp.stack(ks_list), jnp.stack(vs_list)

    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    cache["length"] = jnp.full((B,), S, jnp.int32)
    h = apply_norm(cfg, h, params["final_norm"])
    return unembed(cfg, params, h[:, -1:, :]), cache


# ---------------------------------------------------------------------------
# Paged KV cache (real serving backend)
# ---------------------------------------------------------------------------


def init_paged_cache(cfg, num_blocks: int, block_size: int):
    """Paged KV pool: (L, num_blocks + 1, block_size, KH, hd).

    The LAST block (index ``num_blocks``) is the write-off ("trash") block:
    padded batch rows and ragged-chunk tail slots scatter their K/V there so
    no write can ever touch a live sequence's blocks.  Block tables are
    padded with the trash id too, which doubles as the "any valid id"
    padding the attention kernels require."""
    dt = _dtype(cfg)
    KH, hd, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
    shape = (L, num_blocks + 1, block_size, KH, hd)
    return {"k_pages": jnp.zeros(shape, dt), "v_pages": jnp.zeros(shape, dt)}


def _paged_write(pages, new, tables, positions, valid):
    """Scatter ``new`` (B, T, KH, hd) into ``pages`` (NB+1, bs, KH, hd) at
    per-token ``positions`` (B, T) through the block tables; slots where
    ``valid`` is False are routed to the trash block."""
    bs = pages.shape[1]
    maxb = tables.shape[1]
    idx = jnp.minimum(positions // bs, maxb - 1)
    blk = jnp.take_along_axis(tables, idx, axis=1)      # (B, T)
    blk = jnp.where(valid, blk, pages.shape[0] - 1)     # trash for pad slots
    off = positions % bs
    return pages.at[blk, off].set(new.astype(pages.dtype))


def apply_layer_decode_paged(cfg, layer, h, k_pages, v_pages, tables,
                             positions, valid, lengths, *, use_kernel=False):
    """Paged analogue of :func:`apply_layer_decode`: h (B, T, d); pages
    (NB+1, bs, KH, hd); new K/V are scattered through the block tables
    first, then every query attends to all paged slots at or before its
    position (the multi-query paged-attention kernel / its jnp oracle)."""
    x = apply_norm(cfg, h, layer["ln1"])
    q, k, v = qkv_project(cfg, layer["attn"], x, positions)
    k_pages = _paged_write(k_pages, k, tables, positions, valid)
    v_pages = _paged_write(v_pages, v, tables, positions, valid)
    if use_kernel:
        from ..kernels.paged_attention import paged_attention
        o = paged_attention(q, k_pages, v_pages, tables, lengths,
                            interpret=True)
    else:
        from ..kernels.ref import paged_attention_ref
        o = paged_attention_ref(q, k_pages, v_pages, tables, lengths)
    h = h + attn_output(layer["attn"], o.astype(h.dtype))
    x = apply_norm(cfg, h, layer["ln2"])
    if cfg.moe_num_experts:
        y, _aux = apply_moe(cfg, layer["moe"], x,
                            capacity_factor=max(cfg.moe_capacity_factor, 2.0))
    else:
        y = apply_mlp(cfg, layer["mlp"], x)
    return h + y, k_pages, v_pages


def decode_step_paged(cfg, params, pages, tokens, tables, start, valid=None,
                      *, use_kernel: bool = False):
    """Extend T tokens per sequence against the paged KV pool.

    One function serves every real-backend shape: plain decode (T=1),
    speculative verification (T=gamma+1), batched prefill (start=0) and
    chunked-prefill appends (ragged ``valid``).

    tokens: (B, T) int32; tables: (B, max_blocks) int32 block tables padded
    with the trash id; start: (B,) tokens already materialised per sequence;
    valid: (B,) count of real tokens per row (None = all T valid).  Invalid
    tail slots write their K/V to the trash block and produce garbage logits
    — callers read logits at index ``valid - 1``.  Returns
    (logits (B, T, V), pages)."""
    B, T = tokens.shape
    positions = start[:, None] + jnp.arange(T)[None, :]            # (B, T)
    if valid is None:
        vmask = jnp.ones((B, T), bool)
    else:
        vmask = jnp.arange(T)[None, :] < valid[:, None]
    lengths = start + T
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos_embedding == "learned":
        h = h + params["pos_embed"][positions]

    if cfg.scan_layers:
        def body(hh, xs):
            layer, kp, vp = xs
            hh, kp, vp = apply_layer_decode_paged(
                cfg, layer, hh, kp, vp, tables, positions, vmask, lengths,
                use_kernel=use_kernel)
            return hh, (kp, vp)
        h, (ks, vs) = jax.lax.scan(
            body, h, (params["layers"], pages["k_pages"], pages["v_pages"]))
        pages = {"k_pages": ks, "v_pages": vs}
    else:
        ks_l, vs_l = [], []
        for i, layer in enumerate(params["layers"]):
            h, kp, vp = apply_layer_decode_paged(
                cfg, layer, h, pages["k_pages"][i], pages["v_pages"][i],
                tables, positions, vmask, lengths, use_kernel=use_kernel)
            ks_l.append(kp)
            vs_l.append(vp)
        pages = {"k_pages": jnp.stack(ks_l), "v_pages": jnp.stack(vs_l)}
    h = apply_norm(cfg, h, params["final_norm"])
    return unembed(cfg, params, h), pages


def decode_step(cfg, params, cache, tokens, positions=None):
    """Extend by T tokens: tokens (B, T) int32; T=1 is plain decode and
    T=gamma+1 is the speculative-verify extension.  Positions default to a
    contiguous span starting at cache['length']."""
    B, T = tokens.shape
    if positions is None:
        positions = cache["length"][:, None] + jnp.arange(T)[None, :]  # (B, T)
    h = embed_tokens(cfg, params, tokens)
    if cfg.pos_embedding == "learned":
        h = h + params["pos_embed"][positions]

    if cfg.scan_layers:
        def body(hh, xs):
            layer, kc, vc = xs
            hh, kc, vc = apply_layer_decode(cfg, layer, hh, kc, vc, positions)
            return hh, (kc, vc)
        h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache["k"], cache["v"]))
        cache = {"k": ks, "v": vs, "length": cache["length"] + T}
    else:
        ks_l, vs_l = [], []
        for i, layer in enumerate(params["layers"]):
            h, kc, vc = apply_layer_decode(
                cfg, layer, h, cache["k"][i], cache["v"][i], positions)
            ks_l.append(kc)
            vs_l.append(vc)
        cache = {"k": jnp.stack(ks_l), "v": jnp.stack(vs_l),
                 "length": cache["length"] + T}
    h = apply_norm(cfg, h, params["final_norm"])
    return unembed(cfg, params, h), cache
