"""Mixture-of-Experts layer with group-local capacity dispatch.

FLOP-efficient: each token is routed to its top-k experts only (plus a
capacity-factor head-room) via gather/scatter built from cumulative
positions — no (T, E, C) one-hot tensors.

Dispatch is **hierarchical** (Mesh-TF style groups): tokens are split into
``moe_groups`` groups aligned with the data-parallel mesh axes, and the
gather/scatter stays *within* a group.  Under SPMD this keeps every dispatch
buffer and index operation shard-local — a global top-k gather would force
the partitioner to all-gather the full token tensor (observed +16 GB/device
at 1M-token prefill; see EXPERIMENTS §Perf).

Used by grok-1 (8 experts, top-2) and granite-moe (32 experts, top-8).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init
from ..distributed.sharding import shard_moe_slots


def init_moe(cfg, key, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),  # router kept fp32
        "wg": dense_init(ks[1], (E, d, f), in_axis_size=d, dtype=dtype),
        "wu": dense_init(ks[2], (E, d, f), in_axis_size=d, dtype=dtype),
        "wd": dense_init(ks[3], (E, f, d), in_axis_size=f, dtype=dtype),
    }


def moe_capacity(num_tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    cap = int(num_tokens * top_k * factor / num_experts) + 1
    # round up to a lane-friendly multiple of 8
    return max(8, ((cap + 7) // 8) * 8)


def apply_moe(cfg, p, x, *, capacity_factor: float | None = None,
              groups: int | None = None):
    """x: (B, S, d) -> (B, S, d) plus aux losses dict."""
    B, S, d = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    T = B * S
    G = groups if groups is not None else getattr(cfg, "moe_groups", 1)
    if T % G:
        G = 1
    Tg = T // G
    xf = x.reshape(G, Tg, d)

    logits = jnp.einsum("gtd,de->gte", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cf = capacity_factor if capacity_factor is not None else cfg.moe_capacity_factor
    C = moe_capacity(Tg, E, k, cf)

    # position of each (token, slot) within its expert queue — per group
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (G, Tg, k, E)
    flat = onehot.reshape(G, Tg * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # exclusive cumsum within group
    pos_in_expert = jnp.sum(pos * flat, axis=-1)  # (G, Tg*k)
    expert_of = gate_idx.reshape(G, Tg * k)

    # scatter token ids into the per-group (E, C) slot table; slot -1 = empty.
    # over-capacity writes have pos >= C and are dropped by mode="drop".
    slot_table = jnp.full((G, E, C), -1, jnp.int32)
    tok_ids = jnp.tile(jnp.arange(Tg, dtype=jnp.int32)[:, None],
                       (1, k)).reshape(Tg * k)[None].repeat(G, axis=0)
    gi = jnp.arange(G, dtype=jnp.int32)[:, None].repeat(Tg * k, axis=1)
    slot_table = slot_table.at[gi, expert_of, pos_in_expert].set(
        tok_ids, mode="drop")
    slot_valid = slot_table >= 0
    safe_ids = jnp.maximum(slot_table, 0)  # (G, E, C)

    # gather expert inputs within each group: (G, E, C, d)
    xin = jnp.take_along_axis(
        xf[:, None], safe_ids.reshape(G, 1, E * C)[..., None], axis=2
    ).reshape(G, E, C, d)
    xin = xin * slot_valid[..., None].astype(xf.dtype)
    xin = shard_moe_slots(xin)

    # expert computation (grouped matmuls)
    if cfg.mlp_type == "geglu":
        act = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xin, p["wg"]),
                          approximate=True)
    else:
        act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["wg"]))
    h = act * jnp.einsum("gecd,edf->gecf", xin, p["wu"])
    yout = jnp.einsum("gecf,efd->gecd", h, p["wd"])  # (G, E, C, d)
    yout = shard_moe_slots(yout)

    # combine: gather each (token, slot)'s expert output within its group
    safe_pos = jnp.minimum(pos_in_expert, C - 1)
    flat_idx = (expert_of * C + safe_pos)  # (G, Tg*k)
    y_slots = jnp.take_along_axis(
        yout.reshape(G, E * C, d), flat_idx[..., None], axis=1)  # (G, Tg*k, d)
    kept = jnp.take_along_axis(
        slot_table.reshape(G, E * C), flat_idx, axis=1) == tok_ids
    y_slots = y_slots * kept[..., None]
    gates_flat = gate_vals.reshape(G, Tg * k)
    y = jnp.sum((y_slots * gates_flat[..., None]).reshape(G, Tg, k, d), axis=2)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = {"load_balance_loss": E * jnp.sum(me * ce)}

    return y.reshape(B, S, d).astype(x.dtype), aux
