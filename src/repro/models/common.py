"""Shared model building blocks: norms, RoPE, attention, MLPs, initialisers.

Everything is pure-functional JAX operating on parameter pytrees.  Attention
is implemented blockwise (flash-style running softmax) so that 32k-token
prefill never materialises an S x S matrix — this is also the pure-jnp oracle
for the Pallas flash kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in initialisation."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, scale, *, eps: float = 1e-6, offset: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if offset else scale.astype(jnp.float32)
    return (y * w).astype(dt)


def layer_norm(x, scale, bias, *, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(cfg, x, p):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"], offset=cfg.rmsnorm_offset)


def init_norm(cfg, d, dtype):
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    init = jnp.zeros if cfg.rmsnorm_offset else jnp.ones
    return {"scale": init((d,), dtype)}


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    angles = angles[..., None, :]  # broadcast over heads: (..., S, 1, d/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure jnp, numerically stable
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B, Sq, KH, G, D), k: (B, Ck, KH, D) -> (B, KH, G, Sq, Ck) fp32."""
    return jnp.einsum("bskgd,bckd->bkgsc", q, k, preferred_element_type=jnp.float32)


def blockwise_attention(
    q,
    k,
    v,
    *,
    causal: bool,
    q_offset=0,
    prefix_len: int = 0,
    chunk: int = 1024,
    softcap: float = 0.0,
    unroll: bool = False,
):
    """Chunked attention over the KV sequence with a running softmax.

    q: (B, Sq, H, D)   k, v: (B, Sk, KH, D)   returns (B, Sq, H, D).

    ``prefix_len`` marks a bidirectional prefix (PaliGemma-style prefix-LM):
    keys with position < prefix_len are visible to every query.
    """
    B, Sq, H, D = q.shape
    Sk, KH = k.shape[1], k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D) * (D ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)

    chunk = min(chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KH, D).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc, c_idx = carry
        k_blk, v_blk = xs
        k_pos = c_idx * chunk + jnp.arange(chunk)
        s = _gqa_scores(qg, k_blk)  # (B, KH, G, Sq, C)
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap
        mask = jnp.ones((Sq, chunk), bool)
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            if prefix_len > 0:
                mask = mask | (k_pos[None, :] < prefix_len)
        if pad:
            mask = mask & (k_pos[None, :] < Sk)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgsc,bckd->bskgd", p, v_blk.astype(jnp.float32))
        acc_new = acc * scale.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, c_idx + 1), None

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    if unroll:
        # static unroll: exact flop accounting in HLO cost analysis
        carry = (m0, l0, acc0, jnp.array(0))
        for i in range(n_chunks):
            carry, _ = body(carry, (kc[i], vc[i]))
        m, l, acc, _ = carry
    else:
        # flash-style: per-chunk remat keeps bwd residuals at carry size
        (m, l, acc, _), _ = jax.lax.scan(
            jax.checkpoint(body), (m0, l0, acc0, jnp.array(0)), (kc, vc))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 3, 1, 2)[..., None]
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, q_positions, *, softcap: float = 0.0):
    """Attention of T new positions against a (partially filled) cache.

    q: (B, T, H, D); caches: (B, S, KH, D); q_positions: (B, T) absolute
    positions of the new tokens (their K/V already written into the cache).
    Each query attends to every cache slot with position <= its own — this
    covers both single-token decode (T=1) and speculative verify (T=gamma+1).

    Pure-jnp oracle; the distributed context-parallel version lives in
    distributed/collectives.py and reduces to this on a 1-device mesh.
    """
    from ..distributed.sharding import shard_decode_scores

    B, T, H, D = q.shape
    S, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, T, KH, G, D) * (D ** -0.5)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k_cache,
                   preferred_element_type=jnp.float32)  # (B, KH, G, T, S)
    s = shard_decode_scores(s)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(S)[None, None, :] <= q_positions[:, :, None]  # (B, T, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    # explicit streaming softmax: reductions over the (sharded) S dim become
    # small cross-shard all-reduces; the big tensors stay partitioned
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = shard_decode_scores(p)
    l = jnp.sum(p, axis=-1, keepdims=True)
    # contract in the cache dtype with fp32 accumulation: converting v to
    # fp32 here lets XLA hoist an fp32 copy of the ENTIRE stacked cache out
    # of the layer scan (+16 GB/device at 32k x 128 — EXPERIMENTS §Perf)
    out = jnp.einsum("bkgts,bskd->btkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.moveaxis(l, (1, 2, 3), (2, 3, 1))
    return out.reshape(B, T, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(cfg, key, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "wg": dense_init(k1, (d, f), dtype=dtype),
            "wu": dense_init(k2, (d, f), dtype=dtype),
            "wd": dense_init(k3, (f, d), dtype=dtype),
        }
    return {  # plain gelu (whisper)
        "w1": dense_init(k1, (d, f), dtype=dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": dense_init(k2, (f, d), dtype=dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def apply_mlp(cfg, p, x):
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]
    if cfg.mlp_type == "geglu":
        return (jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wu"])) @ p["wd"]
    return (jax.nn.gelu(x @ p["w1"] + p["b1"], approximate=True)) @ p["w2"] + p["b2"]


# ---------------------------------------------------------------------------
# Attention parameter block
# ---------------------------------------------------------------------------


def init_attention(cfg, key, dtype, *, d_model=None, num_heads=None, num_kv_heads=None,
                   head_dim=None, cross: bool = False):
    d = d_model or cfg.d_model
    H = num_heads or cfg.num_heads
    KH = num_kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), in_axis_size=d, dtype=dtype),
        "wk": dense_init(ks[1], (d, KH, hd), in_axis_size=d, dtype=dtype),
        "wv": dense_init(ks[2], (d, KH, hd), in_axis_size=d, dtype=dtype),
        "wo": dense_init(ks[3], (H, hd, d), in_axis_size=H * hd, dtype=dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KH, hd), dtype)
        p["bv"] = jnp.zeros((KH, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def qkv_project(cfg, p, x, positions, *, use_rope=True):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KH,hd) with rope/qknorm applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if use_rope and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_output(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def cache_write(cache, new, start):
    """Write `new` (B, T, KH, hd) into `cache` (B, S, KH, hd) at per-sequence
    offsets `start` (B,).

    Implemented as a masked broadcast (iota compare) rather than a scattered
    dynamic_update_slice: elementwise selects partition cleanly under SPMD
    (a vmap'd scatter forces the partitioner to regroup/replicate the cache,
    which blows past HBM at 32k x 128 decode shapes — see EXPERIMENTS §Perf).
    """
    B, S = cache.shape[0], cache.shape[1]
    T = new.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]  # (1, S)
    out = cache
    for t in range(T):  # T is static and tiny (1..gamma+1)
        sel = (pos == (start + t)[:, None])[..., None, None]  # (B, S, 1, 1)
        out = jnp.where(sel, new[:, t][:, None].astype(cache.dtype), out)
    return out
