"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

38 mamba2 layers; one attention+MLP block whose weights are **reused** at
every ``hybrid_attn_every``-th layer (zamba2's parameter-sharing design).
Each application point keeps its own KV cache (weights are shared,
activations are not).  The shared block receives the current hidden state
plus the original token embedding (additive simplification of zamba2's
concat + linear; see DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import (
    apply_mlp,
    attn_output,
    blockwise_attention,
    cache_write,
    decode_attention,
    embed_init,
    init_attention,
    init_mlp,
    qkv_project,
    rms_norm,
)
from .mamba2 import apply_mamba_full, apply_mamba_step, init_mamba_layer
from ..distributed.sharding import shard_activations
from . import mamba2

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def attn_points(cfg):
    """Layer indices at which the shared attention block is applied."""
    k = cfg.hybrid_attn_every
    return tuple(i for i in range(cfg.num_layers) if (i + 1) % k == 0)


def init_params(rng, cfg) -> Params:
    dt = _dtype(cfg)
    k_embed, k_layers, k_shared, k_mlp = jax.random.split(rng, 4)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    layers = [init_mamba_layer(cfg, k) for k in layer_keys]
    shared = {
        "attn": init_attention(cfg, k_shared, dt),
        "mlp": init_mlp(cfg, k_mlp, dt),
        "ln1": jnp.ones((cfg.d_model,), dt),
        "ln2": jnp.ones((cfg.d_model,), dt),
    }
    return {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "layers": layers,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }


def _shared_full(cfg, shared, h, emb, positions):
    x = rms_norm(h + emb, shared["ln1"])
    q, k, v = qkv_project(cfg, shared["attn"], x, positions)
    o = blockwise_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, unroll=cfg.unroll_scans)
    h = h + attn_output(shared["attn"], o)
    x = rms_norm(h, shared["ln2"])
    return h + apply_mlp(cfg, shared["mlp"], x), k, v


def _shared_decode(cfg, shared, h, emb, k_cache, v_cache, positions):
    x = rms_norm(h + emb, shared["ln1"])
    q, k, v = qkv_project(cfg, shared["attn"], x, positions)
    from ..distributed.sharding import replicate_new_kv, shard_kv_cache
    start = positions[:, 0]
    k_cache = shard_kv_cache(cache_write(k_cache, replicate_new_kv(k), start))
    v_cache = shard_kv_cache(cache_write(v_cache, replicate_new_kv(v), start))
    o = decode_attention(q, k_cache, v_cache, positions)
    h = h + attn_output(shared["attn"], o)
    x = rms_norm(h, shared["ln2"])
    return h + apply_mlp(cfg, shared["mlp"], x), k_cache, v_cache


def forward(cfg, params, batch):
    tokens = batch["tokens"]
    B, S = tokens.shape
    emb = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    h = emb

    # scan over stacked layers with a cond'd shared block: the loop boundary
    # is what makes remat stick (straight-line jax.checkpoint gets undone by
    # XLA CSE — EXPERIMENTS §Perf iteration 7)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params["layers"])
    k = cfg.hybrid_attn_every

    def body(hh, xs):
        layer, idx = xs
        hh, _ = apply_mamba_full(cfg, layer, hh)
        hh = jax.lax.cond(
            (idx + 1) % k == 0,
            lambda a: _shared_full(cfg, params["shared"], a, emb, positions)[0],
            lambda a: a,
            hh)
        return shard_activations(hh), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, (stacked, jnp.arange(cfg.num_layers)))
    return rms_norm(h, params["final_norm"])


def init_cache(cfg, batch_size: int, max_len: int):
    dt = _dtype(cfg)
    base = mamba2.init_cache(cfg, batch_size)
    n_apps = len(attn_points(cfg))
    KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    base["attn_k"] = jnp.zeros((n_apps, batch_size, max_len, KH, hd), dt)
    base["attn_v"] = jnp.zeros((n_apps, batch_size, max_len, KH, hd), dt)
    return base


def prefill(cfg, params, batch, max_len: int):
    tokens = batch["tokens"]
    B, S = tokens.shape
    emb = params["embed"][tokens]
    positions = jnp.arange(S)[None, :]
    h = emb
    cache = init_cache(cfg, B, max_len)
    pts = list(attn_points(cfg))
    convs, ssms, aks, avs = [], [], [], []
    for i, layer in enumerate(params["layers"]):
        u = rms_norm(h, layer["ln"])
        _, xBC, _, _ = mamba2._split_proj(cfg, layer, u, cfg.d_model)
        K = cfg.ssm_conv
        tail = jnp.pad(xBC, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):]
        h, final_state = apply_mamba_full(cfg, layer, h)
        convs.append(tail)
        ssms.append(final_state)
        if i in pts:
            h, k, v = _shared_full(cfg, params["shared"], h, emb, positions)
            aks.append(k)
            avs.append(v)
    cache["conv"] = jnp.stack(convs).astype(cache["conv"].dtype)
    cache["ssm"] = jnp.stack(ssms)
    cache["attn_k"] = jax.lax.dynamic_update_slice(
        cache["attn_k"], jnp.stack(aks).astype(cache["attn_k"].dtype), (0, 0, 0, 0, 0))
    cache["attn_v"] = jax.lax.dynamic_update_slice(
        cache["attn_v"], jnp.stack(avs).astype(cache["attn_v"].dtype), (0, 0, 0, 0, 0))
    cache["length"] = jnp.full((B,), S, jnp.int32)
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h[:, -1:], params["embed"],
                        preferred_element_type=jnp.float32)
    return logits, cache


def _step_once(cfg, params, cache, tok_col, positions):
    """One token through all layers. tok_col: (B,). positions: (B, 1)."""
    emb = params["embed"][tok_col][:, None, :]
    h = emb
    pts = list(attn_points(cfg))
    convs, ssms, aks, avs = [], [], [], []
    app = 0
    for i, layer in enumerate(params["layers"]):
        h, cs, ss = apply_mamba_step(cfg, layer, h, cache["conv"][i], cache["ssm"][i])
        convs.append(cs)
        ssms.append(ss)
        if i in pts:
            h, knew, vnew = _shared_decode(
                cfg, params["shared"], h, emb,
                cache["attn_k"][app], cache["attn_v"][app], positions)
            # collect and stack ONCE: chaining .at[app].set() makes each
            # application copy the full stacked cache (6x at long_500k)
            aks.append(knew)
            avs.append(vnew)
            app += 1
    h = rms_norm(h, params["final_norm"])
    logits = jnp.einsum("bsd,vd->bsv", h, params["embed"],
                        preferred_element_type=jnp.float32)
    new_cache = {"conv": jnp.stack(convs), "ssm": jnp.stack(ssms),
                 "attn_k": jnp.stack(aks), "attn_v": jnp.stack(avs),
                 "length": cache["length"] + 1}
    return logits, new_cache


def decode_step(cfg, params, cache, tokens, positions=None):
    """tokens (B, T); T>1 keeps per-position SSM checkpoints for rollback."""
    B, T = tokens.shape
    if positions is None:
        base = cache["length"]
    else:
        base = positions[:, 0]
    if T == 1:
        return _step_once(cfg, params, cache, tokens[:, 0], base[:, None])

    logits_all, conv_ck, ssm_ck = [], [], []
    cur = dict(cache)
    for t in range(T):
        logits, cur = _step_once(cfg, params, cur, tokens[:, t], (base + t)[:, None])
        logits_all.append(logits[:, 0])
        conv_ck.append(cur["conv"])
        ssm_ck.append(cur["ssm"])
    cur["checkpoints"] = {"conv": jnp.stack(conv_ck), "ssm": jnp.stack(ssm_ck)}
    return jnp.stack(logits_all, axis=1), cur
