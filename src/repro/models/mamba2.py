"""Mamba2 (state-space duality / SSD) blocks in pure JAX.

Implements the chunked SSD algorithm of "Transformers are SSMs"
(arXiv:2405.21060): within-chunk quadratic (attention-like) term plus an
inter-chunk recurrence on the (H, P, N) states — the TPU-friendly formulation
(all matmuls, scan only over L/chunk steps).

Decode runs the O(1)-state recurrence:
    h <- h * exp(dt*A) + dt * (B ⊗ x);   y = C · h + D * x

Speculative-decoding adaptation (DESIGN.md §5): ``decode_chunk`` processes
gamma+1 draft tokens in one SSD pass and returns the *per-position* states so
the engine can roll back to the acceptance point — the SSM analogue of KV
truncation.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import dense_init, embed_init, rms_norm
from ..distributed.sharding import shard_activations

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_mamba_layer(cfg, key, d_model=None):
    dt = _dtype(cfg)
    d = d_model or cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    ks = jax.random.split(key, 6)
    # in_proj emits [z (d_in), x (d_in), B (G*N), C (G*N), dt (H)]
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in + 2 * G * N + H), dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * (1.0 / cfg.ssm_conv ** 0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": jnp.ones((d_in,), dt),
        "out_proj": dense_init(ks[3], (d_in, d), dtype=dt),
        "ln": jnp.ones((d,), dt),
    }


def init_params(rng, cfg) -> Params:
    dt = _dtype(cfg)
    k_embed, k_layers = jax.random.split(rng)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    if cfg.scan_layers:
        layers = jax.vmap(lambda k: init_mamba_layer(cfg, k))(layer_keys)
    else:
        layers = [init_mamba_layer(cfg, k) for k in layer_keys]
    return {
        "embed": embed_init(k_embed, (cfg.vocab_size, cfg.d_model), dt),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }


# ---------------------------------------------------------------------------
# Projections shared by full / step paths
# ---------------------------------------------------------------------------


def _split_proj(cfg, layer, u, d_model):
    d_in = cfg.ssm_expand * d_model
    H = d_in // cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = u @ layer["in_proj"]
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * G * N], axis=-1)
    return z, xBC, dt_raw, (d_in, H, G, N)


def _conv_full(layer, xBC):
    """Causal depthwise conv over (B, L, conv_dim)."""
    w = layer["conv_w"].astype(jnp.float32)  # (K, conv_dim)
    K = w.shape[0]
    x = xBC.astype(jnp.float32)
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + layer["conv_b"].astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x):
    """Stable 'segment sum': x (..., T) -> (..., T, T) lower-tri cumulative sums."""
    T = x.shape[-1]
    xx = jnp.broadcast_to(x[..., None, :], x.shape + (T,)).swapaxes(-1, -2)
    mask = jnp.tril(jnp.ones((T, T), bool), k=-1)
    xx = jnp.where(mask, xx, 0.0)
    segsum = jnp.cumsum(xx, axis=-2)
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, segsum, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk, init_state=None, return_final=True,
                unroll=False):
    """Chunked SSD scan.

    x:  (b, l, h, p)    dt: (b, l, h)    A: (h,) (negative)
    Bm, Cm: (b, l, g, n); returns y (b, l, h, p) and final state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, l)
    nc = l // Q
    assert nc * Q == l, f"seq len {l} not divisible by chunk {Q}"
    rep = h // g

    xc = x.reshape(b, nc, Q, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = jnp.repeat(Bm.reshape(b, nc, Q, g, n), rep, axis=3).astype(jnp.float32)
    Cc = jnp.repeat(Cm.reshape(b, nc, Q, g, n), rep, axis=3).astype(jnp.float32)

    dA = dtc * A  # (b, nc, Q, h)
    dA_cs = jnp.cumsum(dA, axis=2)  # within-chunk cumsum
    xdt = xc * dtc[..., None]

    # (1) intra-chunk (quadratic) term
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))  # (b, nc, h, Q, Q)
    y_diag = jnp.einsum("bcqhn,bcshn,bchqs,bcshp->bcqhp", Cc, Bc, L, xdt)

    # (2) per-chunk output states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b, nc, Q, h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bc, decay_states, xdt)

    # (3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b, nc, h)

    def scan_fn(carry, xs):
        st, dec = xs
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev

    h0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    if unroll:
        carry, prevs = h0, []
        for i in range(nc):
            carry, prev = scan_fn(carry, (states[:, i], chunk_decay[:, i]))
            prevs.append(prev)
        final, prev_states = carry, jnp.stack(prevs, axis=1)
    else:
        final, prev_states = jax.lax.scan(
            scan_fn, h0,
            (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
        prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # (4) inter-chunk contribution to outputs
    state_decay_out = jnp.exp(dA_cs)  # (b, nc, Q, h)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, (final if return_final else None)


# ---------------------------------------------------------------------------
# Full-sequence layer (train / prefill)
# ---------------------------------------------------------------------------


def apply_mamba_full(cfg, layer, hid, d_model=None, init_state=None):
    """hid: (B, L, d). Returns (hid', final_ssm_state, last_conv_window)."""
    d = d_model or cfg.d_model
    u = rms_norm(hid, layer["ln"])
    z, xBC, dt_raw, (d_in, H, G, N) = _split_proj(cfg, layer, u, d)
    xBC = _conv_full(layer, xBC)
    x, Bm, Cm = jnp.split(xBC, [d_in, d_in + G * N], axis=-1)
    b, l = x.shape[0], x.shape[1]
    x = x.reshape(b, l, H, cfg.ssm_headdim)
    Bm = Bm.reshape(b, l, G, N)
    Cm = Cm.reshape(b, l, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + layer["dt_bias"])  # (b, l, H)
    A = -jnp.exp(layer["A_log"])  # (H,)

    y, final_state = ssd_chunked(x, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                 init_state=init_state,
                                 unroll=cfg.unroll_scans)
    y = y + x.astype(jnp.float32) * layer["D"][:, None]
    y = y.reshape(b, l, d_in).astype(hid.dtype)
    y = rms_norm(y * jax.nn.silu(z), layer["norm"])
    out = y @ layer["out_proj"]
    # conv window for decode continuation: last (K-1) pre-activation inputs
    return hid + out, final_state


# ---------------------------------------------------------------------------
# Single-step decode (recurrent)
# ---------------------------------------------------------------------------


def apply_mamba_step(cfg, layer, hid, conv_state, ssm_state, d_model=None):
    """hid: (B, 1, d); conv_state: (B, K-1, conv_dim); ssm_state: (B, H, P, N)."""
    d = d_model or cfg.d_model
    u = rms_norm(hid, layer["ln"])
    z, xBC, dt_raw, (d_in, H, G, N) = _split_proj(cfg, layer, u[:, 0], d)
    # depthwise conv with rolling state
    K = cfg.ssm_conv
    w = layer["conv_w"].astype(jnp.float32)
    window = jnp.concatenate(
        [conv_state.astype(jnp.float32), xBC[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + layer["conv_b"].astype(jnp.float32)
    xBC_act = jax.nn.silu(conv_out).astype(hid.dtype)
    new_conv_state = window[:, 1:].astype(conv_state.dtype)

    x, Bm, Cm = jnp.split(xBC_act, [d_in, d_in + G * N], axis=-1)
    b = x.shape[0]
    x = x.reshape(b, H, cfg.ssm_headdim).astype(jnp.float32)
    Bm = jnp.repeat(Bm.reshape(b, G, N), H // G, axis=1).astype(jnp.float32)
    Cm = jnp.repeat(Cm.reshape(b, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + layer["dt_bias"])  # (b, H)
    A = -jnp.exp(layer["A_log"])

    decay = jnp.exp(dt * A)  # (b, H)
    ssm_state = (ssm_state.astype(jnp.float32) * decay[..., None, None]
                 + jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Bm))
    y = jnp.einsum("bhn,bhpn->bhp", Cm, ssm_state) + x * layer["D"][:, None]
    y = y.reshape(b, 1, d_in).astype(hid.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None]), layer["norm"])
    out = y @ layer["out_proj"]
    return hid + out, new_conv_state, ssm_state.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Model-level interface (ssm family)
# ---------------------------------------------------------------------------


def forward(cfg, params, batch):
    h = params["embed"][batch["tokens"]]

    def body(hh, layer):
        hh, _ = apply_mamba_full(cfg, layer, hh)
        return shard_activations(hh), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        h, _ = jax.lax.scan(body, h, params["layers"])
    else:
        for layer in params["layers"]:
            h, _ = apply_mamba_full(cfg, layer, h)
    return rms_norm(h, params["final_norm"])


def init_cache(cfg, batch_size: int, max_len: int = 0):
    """SSM caches are O(1) in sequence length (max_len ignored)."""
    dt = _dtype(cfg)
    d_in = cfg.d_inner
    H = cfg.ssm_nheads
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = d_in + 2 * G * N
    L = cfg.num_layers
    return {
        "conv": jnp.zeros((L, batch_size, cfg.ssm_conv - 1, conv_dim), dt),
        "ssm": jnp.zeros((L, batch_size, H, cfg.ssm_headdim, N), jnp.float32),
        "length": jnp.zeros((batch_size,), jnp.int32),
    }


def _unembed(cfg, params, h):
    return jnp.einsum("bsd,vd->bsv", h, params["embed"],
                      preferred_element_type=jnp.float32)


def prefill(cfg, params, batch, max_len: int = 0):
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = params["embed"][tokens]
    cache = init_cache(cfg, B)

    def body(hh, xs):
        layer = xs
        # recompute conv tail inside: run full layer, also emit states
        hid, final_state = apply_mamba_full(cfg, layer, hh)
        return hid, final_state

    # also need conv windows: recompute the pre-conv activations' tail
    conv_states, ssm_states = [], []
    if cfg.scan_layers:
        def body2(hh, layer):
            u = rms_norm(hh, layer["ln"])
            _, xBC, _, _ = _split_proj(cfg, layer, u, cfg.d_model)
            K = cfg.ssm_conv
            tail = jnp.pad(xBC, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):]
            hid, final_state = apply_mamba_full(cfg, layer, hh)
            return hid, (tail, final_state)
        h, (convs, ssms) = jax.lax.scan(body2, h, params["layers"])
        cache = {"conv": convs.astype(cache["conv"].dtype), "ssm": ssms,
                 "length": jnp.full((B,), S, jnp.int32)}
    else:
        for layer in params["layers"]:
            u = rms_norm(h, layer["ln"])
            _, xBC, _, _ = _split_proj(cfg, layer, u, cfg.d_model)
            K = cfg.ssm_conv
            tail = jnp.pad(xBC, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):]
            h, final_state = apply_mamba_full(cfg, layer, h)
            conv_states.append(tail)
            ssm_states.append(final_state)
        cache = {"conv": jnp.stack(conv_states).astype(cache["conv"].dtype),
                 "ssm": jnp.stack(ssm_states),
                 "length": jnp.full((B,), S, jnp.int32)}
    h = rms_norm(h, params["final_norm"])
    return _unembed(cfg, params, h[:, -1:]), cache


def decode_step(cfg, params, cache, tokens, positions=None):
    """tokens (B, T).  T=1: recurrent step.  T>1 (speculative verify): the
    chunk is processed token-by-token with per-position state checkpoints so
    the engine can roll back to the acceptance point (DESIGN.md §5)."""
    B, T = tokens.shape
    h = params["embed"][tokens]

    if T == 1:
        def body(hh, xs):
            layer, cs, ss = xs
            hh, cs, ss = apply_mamba_step(cfg, layer, hh, cs, ss)
            return hh, (cs, ss)
        if cfg.scan_layers:
            h, (convs, ssms) = jax.lax.scan(
                body, h, (params["layers"], cache["conv"], cache["ssm"]))
        else:
            convs_l, ssms_l = [], []
            for i, layer in enumerate(params["layers"]):
                h, cs, ss = apply_mamba_step(cfg, layer, h, cache["conv"][i],
                                             cache["ssm"][i])
                convs_l.append(cs)
                ssms_l.append(ss)
            convs, ssms = jnp.stack(convs_l), jnp.stack(ssms_l)
        cache = {"conv": convs, "ssm": ssms, "length": cache["length"] + 1}
        h = rms_norm(h, params["final_norm"])
        return _unembed(cfg, params, h), cache

    # multi-token extension: scan over the T positions, keeping checkpoints
    def token_body(carry, tok_col):
        conv, ssm = carry
        hh = params["embed"][tok_col][:, None, :]

        def layer_body(hh2, xs):
            layer, cs, ss = xs
            hh2, cs, ss = apply_mamba_step(cfg, layer, hh2, cs, ss)
            return hh2, (cs, ss)
        if cfg.scan_layers:
            hh, (conv, ssm) = jax.lax.scan(layer_body, hh, (params["layers"], conv, ssm))
        else:
            cl, sl = [], []
            for i, layer in enumerate(params["layers"]):
                hh, cs, ss = apply_mamba_step(cfg, layer, hh, conv[i], ssm[i])
                cl.append(cs)
                sl.append(ss)
            conv, ssm = jnp.stack(cl), jnp.stack(sl)
        logits = _unembed(cfg, params, rms_norm(hh, params["final_norm"]))
        return (conv, ssm), (logits[:, 0], conv, ssm)

    (convs, ssms), (logits_t, conv_ckpts, ssm_ckpts) = jax.lax.scan(
        token_body, (cache["conv"], cache["ssm"]), tokens.T)
    logits = logits_t.transpose(1, 0, 2)  # (B, T, V)
    cache = {"conv": convs, "ssm": ssms, "length": cache["length"] + T,
             "checkpoints": {"conv": conv_ckpts, "ssm": ssm_ckpts}}
    return logits, cache
