from . import common, encdec, hybrid, mamba2, moe, registry, transformer  # noqa: F401
