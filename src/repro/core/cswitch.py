"""Switching-cost (C_switch) lookup table — paper §5.2 "Prefill Cost Modeling".

Re-enabling speculation after a disabled phase forces the draft model to
re-prefill the ``delta`` tokens it skipped.  The cost is profiled offline on a
grid of (skip length, batch size) — Table 3 of the paper — and queried at
run time with the *effective skip length* ``delta_max = max_i delta_i``.

Two constructors:
  * profile() — real tier: measures T_SD - T_base wall-clock on actual JAX
    models (tiny configs, CPU).
  * from_cost_model() — analytical tier: derives the same quantity from the
    TPU roofline step-latency model.
"""
from __future__ import annotations

import bisect
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Sequence, Tuple


def _geometric_grid(lo: int, hi: int) -> List[int]:
    out, v = [], max(lo, 1)
    while v <= hi:
        out.append(v)
        v *= 2
    return out


@dataclass
class CSwitchTable:
    """C_switch(delta_max, B) lookup with nearest-grid-point retrieval."""

    deltas: Tuple[int, ...]
    batches: Tuple[int, ...]
    table: Dict[Tuple[int, int], float]  # (delta, batch) -> seconds

    def lookup(self, delta_max: int, batch: int) -> float:
        d = self._nearest(self.deltas, delta_max)
        b = self._nearest(self.batches, batch)
        return self.table[(d, b)]

    @property
    def c_max(self) -> float:
        return max(self.table.values()) if self.table else 0.0

    @staticmethod
    def _nearest(grid: Sequence[int], x: int) -> int:
        i = bisect.bisect_left(grid, x)
        if i == 0:
            return grid[0]
        if i == len(grid):
            return grid[-1]
        lo, hi = grid[i - 1], grid[i]
        return lo if (x - lo) <= (hi - x) else hi

    # ------------------------------------------------------------------
    @classmethod
    def constant(cls, value: float) -> "CSwitchTable":
        return cls(deltas=(1,), batches=(1,), table={(1, 1): value})

    @classmethod
    def profile(cls, measure_fn: Callable[[int, int], float],
                deltas: Sequence[int] = (128, 256, 512),
                batches: Sequence[int] = (2, 4, 8, 16, 32, 64)) -> "CSwitchTable":
        """measure_fn(delta, batch) -> seconds of extra latency (T_SD - T_base)."""
        table = {}
        for d in deltas:
            for b in batches:
                table[(d, b)] = max(measure_fn(d, b), 0.0)
        return cls(deltas=tuple(sorted(set(deltas))),
                   batches=tuple(sorted(set(batches))), table=table)

    @classmethod
    def from_cost_model(cls, cost_model, draft_cfg,
                        deltas: Sequence[int] = (128, 256, 512, 1024, 2048),
                        batches: Sequence[int] = (2, 4, 8, 16, 32, 64, 128)
                        ) -> "CSwitchTable":
        """Analytical tier: C_switch = draft-prefill(delta, B) latency."""
        table = {}
        for d in deltas:
            for b in batches:
                table[(d, b)] = cost_model.prefill_latency(draft_cfg, b, d)
        return cls(deltas=tuple(sorted(set(deltas))),
                   batches=tuple(sorted(set(batches))), table=table)
