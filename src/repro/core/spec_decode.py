"""Draft-then-verify speculative decoding step (chain-style, batched).

One jittable function per (target, draft) pair:

  spec_step(key, tparams, dparams, tcache, dcache, last_tokens, gamma)
    -> committed tokens, n_accepted, rolled-back caches

Cache-synchronisation invariant (holds before and after every step):
  tcache.length == dcache.length == N, both caches contain K/V (or SSM
  state) for tokens x_0..x_{N-1}, and ``last_tokens`` = x_N is committed but
  in NEITHER cache.  The draft chain therefore consumes the full
  (gamma+1)-token chunk [x_N, d_1..d_gamma] — one tiny extra draft step per
  round — so both caches advance in lockstep and rollback is a pure length
  decrement.

Attention caches roll back for free (stale slots are never attended: the
mask is pos <= q_position, and they are overwritten by later writes).
SSM/hybrid caches restore per-position state checkpoints (DESIGN.md §5) —
the TPU-friendly analogue of KV truncation.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..models.registry import ModelAPI
from .verify import verify_greedy, verify_rejection


class SpecResult(NamedTuple):
    tokens: jnp.ndarray       # (B, g+1) committed, -1 padded
    n_accepted: jnp.ndarray   # (B,)
    n_committed: jnp.ndarray  # (B,) == n_accepted + 1
    tcache: Any
    dcache: Any
    last_token: jnp.ndarray   # (B,) newly sampled token (not yet in caches)


def _select_ckpt(x, idx):
    """x: (T, L, B, ...) per-step checkpoints -> (L, B, ...) at per-seq idx."""
    T = x.shape[0]
    moved = jnp.moveaxis(x, 2, 0)  # (B, T, L, ...)
    sel = jax.vmap(lambda xb, i: xb[i])(moved, jnp.clip(idx, 0, T - 1))
    return jnp.moveaxis(sel, 0, 1)


def _rollback_ssm_cache(cache_ext, base_cache, n_keep):
    """Restore conv/ssm from checkpoint index n_keep-1 (state after consuming
    the first n_keep chunk tokens).  Attention parts (hybrid) roll back by
    length alone."""
    ck = cache_ext["checkpoints"]
    idx = n_keep - 1  # n_keep >= 1 always (chunk starts with the last token)
    out = {k: v for k, v in cache_ext.items() if k != "checkpoints"}
    out["conv"] = _select_ckpt(ck["conv"], idx)
    out["ssm"] = _select_ckpt(ck["ssm"], idx)
    out["length"] = base_cache["length"] + n_keep
    return out


def make_spec_step(target: ModelAPI, draft: ModelAPI, *, sampling: str = "greedy",
                   temperature: float = 1.0):
    """Build the jittable speculative-decoding step.

    sampling: "greedy" (accept on argmax match) or "rejection" (lossless
    stochastic verification).
    """
    t_is_ssm = target.cfg.family in ("ssm", "hybrid")
    d_is_ssm = draft.cfg.family in ("ssm", "hybrid")
    if draft.cfg.family == "hybrid":
        raise NotImplementedError("use a pure-ssm or attention draft model")

    def drafting(key, dparams, dcache, last_tokens, gamma: int):
        """Chain-draft. Consumes the full (gamma+1)-token chunk; returns the
        gamma proposals, their distributions, the advanced cache, and (for
        SSM drafts) per-step state checkpoints."""

        def body(carry, k):
            cache, tok = carry
            logits, cache = draft.decode_step(dparams, cache, tok[:, None])
            lg = logits[:, 0] / temperature
            if sampling == "greedy":
                nxt = jnp.argmax(lg, axis=-1)
            else:
                nxt = jax.random.categorical(k, lg)
            probs = jax.nn.softmax(lg, axis=-1)
            ck = (cache["conv"], cache["ssm"]) if d_is_ssm else None
            return (cache, nxt), (nxt, probs, ck)

        keys = jax.random.split(key, gamma + 1)
        (dcache, _), (toks, probs, cks) = jax.lax.scan(
            body, (dcache, last_tokens), keys)
        # proposals are the outputs of the first gamma consumes
        draft_tokens = toks[:gamma].T                     # (B, g)
        draft_probs = jnp.swapaxes(probs[:gamma], 0, 1)   # (B, g, V)
        return draft_tokens, draft_probs, dcache, cks

    def spec_step(key, tparams, dparams, tcache, dcache, last_tokens, gamma: int):
        """last_tokens: (B,). gamma: static python int > 0."""
        kd, kv = jax.random.split(key)
        draft_tokens, draft_probs, dcache_ext, dcks = drafting(
            kd, dparams, dcache, last_tokens, gamma)

        # target verifies [last, d_1..d_g] in one extension pass
        chunk = jnp.concatenate([last_tokens[:, None], draft_tokens], axis=1)
        t_logits, tcache_ext = target.decode_step(tparams, tcache, chunk)
        t_logits = t_logits / temperature

        if sampling == "greedy":
            res = verify_greedy(draft_tokens, t_logits)
        else:
            res = verify_rejection(kv, draft_tokens, draft_probs,
                                   jax.nn.softmax(t_logits, -1))
        n_acc = res["n_accepted"]
        n_keep = 1 + n_acc  # chunk tokens retained (x_N + accepted drafts)

        # --- target rollback
        if t_is_ssm:
            tcache_new = _rollback_ssm_cache(tcache_ext, tcache, n_keep)
        else:
            tcache_new = {k: v for k, v in tcache_ext.items()
                          if k != "checkpoints"}
            tcache_new["length"] = tcache["length"] + n_keep

        # --- draft rollback (consumed the same chunk + d_gamma)
        if d_is_ssm:
            conv_ck, ssm_ck = dcks
            dcache_new = dict(dcache_ext)
            dcache_new["conv"] = _select_ckpt(conv_ck, n_keep - 1)
            dcache_new["ssm"] = _select_ckpt(ssm_ck, n_keep - 1)
        else:
            dcache_new = dict(dcache_ext)
        dcache_new["length"] = tcache["length"] + n_keep

        return SpecResult(
            tokens=res["tokens"],
            n_accepted=n_acc,
            n_committed=n_acc + 1,
            tcache=tcache_new,
            dcache=dcache_new,
            last_token=res["next_token"],
        )

    return spec_step


def make_paged_spec_step(target: ModelAPI, draft: ModelAPI, *,
                         sampling: str = "greedy", temperature: float = 1.0):
    """Speculative step over paged KV pools (zero-copy continuous batching).

    Same chain-draft/verify semantics as :func:`make_spec_step`, but the
    caches are shared paged pools indexed by per-sequence block tables:

      spec_step(key, tparams, dparams, tpages, dpages, tables, lengths,
                last_tokens, gamma) -> SpecResult (tcache/dcache = pages)

    ``lengths`` is the per-sequence materialised token count N (the cache
    holds x_0..x_{N-1}; ``last_tokens`` = x_N is in neither pool).  Both
    models write the (gamma+1)-token chunk at positions N..N+gamma through
    the SAME block tables, so rollback is free: the host advances each
    sequence's length by n_accepted+1 and the stale slots beyond it are
    never attended (the kernel masks pos <= query position) and are
    overwritten by the next step's writes at the same positions.  The
    caller must have grown the tables to cover N + gamma + 1 positions
    (``BlockManager.ensure_capacity``)."""
    if not (target.supports_paged and draft.supports_paged):
        raise NotImplementedError(
            "paged speculative decoding needs attention-family target and "
            "draft models (SSM/hybrid state is O(1) — use make_spec_step)")

    def spec_step(key, tparams, dparams, tpages, dpages, tables, lengths,
                  last_tokens, gamma: int):
        kd, kv = jax.random.split(key)

        def body(carry, k):
            dpg, tok, pos = carry
            logits, dpg = draft.decode_step_paged(dparams, dpg, tok[:, None],
                                                  tables, pos)
            lg = logits[:, 0] / temperature
            if sampling == "greedy":
                nxt = jnp.argmax(lg, axis=-1)
            else:
                nxt = jax.random.categorical(k, lg)
            return (dpg, nxt, pos + 1), (nxt, jax.nn.softmax(lg, axis=-1))

        keys = jax.random.split(kd, gamma + 1)
        (dpages, _, _), (toks, probs) = jax.lax.scan(
            body, (dpages, last_tokens, lengths), keys)
        draft_tokens = toks[:gamma].T                     # (B, g)
        draft_probs = jnp.swapaxes(probs[:gamma], 0, 1)   # (B, g, V)

        # target verifies [last, d_1..d_g] in one paged extension pass
        chunk = jnp.concatenate([last_tokens[:, None], draft_tokens], axis=1)
        t_logits, tpages = target.decode_step_paged(tparams, tpages, chunk,
                                                    tables, lengths)
        t_logits = t_logits / temperature

        if sampling == "greedy":
            res = verify_greedy(draft_tokens, t_logits)
        else:
            res = verify_rejection(kv, draft_tokens, draft_probs,
                                   jax.nn.softmax(t_logits, -1))
        n_acc = res["n_accepted"]
        return SpecResult(
            tokens=res["tokens"],
            n_accepted=n_acc,
            n_committed=n_acc + 1,
            tcache=tpages,
            dcache=dpages,
            last_token=res["next_token"],
        )

    return spec_step


def make_paged_ar_step(target: ModelAPI, *, sampling: str = "greedy",
                       temperature: float = 1.0):
    """Plain autoregressive decode step over the paged pool (gamma=0 arm)."""

    def ar_step(key, tparams, tpages, tables, lengths, last_tokens):
        logits, tpages = target.decode_step_paged(tparams, tpages,
                                                  last_tokens[:, None],
                                                  tables, lengths)
        lg = logits[:, 0] / temperature
        if sampling == "greedy":
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(key, lg)
        return nxt, tpages

    return ar_step


def make_ar_step(target: ModelAPI, *, sampling: str = "greedy",
                 temperature: float = 1.0):
    """Plain autoregressive decode step (the gamma=0 arm)."""

    def ar_step(key, tparams, tcache, last_tokens):
        logits, tcache = target.decode_step(tparams, tcache, last_tokens[:, None])
        lg = logits[:, 0] / temperature
        if sampling == "greedy":
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(key, lg)
        return nxt, tcache

    return ar_step
