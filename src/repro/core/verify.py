"""Lossless speculative-decoding verification (Leviathan et al. 2023).

Batched rejection sampling: given draft tokens, draft distributions and the
target's distributions over the same positions (+ one bonus position), accept
a prefix of the draft and sample a correction/bonus token such that the
committed tokens are distributed EXACTLY as target-only decoding.

This module is the pure-jnp oracle shared by the engine and by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def verify_rejection(key, draft_tokens, draft_probs, target_probs):
    """Batched rejection-sampling verification.

    draft_tokens: (B, g) int32 — tokens proposed by the draft model
    draft_probs:  (B, g, V) — draft distribution at each proposal position
    target_probs: (B, g+1, V) — target distribution at the same g positions
                  plus the bonus position.

    Returns dict with
      n_accepted: (B,) number of draft tokens accepted (0..g)
      next_token: (B,) the correction (on rejection) or bonus (all accepted)
      tokens:     (B, g+1) committed tokens = accepted prefix + next_token,
                  positions beyond n_accepted+1 are -1
    """
    B, g = draft_tokens.shape
    kb, ks = jax.random.split(key)

    p_tok = jnp.take_along_axis(target_probs[:, :g], draft_tokens[..., None],
                                axis=-1)[..., 0]  # (B, g)
    q_tok = jnp.take_along_axis(draft_probs, draft_tokens[..., None],
                                axis=-1)[..., 0]
    u = jax.random.uniform(kb, (B, g))
    accept = u * q_tok < p_tok  # == u < p/q, robust to q == 0
    prefix_acc = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    n_accepted = jnp.sum(prefix_acc, axis=1)  # (B,)

    # distribution for the next token:
    #  - if n == g: the bonus distribution target_probs[:, g]
    #  - else: residual norm(max(p_n - q_n, 0)) at the first rejected position
    idx = jnp.minimum(n_accepted, g - 1)  # first rejected position (clamped)
    p_rej = jnp.take_along_axis(
        target_probs[:, :g], idx[:, None, None], axis=1)[:, 0]  # (B, V)
    q_rej = jnp.take_along_axis(draft_probs, idx[:, None, None], axis=1)[:, 0]
    residual = jnp.maximum(p_rej - q_rej, 0.0)
    res_sum = jnp.sum(residual, axis=-1, keepdims=True)
    # numerical guard: if residual is empty (p == q exactly), fall back to p
    residual = jnp.where(res_sum > 1e-9, residual / jnp.maximum(res_sum, 1e-9), p_rej)
    bonus = target_probs[:, g]
    next_dist = jnp.where((n_accepted == g)[:, None], bonus, residual)
    next_token = jax.random.categorical(ks, jnp.log(jnp.maximum(next_dist, 1e-30)))

    pos = jnp.arange(g + 1)[None, :]
    committed = jnp.where(
        pos < n_accepted[:, None],
        jnp.pad(draft_tokens, ((0, 0), (0, 1))),
        jnp.where(pos == n_accepted[:, None], next_token[:, None], -1),
    )
    return {"n_accepted": n_accepted, "next_token": next_token,
            "tokens": committed}


def verify_greedy(draft_tokens, target_logits):
    """Greedy verification: accept while draft token == target argmax.

    target_logits: (B, g+1, V).  Deterministic — used by losslessness tests
    (greedy spec decoding must emit exactly the target's greedy sequence).
    """
    B, g = draft_tokens.shape
    tgt = jnp.argmax(target_logits, axis=-1)  # (B, g+1)
    match = tgt[:, :g] == draft_tokens
    prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)
    n_accepted = jnp.sum(prefix, axis=1)
    next_token = jnp.take_along_axis(tgt, n_accepted[:, None], axis=1)[:, 0]
    pos = jnp.arange(g + 1)[None, :]
    committed = jnp.where(
        pos < n_accepted[:, None],
        jnp.pad(draft_tokens, ((0, 0), (0, 1))),
        jnp.where(pos == n_accepted[:, None], next_token[:, None], -1),
    )
    return {"n_accepted": n_accepted, "next_token": next_token,
            "tokens": committed}
