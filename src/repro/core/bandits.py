"""Baseline speculative-length policies evaluated against Nightjar.

All policies share the interface:
    select(batch, delta_max=0) -> gamma
    observe(batch, gamma, latency_per_token, n_accepted=None, delta_max=0)

Implemented (paper §7.1 baselines + §8.2.1 ablations):
  * FixedGamma        — standard SD (gamma=3) / vanilla AR (gamma=0)
  * EpsilonGreedy     — decaying-epsilon bandit, batch size as context
  * UCBBandit         — BanditSpec-style UCB, NO batch-size context
  * LinUCB            — linear contextual bandit on batch-size features
  * DSD               — linear goodput model from historical acceptance;
                        reproduces the paper's "deadlock" vulnerability
                        (disabling speculation halts data collection)
  * AdaBinGreedy      — Nightjar's scaffold WITHOUT the switch-cost term
"""
from __future__ import annotations

import math
import random
from collections import defaultdict
from typing import Dict, Optional, Tuple

import numpy as np

from .planner import NightjarPlanner


class Policy:
    name = "policy"

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        raise NotImplementedError

    def observe(self, batch: int, gamma: int, latency_per_token: float,
                *, n_accepted: Optional[float] = None, delta_max: int = 0) -> None:
        pass

    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, d: dict) -> None:
        pass


class FixedGamma(Policy):
    def __init__(self, gamma: int):
        self.gamma = gamma
        self.name = f"fixed-{gamma}" if gamma else "ar"

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        return self.gamma


class EpsilonGreedy(Policy):
    name = "eps-greedy"

    def __init__(self, gamma_max: int, *, eps0: float = 0.5, decay: float = 0.999,
                 seed: int = 0, bucketing: bool = True):
        self.gamma_max = gamma_max
        self.eps = eps0
        self.decay = decay
        self.rng = random.Random(seed)
        self.bucketing = bucketing
        self.sums: Dict[Tuple[int, int], float] = defaultdict(float)
        self.counts: Dict[Tuple[int, int], int] = defaultdict(int)

    def _bucket(self, b: int) -> int:
        return 1 << max(b - 1, 0).bit_length() if self.bucketing else 0

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        B = self._bucket(batch)
        if self.rng.random() < self.eps:
            return self.rng.randrange(self.gamma_max + 1)
        means = []
        for g in range(self.gamma_max + 1):
            c = self.counts[(B, g)]
            means.append(self.sums[(B, g)] / c if c else 0.0)
        return int(np.argmin(means))

    def observe(self, batch, gamma, latency_per_token, *, n_accepted=None,
                delta_max: int = 0):
        B = self._bucket(batch)
        self.sums[(B, gamma)] += latency_per_token
        self.counts[(B, gamma)] += 1
        self.eps *= self.decay


class UCBBandit(Policy):
    """BanditSpec-style UCB over arms — static, no batch-size context."""

    name = "banditspec-ucb"

    def __init__(self, gamma_max: int, *, c: float = 0.5):
        self.gamma_max = gamma_max
        self.c = c
        self.sums = np.zeros(gamma_max + 1)
        self.counts = np.zeros(gamma_max + 1, dtype=int)
        self.t = 0

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        self.t += 1
        for g in range(self.gamma_max + 1):
            if self.counts[g] == 0:
                return g
        means = self.sums / self.counts
        # latency minimisation -> lower confidence bound
        bonus = self.c * np.sqrt(np.log(self.t) / self.counts)
        return int(np.argmin(means - bonus))

    def observe(self, batch, gamma, latency_per_token, *, n_accepted=None,
                delta_max: int = 0):
        self.sums[gamma] += latency_per_token
        self.counts[gamma] += 1


class LinUCB(Policy):
    """Linear contextual UCB; context = [1, B, B^2] (normalised)."""

    name = "linucb"

    def __init__(self, gamma_max: int, *, alpha: float = 0.3, b_scale: float = 64.0):
        self.gamma_max = gamma_max
        self.alpha = alpha
        self.b_scale = b_scale
        d = 3
        self.A = [np.eye(d) for _ in range(gamma_max + 1)]
        self.bv = [np.zeros(d) for _ in range(gamma_max + 1)]

    def _x(self, batch: int) -> np.ndarray:
        z = batch / self.b_scale
        return np.array([1.0, z, z * z])

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        x = self._x(batch)
        best, best_val = 0, float("inf")
        for g in range(self.gamma_max + 1):
            Ainv = np.linalg.inv(self.A[g])
            theta = Ainv @ self.bv[g]
            # lower confidence bound on latency
            val = float(theta @ x) - self.alpha * math.sqrt(float(x @ Ainv @ x))
            if val < best_val:
                best, best_val = g, val
        return best

    def observe(self, batch, gamma, latency_per_token, *, n_accepted=None,
                delta_max: int = 0):
        x = self._x(batch)
        self.A[gamma] += np.outer(x, x)
        self.bv[gamma] += latency_per_token * x


class DSD(Policy):
    """Dynamic Speculative Decoding (Liu et al. 2024): linear latency model +
    historical acceptance rate; picks argmax expected goodput.

    Faithfully reproduces the deadlock: once gamma=0 is selected, acceptance
    statistics stop updating, so the expected benefit of speculation never
    recovers (paper §9.1)."""

    name = "dsd"

    def __init__(self, gamma_max: int, *, ema: float = 0.95):
        self.gamma_max = gamma_max
        self.ema = ema
        self.alpha = 0.7  # initial per-token acceptance estimate
        # per-(bucket) linear model latency(B, gamma) ~ base(B) + slope(B)*gamma
        self.lat: Dict[Tuple[int, int], float] = {}

    def _bucket(self, b: int) -> int:
        return 1 << max(b - 1, 0).bit_length()

    def _latency(self, B: int, g: int) -> float:
        if (B, g) in self.lat:
            return self.lat[(B, g)]
        # fit from the two nearest observed gammas, else optimistic constant
        obs = sorted(gg for (bb, gg) in self.lat if bb == B)
        if len(obs) >= 2:
            g1, g2 = obs[0], obs[-1]
            l1, l2 = self.lat[(B, g1)], self.lat[(B, g2)]
            slope = (l2 - l1) / max(g2 - g1, 1)
            return l1 + slope * (g - g1)
        if len(obs) == 1:
            return self.lat[(B, obs[0])]
        return 0.0

    def select(self, batch: int, *, delta_max: int = 0) -> int:
        B = self._bucket(batch)
        best, best_gp = 0, -float("inf")
        for g in range(self.gamma_max + 1):
            # expected committed tokens per step: (1 - a^(g+1)) / (1 - a)
            a = min(self.alpha, 0.999)
            exp_tokens = (1 - a ** (g + 1)) / (1 - a) if g else 1.0
            lat = self._latency(B, g)
            gp = exp_tokens / lat if lat > 0 else exp_tokens
            if gp > best_gp:
                best, best_gp = g, gp
        return best

    def observe(self, batch, gamma, latency_per_token, *, n_accepted=None,
                delta_max: int = 0):
        B = self._bucket(batch)
        # per-step latency model uses step latency = lpt * committed tokens
        step_latency = latency_per_token * ((n_accepted or 0) + 1 if gamma else 1.0)
        key = (B, gamma)
        self.lat[key] = (self.ema * self.lat[key] + (1 - self.ema) * step_latency
                         if key in self.lat else step_latency)
        if gamma > 0 and n_accepted is not None:
            # per-token acceptance probability estimate
            rate = min(n_accepted / gamma, 1.0)
            self.alpha = self.ema * self.alpha + (1 - self.ema) * rate
        # NOTE: when gamma == 0 nothing updates alpha — the deadlock.


class AdaBinGreedy(NightjarPlanner):
    """Ablation: ADA-BINGREEDY scaffold without the C_switch term."""

    name = "ada-bingreedy"

    def __init__(self, gamma_max: int, **kw):
        kw.pop("use_switch_cost", None)
        super().__init__(gamma_max, use_switch_cost=False, **kw)


def make_policy(name: str, gamma_max: int, *, cswitch=None, seed: int = 0):
    if name == "nightjar":
        return NightjarPlanner(gamma_max, cswitch, seed=seed)
    if name == "ada-bingreedy":
        return AdaBinGreedy(gamma_max, seed=seed)
    if name == "eps-greedy":
        return EpsilonGreedy(gamma_max, seed=seed)
    if name == "banditspec":
        return UCBBandit(gamma_max)
    if name == "linucb":
        return LinUCB(gamma_max)
    if name == "dsd":
        return DSD(gamma_max)
    if name == "ar" or name == "w/o-sd":
        return FixedGamma(0)
    if name.startswith("fixed-"):
        return FixedGamma(int(name.split("-")[1]))
    if name == "sd":
        return FixedGamma(3)
    raise KeyError(name)
