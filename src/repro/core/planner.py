"""The Nightjar planner — Algorithm 1 of the paper, verbatim.

Per-batch-size timelines organised into exponentially growing *blocks*
(H_B = 2^(j_B - 1)) of *bins*; a bin explores with probability 1/b_B,
otherwise exploits Eq. (4):

    gamma_t = argmin_gamma { l~(B, gamma)
                             + 1[gamma_{t-1} = 0 and gamma > 0] * C_switch / gamma }

The selected arm is LOCKED for the whole bin, bounding switch count (and
hence switching regret) to O(sqrt(T)) — Appendix A.

This is host-side control logic (the paper measures arm selection at ~1e-5 s
per step); the planner state is a plain pytree of Python scalars so it can be
checkpointed and restored for fault tolerance.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .cswitch import CSwitchTable


@dataclass
class _BState:
    """Per-batch-size hierarchy state (Algorithm 1 lines 1-3)."""

    j: int = 1      # block index
    H: float = 1.0  # block duration 2^(j-1)
    b: int = 1      # bin index within block
    tau: int = 1    # round counter within bin
    gamma_curr: int = 0
    explore_bin: bool = False


@dataclass
class ArmStats:
    count: int = 0
    total: float = 0.0

    @property
    def mean(self) -> float:
        # optimistic initialisation: unseen arms look free, so exploitation
        # visits each arm at least once before trusting the estimates
        return self.total / self.count if self.count else 0.0


class NightjarPlanner:
    """Contextual MAB over speculative lengths, batch size as context."""

    name = "nightjar"

    def __init__(self, gamma_max: int, cswitch: Optional[CSwitchTable] = None,
                 *, batch_bucketing: str = "pow2", seed: int = 0,
                 use_switch_cost: bool = True):
        self.gamma_max = gamma_max
        self.cswitch = cswitch or CSwitchTable.constant(0.0)
        self.use_switch_cost = use_switch_cost
        self.batch_bucketing = batch_bucketing
        self.rng = random.Random(seed)
        self.states: Dict[int, _BState] = {}
        self.stats: Dict[Tuple[int, int], ArmStats] = {}
        self.prev_gamma: int = 0  # gamma_{t-1} (global across batch sizes)
        self.t: int = 0
        self.switch_count: int = 0

    # ------------------------------------------------------------------
    def bucket(self, batch: int) -> int:
        if self.batch_bucketing == "exact":
            return max(batch, 1)
        return 1 << max(batch - 1, 0).bit_length()  # next power of two

    def _arm_stats(self, B: int, gamma: int) -> ArmStats:
        key = (B, gamma)
        if key not in self.stats:
            self.stats[key] = ArmStats()
        return self.stats[key]

    def _eq4(self, B: int, delta_max: int, batch: int) -> int:
        """Exploitation arm: Eq. (4)."""
        best, best_val = 0, float("inf")
        for g in range(self.gamma_max + 1):
            val = self._arm_stats(B, g).mean
            if self.use_switch_cost and self.prev_gamma == 0 and g > 0:
                val += self.cswitch.lookup(delta_max, batch) / g
            if val < best_val:
                best, best_val = g, val
        return best

    # ------------------------------------------------------------------
    def select(self, batch: int, *, delta_max: int = 0) -> int:
        """Choose the speculative length for the current decoding step."""
        B = self.bucket(batch)
        st = self.states.setdefault(B, _BState())

        if st.tau == 1:  # bin start: select strategy & arm (lines 6-15)
            p = 1.0 / st.b
            if self.rng.random() < p:
                st.explore_bin = True
                st.gamma_curr = self.rng.randrange(self.gamma_max + 1)
            else:
                st.explore_bin = False
                st.gamma_curr = self._eq4(B, delta_max, batch)
        gamma = st.gamma_curr
        if gamma != self.prev_gamma:
            self.switch_count += 1
        return gamma

    def observe(self, batch: int, gamma: int, latency_per_token: float,
                *, n_accepted=None, delta_max: int = 0) -> None:
        """Record the realised loss (Eq. 1) and advance the hierarchy."""
        B = self.bucket(batch)
        st = self.states.setdefault(B, _BState())

        loss = latency_per_token
        if self.use_switch_cost and self.prev_gamma == 0 and gamma > 0:
            loss += self.cswitch.lookup(delta_max, batch) / max(gamma, 1)
        s = self._arm_stats(B, gamma)
        s.count += 1
        s.total += loss

        self.prev_gamma = gamma
        self.t += 1

        # hierarchy bookkeeping (lines 19-25)
        st.tau += 1
        if st.tau > math.sqrt(st.H):
            st.b += 1
            st.tau = 1
            if st.b > math.sqrt(st.H):
                st.j += 1
                st.H = 2.0 ** (st.j - 1)
                st.b = 1

    # ------------------------------------------------------------------
    # fault tolerance: planner state serialisation
    def state_dict(self) -> dict:
        return {
            "gamma_max": self.gamma_max,
            "prev_gamma": self.prev_gamma,
            "t": self.t,
            "switch_count": self.switch_count,
            "states": {B: vars(s).copy() for B, s in self.states.items()},
            "stats": {f"{B}:{g}": (s.count, s.total)
                      for (B, g), s in self.stats.items()},
            "rng_state": self.rng.getstate(),
        }

    def load_state_dict(self, d: dict) -> None:
        self.prev_gamma = d["prev_gamma"]
        self.t = d["t"]
        self.switch_count = d["switch_count"]
        self.states = {int(B): _BState(**s) for B, s in d["states"].items()}
        self.stats = {}
        for key, (c, tot) in d["stats"].items():
            B, g = key.split(":")
            self.stats[(int(B), int(g))] = ArmStats(count=c, total=tot)
        rs = d["rng_state"]
        # json round-trips tuples as lists
        self.rng.setstate((rs[0], tuple(rs[1]), rs[2]))
