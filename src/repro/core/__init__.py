from . import bandits, cswitch, planner, spec_decode, verify  # noqa: F401
