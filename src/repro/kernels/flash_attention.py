"""Blockwise (flash) attention kernel for prefill/training — VMEM-tiled.

Grid: (batch*heads, q_blocks, kv_blocks) with a running-softmax accumulator
held in VMEM scratch across the kv_blocks axis.  Block shapes are
(BLOCK_Q x head_dim) / (BLOCK_K x head_dim) — multiples of the 8x128 VPU
lanes and MXU-friendly for head_dim in {64, 128, 256}.

Causal masking prunes nothing here (TPU grids are sequential per core), but
out-of-window tiles are masked exactly; the hillclimbed variant skips fully
masked tiles via the grid order (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF, flash_attention_ref

BLOCK_Q = 128
BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal, block_q, block_k, kv_blocks):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (block_q, D)
    k = k_ref[0].astype(jnp.float32)  # (block_k, D)
    v = v_ref[0].astype(jnp.float32)
    D = q.shape[-1]
    s = (q * (D ** -0.5)) @ k.T       # (block_q, block_k)

    if causal:
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale + p @ v
    m_ref[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = BLOCK_Q,
                    block_k: int = BLOCK_K, interpret: bool = True):
    """q: (B, S, H, D), k/v: (B, S, KH, D) -> (B, S, H, D).  GQA supported by
    repeating kv heads at the wrapper level (kernel sees matched heads)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)

    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)

    # (B*H, S, D) layout
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)

    grid = (B * H, S // block_q, S // block_k)
    kernel = functools.partial(_flash_kernel, causal=causal, block_q=block_q,
                               block_k=block_k, kv_blocks=S // block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
