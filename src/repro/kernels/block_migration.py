"""KV-cache block-migration kernel — the paper's §6.4 step-3 Triton kernel,
adapted to TPU with Pallas.

The paper launches one thread block per migrated KV block and moves it with
vectorised load/stores.  On TPU the analogue is a Pallas grid over
(migration entries x row chunks): the scalar-prefetched migration map drives
the BlockSpec index_map, so the DMA engine pipelines the non-contiguous
HBM->VMEM->HBM block copies.  ``input_output_aliases`` makes the move
in-place (no second pool allocation), matching the Triton kernel's in-place
compaction semantics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import migrate_blocks_ref

# rows are copied in chunks of this many elements (8x128-aligned)
_CHUNK = 1024


def _migrate_kernel(src_ref, dst_ref, x_ref, o_ref):
    # one (migration entry, chunk) cell: pure copy through VMEM
    o_ref[...] = x_ref[...]


def _migrate_rows_pallas(x, src, dst, *, interpret=True):
    """x: (num_blocks, row) float; src/dst: (M,) int32."""
    nb, row = x.shape
    chunk = min(_CHUNK, row)
    assert row % chunk == 0, (row, chunk)
    grid = (src.shape[0], row // chunk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk), lambda i, j, src_ref, dst_ref: (src_ref[i], j)),
        ],
        out_specs=pl.BlockSpec((1, chunk),
                               lambda i, j, src_ref, dst_ref: (dst_ref[i], j)),
    )
    return pl.pallas_call(
        _migrate_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={2: 0},  # x aliases the output: in-place move
        interpret=interpret,
    )(src, dst, x)


def migrate_blocks(pool, src, dst, *, use_kernel: bool = False,
                   interpret: bool = True):
    """pool: (L, num_blocks, ...) — copy blocks src->dst along axis 1.

    use_kernel=False runs the pure-jnp oracle (the fast path on this CPU
    container); use_kernel=True exercises the Pallas kernel (interpret mode
    on CPU, compiled on TPU)."""
    L, nb = pool.shape[:2]
    rest = pool.shape[2:]
    if not use_kernel:
        return jnp.moveaxis(
            migrate_blocks_ref(jnp.moveaxis(pool, 1, 0).reshape(nb, -1),
                               src, dst).reshape((nb, L) + rest),
            0, 1)
    rows = jnp.moveaxis(pool, 1, 0).reshape(nb, -1)
    row = rows.shape[1]
    # pad row dim to a lane-aligned chunk multiple
    chunk = min(_CHUNK, max(128, row))
    pad = (-row) % chunk
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
    out = _migrate_rows_pallas(rows, src.astype(jnp.int32),
                               dst.astype(jnp.int32), interpret=interpret)
    out = out[:, :row].reshape((nb, L) + rest)
    return jnp.moveaxis(out, 0, 1)
