"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(``tests/test_kernels.py`` sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def migrate_blocks_ref(x, src, dst):
    """x: (num_blocks, row); copy rows src -> dst (one-to-one)."""
    return x.at[dst].set(x[src])


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths):
    """Attention of a T-token extension over paged KV.

    q:            (B, H, D) single-query decode, or (B, T, H, D) multi-query —
                  one kernel shape serves plain decode (T=1), speculative
                  verification (T=gamma+1) and chunked-prefill appends
                  (T=chunk tokens just scattered into freshly grown blocks)
    k/v_pages:    (num_blocks, block_size, KH, D)
    block_tables: (B, max_blocks) int32 (padded with any valid id)
    lengths:      (B,) valid token counts INCLUDING the T new positions
                  (whose K/V are already written into the pages); query t
                  attends to positions <= lengths - T + t, i.e. causally
                  within the extension
    returns       same rank as q
    """
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, T, H, D = q.shape
    nb, bs, KH, _ = k_pages.shape
    G = H // KH
    max_blocks = block_tables.shape[1]
    S = max_blocks * bs

    # gather each sequence's KV contiguously
    k = k_pages[block_tables].reshape(B, S, KH, D)
    v = v_pages[block_tables].reshape(B, S, KH, D)
    qg = q.reshape(B, T, KH, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    limit = lengths[:, None] - T + jnp.arange(T)[None, :]         # (B, T)
    mask = jnp.arange(S)[None, None, :] <= limit[:, :, None]      # (B, T, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    out = out.reshape(B, T, H, D).astype(q.dtype)
    return out[:, 0] if squeeze else out


def flash_attention_ref(q, k, v, *, causal=True):
    """Standard full attention. q: (B, S, H, D), k/v: (B, S, KH, D)."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)
