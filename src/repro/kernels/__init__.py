from . import block_migration, flash_attention, ops, paged_attention, ref  # noqa: F401
