"""Jit'd public wrappers for the Pallas kernels.

Each op dispatches between the compiled Pallas kernel (TPU target /
interpret-mode validation) and the pure-jnp oracle (``ref.py``) — the oracle
is the default execution path on this CPU container.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import block_migration, flash_attention, paged_attention, ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def migrate_blocks(pool, src, dst, *, use_kernel=False, interpret=True):
    return block_migration.migrate_blocks(pool, src, dst,
                                          use_kernel=use_kernel,
                                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def paged_attention_op(q, k_pages, v_pages, block_tables, lengths, *,
                       use_kernel=False, interpret=True):
    """q may be (B, H, D) single-query decode or (B, T, H, D) multi-query
    (speculative verify / chunked-prefill appends); see ref for masking."""
    if use_kernel:
        return paged_attention.paged_attention(
            q, k_pages, v_pages, block_tables, lengths, interpret=interpret)
    return ref.paged_attention_ref(q, k_pages, v_pages, block_tables, lengths)


@functools.partial(jax.jit, static_argnames=("causal", "use_kernel", "interpret"))
def flash_attention_op(q, k, v, *, causal=True, use_kernel=False, interpret=True):
    if use_kernel:
        return flash_attention.flash_attention(q, k, v, causal=causal,
                                               interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal)
