"""Paged multi-query attention — the TPU analogue of vLLM's PagedAttention.

One grid cell per (sequence, kv-head); the scalar-prefetched block table
drives the BlockSpec index map so each sequence's non-contiguous KV blocks
stream through VMEM.  A running (max, sum) softmax accumulates across the
sequence's pages — the VMEM working set is one (block_size, head_dim) page
pair plus the (T*G, head_dim) query/accumulator tile, independent of context
length.

The query carries T positions per sequence, so ONE kernel serves all three
real-backend shapes: plain decode (T=1), speculative verification
(T=gamma+1), and chunked-prefill appends (T=chunk tokens scattered into
freshly grown blocks).  ``lengths`` counts the valid tokens INCLUDING the T
new positions; query t attends to page positions <= lengths - T + t, i.e.
causally within the extension.

Validated in interpret mode against ref.paged_attention_ref over
shape/dtype/T/GQA sweeps with ragged lengths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import NEG_INF, paged_attention_ref


def _paged_attn_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                       m_ref, l_ref, acc_ref, *, block_size, pages_per_seq,
                       n_queries, group):
    b = pl.program_id(0)
    page = pl.program_id(2)

    @pl.when(page == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (T*G, D)
    k = k_ref[0, 0].astype(jnp.float32)           # (block_size, D)
    v = v_ref[0, 0].astype(jnp.float32)
    D = q.shape[-1]

    s = (q * (D ** -0.5)) @ k.T                   # (T*G, block_size)
    length = lengths_ref[b]
    pos = page * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    # row r of the query tile is query t = r // group: it may attend to
    # every position at or before its own (length - n_queries + t)
    t = jax.lax.broadcasted_iota(jnp.int32, (q.shape[0], 1), 0) // group
    limit = length - n_queries + t                # (T*G, 1)
    s = jnp.where(pos <= limit, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * scale + p @ v
    m_ref[...] = m_new

    @pl.when(page == pages_per_seq - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    interpret: bool = True):
    """q: (B, H, D) or (B, T, H, D); k/v_pages: (num_blocks, block_size,
    KH, D); block_tables: (B, max_blocks); lengths: (B,) valid tokens
    including the T new positions -> output of the same rank as q."""
    squeeze = q.ndim == 3
    if squeeze:
        q = q[:, None]
    B, T, H, D = q.shape
    nb, bs, KH, _ = k_pages.shape
    G = H // KH
    pages_per_seq = block_tables.shape[1]

    # query tile rows ordered (t, g): row = t * G + g
    qg = q.reshape(B, T, KH, G, D).transpose(0, 2, 1, 3, 4).reshape(
        B, KH, T * G, D)
    # kv pages viewed per head: (num_blocks, KH, block_size, D)
    kp = jnp.swapaxes(k_pages, 1, 2)
    vp = jnp.swapaxes(v_pages, 1, 2)

    grid = (B, KH, pages_per_seq)
    kernel = functools.partial(_paged_attn_kernel, block_size=bs,
                               pages_per_seq=pages_per_seq, n_queries=T,
                               group=G)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, T * G, D),
                         lambda b, h, p, t_ref, l_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, p, t_ref, l_ref: (t_ref[b, p], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, p, t_ref, l_ref: (t_ref[b, p], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, T * G, D),
                               lambda b, h, p, t_ref, l_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 1), jnp.float32),   # running max
            pltpu.VMEM((T * G, 1), jnp.float32),   # running denominator
            pltpu.VMEM((T * G, D), jnp.float32),   # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, T * G, D), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qg, kp, vp)
    out = out.reshape(B, KH, T, G, D).transpose(0, 2, 1, 3, 4)
    out = out.reshape(B, T, H, D)
    return out[:, 0] if squeeze else out
