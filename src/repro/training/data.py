"""Deterministic synthetic token pipeline.

A seeded Zipf-ish token stream with enough structure (bigram transitions) for
a ~100M model to show a clearly decreasing loss in a few hundred steps.  The
pipeline is cursor-addressable: batch_at(step) is a pure function of (seed,
step), so a restarted job resumes mid-epoch without data skew — the data
cursor is part of the checkpoint metadata implicitly (just the step).
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, *, seed: int = 0, order: int = 1):
        self.vocab = vocab_size
        rng = np.random.default_rng(seed)
        # sparse bigram transition table: each token has k likely successors
        k = 8
        self.next_tokens = rng.integers(0, vocab_size, size=(vocab_size, k))
        self.next_probs = rng.dirichlet(np.ones(k) * 0.5, size=vocab_size)
        self.seed = seed

    def batch_at(self, step: int, batch: int, seq: int):
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            cur = toks[:, t]
            choice = np.array([
                rng.choice(self.next_tokens[c], p=self.next_probs[c])
                for c in cur])
            # 10% uniform noise
            noise = rng.uniform(size=batch) < 0.1
            choice = np.where(noise, rng.integers(0, self.vocab, size=batch),
                              choice)
            toks[:, t + 1] = choice
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_batch_iter(vocab_size: int, batch: int, seq: int, *, seed: int = 0):
    ds = SyntheticLM(vocab_size, seed=seed)

    def it(step: int):
        import jax.numpy as jnp
        b = ds.batch_at(step, batch, seq)
        return {k: jnp.asarray(v) for k, v in b.items()}

    return it
