"""Jit-able train step factory + fault-tolerant training loop.

make_train_step(cfg) builds `(params, opt, batch) -> (metrics, params, opt)`
with donated parameter/optimizer buffers — this is the function the dry-run
lowers on the production mesh for every `train_4k` cell.
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.registry import get_model
from .optimizer import AdamWState, adamw_init, adamw_update, cosine_schedule


def make_train_step(cfg, *, base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, accum: int = 1,
                    accum_dtype=jnp.float32):
    api = get_model(cfg)
    lr_fn = cosine_schedule(base_lr, warmup, total_steps)

    def loss_fn(params, batch):
        loss, _ = api.loss(params, batch)
        return loss

    def train_step(params, opt: AdamWState, batch):
        if accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # gradient accumulation over `accum` microbatches (leading axis)
            def micro(carry, mb):
                acc_loss, acc_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_loss + l,
                        jax.tree.map(lambda a, b: (a + b.astype(a.dtype)),
                                     acc_grads, g)), None
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                                 params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zeros), batch)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt, gnorm = adamw_update(grads, opt, params, lr_fn=lr_fn)
        return {"loss": loss, "grad_norm": gnorm}, params, opt

    return api, train_step


def train(cfg, *, steps: int, batch_iter, rng=None,
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 50,
          resume: bool = True, hooks: Optional[list] = None,
          base_lr: float = 1e-3, warmup: int = 10) -> Dict[str, Any]:
    """Single-host training loop with checkpoint/restart fault tolerance."""
    from . import checkpoint as ckpt

    api, train_step = make_train_step(cfg, base_lr=base_lr, warmup=warmup,
                                      total_steps=max(steps, 100))
    step_fn = jax.jit(train_step, donate_argnums=(0, 1))

    start_step = 0
    params = opt = None
    if checkpoint_dir and resume:
        p_t = jax.eval_shape(api.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        o_t = jax.eval_shape(adamw_init, p_t)
        restored = ckpt.restore_latest(checkpoint_dir,
                                       template={"params": p_t, "opt": o_t})
        if restored is not None:
            params, opt, meta = restored
            start_step = int(meta["step"])

    if params is None:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        params = api.init(rng)
        opt = adamw_init(params)

    history = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        batch = batch_iter(step)
        metrics, params, opt = step_fn(params, opt, batch)
        if hooks:
            for h in hooks:
                h(step, metrics)
        if step % 10 == 0 or step == steps - 1:
            history.append({"step": step,
                            "loss": float(metrics["loss"]),
                            "grad_norm": float(metrics["grad_norm"])})
        if checkpoint_dir and (step + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, params, opt, {"step": step + 1})
    elapsed = time.perf_counter() - t0
    if checkpoint_dir:
        ckpt.save(checkpoint_dir, params, opt, {"step": steps})
    return {"history": history, "params": params, "opt": opt,
            "elapsed_s": elapsed, "final_loss": history[-1]["loss"]}
