"""Checkpointing: atomic, restart-safe pytree save/restore.

Layout: <dir>/step_<N>/
           arrays.npz      — flattened leaves
           manifest.json   — treedef + dtypes + metadata
        <dir>/LATEST        — committed pointer (atomic rename)

Multi-host note: on a real cluster each host writes its process-local shards
(jax.experimental.multihost_utils); here the single-process path saves the
addressable arrays.  The commit protocol (write-all, then atomically move the
LATEST pointer) is the part that matters for fault tolerance: a crash
mid-write never corrupts the last valid checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, str(treedef)


def save(directory: str, params, opt, meta: dict) -> str:
    os.makedirs(directory, exist_ok=True)
    step = meta.get("step", 0)
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    state = {"params": params, "opt": opt}
    leaves, treedef = jax.tree.flatten(state)
    arrays, dtypes = {}, []
    for i, x in enumerate(leaves):
        a = np.asarray(x)
        dtypes.append(str(a.dtype))
        if a.dtype.kind == "V":  # bfloat16: npz cannot round-trip it
            a = a.view(np.uint16)
        arrays[f"leaf_{i}"] = a
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"meta": meta, "num_leaves": len(leaves),
                   "dtypes": dtypes}, f)

    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic pointer commit
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step}")
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    return final


def restore_latest(directory: str, *, template: Optional[Any] = None
                   ) -> Optional[Tuple[Any, Any, dict]]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.isdir(path):
        return None
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    import ml_dtypes
    leaves = []
    for i in range(manifest["num_leaves"]):
        a = data[f"leaf_{i}"]
        want = manifest.get("dtypes", [None] * (i + 1))[i]
        if want == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        leaves.append(a)

    if template is not None:
        treedef = jax.tree.structure(template)
    else:
        # reconstruct structure by saving a probe is impossible without the
        # template; training resaves with the same model so we rebuild lazily
        raise ValueError("restore_latest requires template=... for structure")
    state = jax.tree.unflatten(treedef, [jax.numpy.asarray(x) for x in leaves])
    return state["params"], state["opt"], manifest["meta"]
