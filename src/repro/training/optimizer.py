"""AdamW with global-norm clipping and cosine schedule — from scratch.

Optimizer moments are fp32 and shard exactly like the parameters (ZeRO
semantics come for free from the 2D parameter sharding).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params, *, m_dtype=jnp.float32, v_dtype=jnp.float32) -> AdamWState:
    """Moment dtypes are configurable: >100B-parameter models on 16 GB/chip
    store the second moment in bf16 (DESIGN.md §4 memory-fit policy)."""
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, m_dtype), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, v_dtype), params))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr_fn,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd_core(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_new = (b1 * m.astype(jnp.float32) + (1 - b1) * g).astype(m.dtype)
        v_new = (b2 * v.astype(jnp.float32)
                 + (1 - b2) * jnp.square(g)).astype(v.dtype)
        mhat = m_new.astype(jnp.float32) / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new.astype(jnp.float32) / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr_fn(step) * delta
        return p_new.astype(p.dtype), m_new, v_new

    # NOTE: a scan-chunked variant of this update (bounding fp32 temps per
    # chunk) was tried and REVERTED: the scan ys buffers broke in-place
    # donation and raised peak memory 24 -> 39 GB on grok-1 (§Perf iter 10).
    upd = upd_core

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return params_new, AdamWState(step=step, m=m_new, v=v_new), gnorm
