"""Request and sequence lifecycle for the serving engine."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional


@dataclass
class Request:
    req_id: int
    arrival: float            # seconds
    prompt_len: int
    output_len: int
    alpha: float = 0.8        # per-token draft-acceptance quality (sim tier)
    prompt_tokens: Optional[List[int]] = None  # real tier


@dataclass
class Sequence:
    """A request admitted to the running batch."""

    request: Request
    slot: int = -1
    generated: int = 0
    delta: int = 0            # draft-model skip length (tokens missing from
                              # the draft KV cache) — drives C_switch lookup
    prefill_done_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def context_len(self) -> int:
        return self.request.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.request.output_len


@dataclass
class Metrics:
    """Aggregated per-run serving metrics."""

    total_tokens: int = 0
    elapsed: float = 0.0
    latencies: List[float] = field(default_factory=list)   # per-request e2e
    ttfts: List[float] = field(default_factory=list)
    timeline: List[dict] = field(default_factory=list)     # per-step records
    switch_count: int = 0
    offload_events: int = 0
    reload_events: int = 0

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies) if self.latencies else 0.0

    @property
    def mean_ttft(self) -> float:
        return sum(self.ttfts) / len(self.ttfts) if self.ttfts else 0.0

    def summary(self) -> dict:
        return {
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "switches": self.switch_count,
            "offloads": self.offload_events,
            "reloads": self.reload_events,
        }
