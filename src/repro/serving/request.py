"""Request and sequence lifecycle for the serving engine."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

# Bounded per-step timeline: when timeline recording is opted in, the
# per-step dicts live in a ring of this many entries (oldest evicted
# first) instead of an unbounded list, so long benches that never read
# the timeline stop accumulating memory for it.
TIMELINE_RING_CAP = 65_536

# Priority classes in descending importance.  Admission sheds and the
# scheduler preempts lowest-class-first; within a class age order rules
# (oldest request wins), so a single-class workload behaves exactly as
# before the classes existed.
PRIORITY_CLASSES = ("interactive", "batch", "best_effort")


def class_rank(priority: str) -> int:
    """0 = most important.  Unknown classes rank below every known one
    (they shed first) rather than raising mid-dispatch."""
    try:
        return PRIORITY_CLASSES.index(priority)
    except ValueError:
        return len(PRIORITY_CLASSES)


@dataclass
class Request:
    req_id: int
    arrival: float            # seconds
    prompt_len: int
    output_len: int
    alpha: float = 0.8        # per-token draft-acceptance quality (sim tier)
    prompt_tokens: Optional[List[int]] = None  # real tier
    slo: Optional[float] = None  # TTFT deadline (s) for goodput accounting
    session: Optional[int] = None  # multi-turn session id (sessions dataset)
    turn: int = 0             # 0 = cold first turn, >0 = warm return turn
    priority: str = "interactive"  # one of PRIORITY_CLASSES
    deadline: Optional[float] = None  # hard end-to-end budget (s past
                                      # arrival); expired requests are
                                      # reaped, not finished


@dataclass
class Sequence:
    """A request admitted to the running batch."""

    request: Request
    slot: int = -1
    generated: int = 0
    prefilled: int = 0        # prompt tokens whose KV is materialised; under
                              # chunked prefill this grows chunk by chunk
    cached_tokens: int = 0    # prompt tokens admitted from the prefix cache
                              # (shared blocks — no prefill compute needed)
    delta: int = 0            # draft-model skip length (tokens missing from
                              # the draft KV cache) — drives C_switch lookup
    prefill_done_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def prompt_remaining(self) -> int:
        """Prompt tokens still awaiting prefill (0 = decode-ready)."""
        return self.request.prompt_len - self.prefilled

    @property
    def context_len(self) -> int:
        return self.request.prompt_len + self.generated

    @property
    def done(self) -> bool:
        return self.generated >= self.request.output_len


def percentile(xs: List[float], q: float) -> float:
    """Linear-interpolation percentile (numpy-free, deterministic).

    An empty sample returns 0.0 by contract — indistinguishable from a
    true zero-latency percentile, so table renderers must gate on the
    sample COUNT and print ``n/a`` for empty cells (see
    benchmarks/make_tables.py; edge cases pinned in
    tests/test_metrics_edges.py)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = (len(s) - 1) * q
    lo = int(k)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (k - lo)


@dataclass
class RequestStats:
    """Per-request record for tail-latency / SLO accounting."""

    req_id: int
    arrival: float
    ttft: float               # first-token latency (s)
    tpot: float               # time per output token after the first (s)
    tokens: int               # committed output tokens
    slo: Optional[float]      # TTFT deadline, None = no deadline
    cached_tokens: int = 0    # prompt tokens admitted from the prefix cache
    turn: int = 0             # session turn (warm/cold TTFT split)
    priority: str = "interactive"  # priority class (per-class SLO splits)

    @property
    def slo_met(self) -> bool:
        return self.slo is None or self.ttft <= self.slo


def slo_attainment_of(requests: List["RequestStats"]) -> float:
    """Fraction of deadline-carrying requests that met their TTFT SLO
    (1.0 when no request carries a deadline)."""
    with_slo = [r for r in requests if r.slo is not None]
    if not with_slo:
        return 1.0
    return sum(r.slo_met for r in with_slo) / len(with_slo)


def goodput_of(requests: List["RequestStats"], elapsed: float,
               throughput: float) -> float:
    """Tokens/s counting only requests that met their TTFT SLO (AdaSpec-style
    goodput; falls back to raw throughput when no per-request stats exist).

    Zero/negative ``elapsed`` returns 0.0 by contract (no time base — the
    rate is undefined, not perfect); renderers must treat a cell with no
    finished requests as ``n/a``, not 0 (pinned in
    tests/test_metrics_edges.py)."""
    if elapsed <= 0:
        return 0.0
    if not requests:
        return throughput
    return sum(r.tokens for r in requests if r.slo_met) / elapsed


@dataclass
class Metrics:
    """Aggregated per-run serving metrics."""

    total_tokens: int = 0
    elapsed: float = 0.0
    latencies: List[float] = field(default_factory=list)   # per-request e2e
    ttfts: List[float] = field(default_factory=list)
    timeline: List[dict] = field(default_factory=list)     # per-step records
    requests: List[RequestStats] = field(default_factory=list)
    switch_count: int = 0
    offload_events: int = 0
    reload_events: int = 0
    blocks_allocated: int = 0              # cumulative free-list acquisitions
    prefix: dict = field(default_factory=dict)  # prefix-cache counters
    host: dict = field(default_factory=dict)    # host KV tier counters
                                                # (spills/restores/latency)
    fault_injected_s: float = 0.0  # extra seconds injected by straggler
                                   # fault windows (latency multiplier)
    cancelled: List[dict] = field(default_factory=list)  # client-cancelled
                                   # requests: {req_id, at, priority, slo}
    expired: List[dict] = field(default_factory=list)    # deadline-reaped
                                   # requests: {req_id, at, priority, slo}
    spec: dict = field(default_factory=dict)  # per-gamma speculation
                                   # aggregates (see note_spec_step)

    def use_timeline_ring(self, cap: int = TIMELINE_RING_CAP) -> None:
        """Bound the per-step timeline to a ring of ``cap`` entries.

        Called by the engine when timeline recording is opted in; existing
        entries are preserved (newest-first survival on overflow)."""
        if not isinstance(self.timeline, deque):
            self.timeline = deque(self.timeline, maxlen=cap)

    def note_spec_step(self, batch: int, gamma: int, committed: int,
                       latency: float, *, forced_off: bool = False,
                       restarted: bool = False) -> None:
        """Fold one engine step's (batch, gamma, n_accepted) observation —
        the same tuple the MAB planner sees — into per-gamma aggregates.

        ``committed`` is total committed tokens for the step; with
        speculation on, each sequence commits its accepted draft tokens
        plus one verified token, so accepted = committed - batch."""
        sp = self.spec
        if not sp:
            sp.update(steps=0, spec_steps=0, forced_off_steps=0, restarts=0,
                      per_gamma={})
        sp["steps"] += 1
        if forced_off:
            sp["forced_off_steps"] += 1
        if restarted:
            sp["restarts"] += 1
        if gamma > 0:
            sp["spec_steps"] += 1
        g = sp["per_gamma"].setdefault(
            gamma, {"steps": 0, "proposed": 0, "accepted": 0,
                    "committed": 0, "latency_s": 0.0})
        g["steps"] += 1
        g["committed"] += committed
        g["latency_s"] += latency
        if gamma > 0:
            g["proposed"] += gamma * batch
            g["accepted"] += max(committed - batch, 0)

    def spec_summary(self) -> dict:
        """Speculation aggregates for ``summary()`` — acceptance rate per
        gamma, spec-off step fraction, and speculation restart count."""
        sp = self.spec
        steps = sp.get("steps", 0)
        per_gamma = {}
        for gamma in sorted(sp.get("per_gamma", {})):
            g = sp["per_gamma"][gamma]
            row = {
                "steps": g["steps"],
                "committed_tokens": g["committed"],
                "latency_per_committed_s": round(
                    g["latency_s"] / g["committed"], 6)
                if g["committed"] else 0.0,
            }
            if gamma > 0:
                row["acceptance_rate"] = round(
                    g["accepted"] / g["proposed"], 4) if g["proposed"] \
                    else 0.0
            per_gamma[str(gamma)] = row
        return {
            "steps": steps,
            "spec_step_fraction": round(sp.get("spec_steps", 0) / steps, 4)
            if steps else 0.0,
            "spec_off_step_fraction": round(
                1.0 - sp.get("spec_steps", 0) / steps, 4) if steps else 0.0,
            "forced_off_steps": sp.get("forced_off_steps", 0),
            "restarts": sp.get("restarts", 0),
            "per_gamma": per_gamma,
        }

    def record_finish(self, seq: Sequence, now: float) -> None:
        """Stamp a completed sequence into the per-request stats."""
        first = seq.first_token_at if seq.first_token_at is not None else now
        ttft = first - seq.request.arrival
        tpot = (now - first) / max(seq.generated - 1, 1)
        self.requests.append(RequestStats(
            req_id=seq.req_id, arrival=seq.request.arrival, ttft=ttft,
            tpot=tpot, tokens=seq.generated, slo=seq.request.slo,
            cached_tokens=seq.cached_tokens, turn=seq.request.turn,
            priority=seq.request.priority))

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def mean_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies) if self.latencies else 0.0

    @property
    def mean_ttft(self) -> float:
        return sum(self.ttfts) / len(self.ttfts) if self.ttfts else 0.0

    @property
    def tpots(self) -> List[float]:
        return [r.tpot for r in self.requests]

    def ttft_percentile(self, q: float) -> float:
        return percentile([r.ttft for r in self.requests] or self.ttfts, q)

    def tpot_percentile(self, q: float) -> float:
        return percentile(self.tpots, q)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(0.99)

    @property
    def slo_attainment(self) -> float:
        return slo_attainment_of(self.requests)

    @property
    def goodput(self) -> float:
        return goodput_of(self.requests, self.elapsed, self.throughput)

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix-cache lookups that admitted shared blocks."""
        if not self.prefix or not self.prefix.get("queries"):
            return 0.0
        return self.prefix["hits"] / self.prefix["queries"]

    def summary(self) -> dict:
        out = self._base_summary()
        if self.prefix:
            out.update({
                "prefix_hit_rate": round(self.prefix_hit_rate, 4),
                "prefix_saved_tokens": self.prefix.get("saved_tokens", 0),
                "prefix_shared_blocks": self.prefix.get("shared_blocks", 0),
                "prefix_forks": self.prefix.get("forks", 0),
                "prefix_evictions": self.prefix.get("evictions", 0),
            })
        if self.host:
            out.update({
                "host_spills": int(self.host.get("spills", 0)),
                "host_restores": int(self.host.get("restores", 0)),
                "host_spill_s": round(self.host.get("spill_s", 0.0), 4),
                "host_restore_s": round(self.host.get("restore_s", 0.0), 4),
            })
        if self.fault_injected_s:
            out["fault_injected_s"] = round(self.fault_injected_s, 4)
        if self.cancelled or self.expired:
            out["cancelled"] = len(self.cancelled)
            out["expired"] = len(self.expired)
        if self.spec:
            out["spec"] = self.spec_summary()
        return out

    def _base_summary(self) -> dict:
        return {
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "p50_ttft_s": round(self.ttft_percentile(0.50), 4),
            "p95_ttft_s": round(self.ttft_percentile(0.95), 4),
            "p99_ttft_s": round(self.ttft_percentile(0.99), 4),
            "p50_tpot_s": round(self.tpot_percentile(0.50), 5),
            "p99_tpot_s": round(self.tpot_percentile(0.99), 5),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_tok_s": round(self.goodput, 2),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "switches": self.switch_count,
            "offloads": self.offload_events,
            "reloads": self.reload_events,
            "blocks_allocated": self.blocks_allocated,
        }
