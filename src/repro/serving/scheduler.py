"""Continuous batching scheduler (Orca-style iteration-level scheduling).

Admission is gated on paged-KV block availability through the
:class:`BlockManager`; finished sequences release their blocks at every
step; over-commit is resolved by preempt-and-recompute of the youngest
sequence (vLLM's recompute policy).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional

from .kv_cache import BlockManager, OutOfBlocks
from .request import Request, Sequence


class ContinuousBatchingScheduler:
    def __init__(self, block_manager: BlockManager, *, max_batch: int = 64,
                 watermark_frac: float = 0.02):
        self.bm = block_manager
        self.max_batch = max_batch
        self.watermark_frac = watermark_frac
        self.waiting: Deque[Request] = deque()
        self.running: List[Sequence] = []
        self._next_seq = 0

    # ------------------------------------------------------------------
    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    def add_request(self, req: Request) -> None:
        self.waiting.append(req)

    # ------------------------------------------------------------------
    def schedule(self) -> List[Sequence]:
        """Admit waiting requests while blocks + batch slots allow."""
        admitted: List[Sequence] = []
        watermark = int(self.bm.total_blocks * self.watermark_frac)
        while (self.waiting and len(self.running) < self.max_batch):
            req = self.waiting[0]
            need = self.bm.blocks_needed(req.prompt_len + 1)
            if self.bm.num_free - need < watermark:
                break
            self.waiting.popleft()
            seq = Sequence(request=req)
            self.bm.allocate(self._seq_key(seq), req.prompt_len + 1)
            self.running.append(seq)
            admitted.append(seq)
        return admitted

    def _seq_key(self, seq: Sequence) -> int:
        return seq.req_id

    # ------------------------------------------------------------------
    def commit_tokens(self, seq: Sequence, n: int) -> bool:
        """Record n committed tokens; returns False if the sequence had to be
        preempted (blocks exhausted)."""
        if self._seq_key(seq) not in self.bm.tables:
            return False  # already preempted this step
        try:
            self.bm.append_tokens(self._seq_key(seq), n)
            seq.generated += n
            return True
        except OutOfBlocks:
            self._preempt_youngest(exclude=seq)
            try:
                self.bm.append_tokens(self._seq_key(seq), n)
                seq.generated += n
                return True
            except OutOfBlocks:
                self._preempt(seq)
                return False

    def _preempt_youngest(self, exclude: Optional[Sequence] = None) -> None:
        candidates = [s for s in self.running if s is not exclude]
        if not candidates:
            return
        victim = max(candidates, key=lambda s: s.request.arrival)
        self._preempt(victim)

    def _preempt(self, seq: Sequence) -> None:
        """Recompute policy: release blocks, requeue at the front."""
        self.bm.release(self._seq_key(seq))
        if seq in self.running:
            self.running.remove(seq)
        req = seq.request
        # recompute from scratch: prompt + already-generated tokens count
        self.waiting.appendleft(req)

    def finish(self, seq: Sequence) -> None:
        self.bm.release(self._seq_key(seq))
        if seq in self.running:
            self.running.remove(seq)
