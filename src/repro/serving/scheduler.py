"""Continuous batching scheduler (Orca-style iteration-level scheduling).

Admission is gated on paged-KV block availability through the
:class:`BlockManager`; finished sequences release their blocks at every
step; over-commit is resolved by preempt-and-recompute of the youngest
sequence (vLLM's recompute policy).

Two execution regimes:

  * **monolithic** (``chunk_tokens=None``) — ``schedule()`` admits waiting
    requests whole; the engine prefills the full prompt in one call before
    any decode work happens.  This is the seed behaviour and stays the
    default.
  * **chunked / hybrid** (``chunk_tokens=N``) — ``schedule_chunks()`` emits a
    :class:`ScheduledBatch` mixing prefill *chunks* (at most ``chunk_tokens``
    prompt tokens per step, the per-step token budget) with the decode-ready
    sequences.  A long prompt no longer stalls every running sequence for a
    whole monolithic prefill: its KV blocks are allocated chunk by chunk and
    decode proceeds in the same iterations (Sarathi/vLLM-style chunked
    prefill, the head-of-line fix for p99 TTFT under load).

Scheduling order inside one chunked step is FIFO and progress-guaranteed:
partially prefilled *running* sequences are continued first (so a sequence
mid-prefill is never starved by decode-only steps or newer arrivals), then
the remaining budget admits new requests from the waiting queue.  With
``prefill_order="slo"`` the admission pass picks the waiting request with
the earliest TTFT deadline instead of strict FIFO (FIFO among equal /
absent deadlines); the continue-first progress guarantee is unchanged.

Prefix sharing (``BlockManager(prefix_caching=True)``): admission looks up
the longest cached prefix of the prompt, maps those blocks into the new
sequence's table at refcount+1 and starts the first prefill chunk at the
match boundary — a cached prefix costs no prefill compute and no new
blocks.  Any write range covering a shared block is privatised first via
``fork_for_write`` (copy-on-write).

With a host KV tier attached (``BlockManager(host_store=...)``), the same
``match_prefix`` walk transparently *restores* spilled blocks: a hash that
misses the device index but hits the host store is re-registered into a
free device block (host→device copy queued for the physical tier) and
returned in ``shared`` like any device hit — so admission counts
restorable blocks as cached with no scheduler-side special-casing, and the
watermark arithmetic is unchanged (restores move blocks free→cached, both
sides of ``num_allocatable``).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from .kv_cache import BlockManager, OutOfBlocks
from .request import Request, Sequence, class_rank


@dataclass
class ScheduledBatch:
    """One hybrid iteration's worth of work.

    ``prefill_chunks`` holds ``(seq, n_tokens)`` pairs — the prompt tokens
    each sequence prefills this step (KV blocks already reserved).
    ``decode`` holds the decode-ready sequences (prefill complete).
    ``admitted`` is the subset of chunk sequences newly admitted this step.
    """

    prefill_chunks: List[Tuple[Sequence, int]] = field(default_factory=list)
    decode: List[Sequence] = field(default_factory=list)
    admitted: List[Sequence] = field(default_factory=list)

    @property
    def prefill_tokens(self) -> int:
        return sum(n for _, n in self.prefill_chunks)

    @property
    def empty(self) -> bool:
        return not self.prefill_chunks and not self.decode


class ContinuousBatchingScheduler:
    def __init__(self, block_manager: BlockManager, *, max_batch: int = 64,
                 watermark_frac: float = 0.02,
                 chunk_tokens: Optional[int] = None,
                 min_chunk_tokens: Optional[int] = None,
                 prefill_order: str = "fifo"):
        if chunk_tokens is not None and chunk_tokens < 1:
            raise ValueError("chunk_tokens must be >= 1 (or None)")
        if prefill_order not in ("fifo", "slo"):
            raise ValueError(f"unknown prefill_order {prefill_order!r}")
        self.bm = block_manager
        self.max_batch = max_batch
        self.watermark_frac = watermark_frac
        self.chunk_tokens = chunk_tokens
        self.prefill_order = prefill_order
        # Sarathi-style total-token budget: each decode-ready sequence
        # consumes one of the step's chunk_tokens slots (the decode tokens
        # ride the same fused forward, so this is what actually bounds the
        # step's token count / TPOT spike).  At least min_chunk_tokens —
        # half the budget by default — stay reserved for prefill progress,
        # so a decode batch larger than the budget can never starve
        # admission/chunk progress outright.
        if min_chunk_tokens is None:
            min_chunk_tokens = max(1, (chunk_tokens or 0) // 2)
        self.min_chunk_tokens = min_chunk_tokens
        self.waiting: Deque[Request] = deque()
        self.running: List[Sequence] = []
        self._next_seq = 0
        # observability seam: engine.attach_trace wires these; trace_ctx
        # yields the live (clock, replica_id) so preemption events carry
        # engine time without the scheduler knowing about clocks
        self.trace = None
        self.trace_ctx = None

    # ------------------------------------------------------------------
    @property
    def num_waiting(self) -> int:
        return len(self.waiting)

    @property
    def num_running(self) -> int:
        return len(self.running)

    def add_request(self, req: Request) -> None:
        self.waiting.append(req)

    # ------------------------------------------------------------------
    def schedule(self) -> List[Sequence]:
        """Admit waiting requests while blocks + batch slots allow
        (monolithic path: blocks for the WHOLE prompt up front; prefix
        caching is a chunked-path feature — monolithic prefill always
        recomputes)."""
        admitted: List[Sequence] = []
        watermark = int(self.bm.total_blocks * self.watermark_frac)
        while (self.waiting and len(self.running) < self.max_batch):
            req = self.waiting[0]
            need = self.bm.blocks_needed(req.prompt_len + 1)
            if self.bm.num_allocatable - need < watermark:
                break
            self.waiting.popleft()
            seq = Sequence(request=req)
            self.bm.allocate(self._seq_key(seq), req.prompt_len + 1)
            seq.prefilled = req.prompt_len  # engine prefills it whole
            self.running.append(seq)
            admitted.append(seq)
        return admitted

    # ------------------------------------------------------------------
    def schedule_chunks(self) -> ScheduledBatch:
        """Build one hybrid step under the per-step token budget.

        Invariants (regression-tested):
          * total tokens per step are budgeted Sarathi-style: emitted chunk
            tokens never exceed ``chunk_tokens`` minus one slot per
            decode-ready sequence (the decode tokens ride the same fused
            forward), floored at ``min_chunk_tokens`` so decode-heavy
            batches cannot crowd out chunk progress entirely;
          * running sequences mid-prefill are served before new admissions
            (no starvation by decode-only steps);
          * block reservation happens here, per chunk — a preempted
            half-prefilled sequence releases exactly what it reserved.
        """
        assert self.chunk_tokens is not None, "scheduler is monolithic"
        n_decode = sum(1 for s in self.running
                       if s.prompt_remaining == 0 and not s.done)
        budget = max(self.chunk_tokens - n_decode,
                     min(self.min_chunk_tokens, self.chunk_tokens))
        batch = ScheduledBatch()
        watermark = int(self.bm.total_blocks * self.watermark_frac)

        # 1. continue partially prefilled running sequences, FIFO
        for s in list(self.running):
            if budget <= 0:
                break
            rem = s.prompt_remaining
            if rem <= 0:
                continue
            n = min(rem, budget)
            if not self._reserve_chunk(s, n):
                continue  # s was preempted back to the waiting queue
            batch.prefill_chunks.append((s, n))
            budget -= n

        # 2. admit new requests into the remaining budget (earliest-SLO
        #    first under prefill_order="slo", FIFO otherwise; admission
        #    stops at the first request that cannot be served so a blocked
        #    head is never overtaken into starvation)
        while (budget > 0 and self.waiting
               and len(self.running) < self.max_batch):
            req = self._peek_waiting()
            shared: List[int] = []
            cached = 0
            if self.bm.prefix_caching and req.prompt_tokens is not None:
                # may include host-tier restores: blocks re-registered from
                # the HostKVStore count as cached here, their physical
                # host→device copy drains before the step's writes
                shared, matched = self.bm.match_prefix(req.prompt_tokens)
                # at least one prompt position must be recomputed so the
                # step produces logits for the first output token
                cached = min(matched, max(req.prompt_len - 1, 0))
            n = min(req.prompt_len - cached, budget)
            # blocks this admission may consume: table growth past the
            # shared prefix plus worst-case CoW forks of shared blocks the
            # first chunk writes into (the fully-cached-prompt recompute)
            need = max(self.bm.blocks_needed(cached + n) - len(shared), 0) \
                + self.bm.shared_blocks_in_range(shared, cached, cached + n)
            if self.bm.num_allocatable - need < watermark:
                break
            self.waiting.remove(req)
            seq = Sequence(request=req)
            key = self._seq_key(seq)
            try:
                if shared:
                    self.bm.share(key, shared, cached)
                    seq.cached_tokens = cached
                    seq.prefilled = cached
                    self.bm.fork_for_write(key, cached, cached + n)
                    self.bm.grow_to(key, cached + n)
                else:
                    self.bm.allocate(key, n)
            except OutOfBlocks:
                # the conservative `need` estimate can still lose a race
                # against same-step growth: roll back and retry next step
                self.bm.release(key)
                self.waiting.appendleft(req)
                break
            self.running.append(seq)
            batch.admitted.append(seq)
            batch.prefill_chunks.append((seq, n))
            budget -= n

        # chunks whose sequence was preempted later in this same pass are
        # void — drop them by object identity (the same request may have
        # been re-admitted above as a fresh Sequence under the same key)
        alive = {id(s) for s in self.running}
        batch.prefill_chunks = [(s, n) for s, n in batch.prefill_chunks
                                if id(s) in alive]
        batch.admitted = [s for s in batch.admitted if id(s) in alive]
        batch.decode = [s for s in self.running
                        if s.prompt_remaining == 0 and not s.done]
        return batch

    def _peek_waiting(self) -> Request:
        """Next admission candidate: FIFO head, or — under
        ``prefill_order="slo"`` — highest priority class first, then the
        earliest TTFT deadline (arrival + slo; deadline-free requests
        sort last, FIFO among equals).  A single-class queue orders
        exactly as before classes existed."""
        if self.prefill_order == "fifo" or len(self.waiting) <= 1:
            return self.waiting[0]
        return min(self.waiting,
                   key=lambda r: (class_rank(r.priority),
                                  r.arrival + r.slo if r.slo is not None
                                  else float("inf"), r.arrival, r.req_id))

    def _reserve_chunk(self, seq: Sequence, n: int) -> bool:
        """Reserve KV blocks for the next ``n`` prompt tokens of ``seq``;
        on exhaustion evict the youngest other sequence, then ``seq``
        itself (recompute policy, same as the decode commit path).  Any
        shared block the chunk writes into is privatised first (CoW)."""
        key = self._seq_key(seq)
        if key not in self.bm.tables:
            return False
        target = seq.prefilled + n
        try:
            self.bm.fork_for_write(key, seq.prefilled, target)
            self.bm.grow_to(key, target)
            return True
        except OutOfBlocks:
            self._preempt_youngest(exclude=seq)
            try:
                self.bm.fork_for_write(key, seq.prefilled, target)
                self.bm.grow_to(key, target)
                return True
            except OutOfBlocks:
                self._preempt(seq)
                return False

    def _seq_key(self, seq: Sequence) -> int:
        return seq.req_id

    def note_prefill_progress(self, seq: Sequence, *, draft_ok: bool) -> None:
        """Publish freshly materialised full prompt blocks in the prefix
        cache.  Only draft-covered prefixes register: a cached block must be
        valid in BOTH paged pools so a sharing sequence can speculate
        without a draft catch-up write into shared blocks."""
        if draft_ok and self.bm.prefix_caching:
            self.bm.register_prefix(self._seq_key(seq),
                                    seq.request.prompt_tokens, seq.prefilled)

    # ------------------------------------------------------------------
    def commit_tokens(self, seq: Sequence, n: int) -> bool:
        """Record n committed tokens; returns False if the sequence had to be
        preempted (blocks exhausted)."""
        key = self._seq_key(seq)
        if key not in self.bm.tables:
            return False  # already preempted this step
        end = self.bm.lengths[key] + n
        try:
            self.bm.fork_for_write(key, self.bm.lengths[key], end)
            self.bm.append_tokens(key, n)
            seq.generated += n
            return True
        except OutOfBlocks:
            self._preempt_youngest(exclude=seq)
            try:
                self.bm.fork_for_write(key, self.bm.lengths[key], end)
                self.bm.append_tokens(key, n)
                seq.generated += n
                return True
            except OutOfBlocks:
                self._preempt(seq)
                return False

    @staticmethod
    def _age_key(seq: Sequence) -> Tuple[int, float, int]:
        """Strict total preemption order: lowest priority class first,
        age-ordered within a class.  A lower-class sequence is 'younger'
        than every higher-class one regardless of arrival, so interactive
        work can displace older best_effort work but never vice versa;
        a uniform-class batch reduces to the original (arrival, req_id)
        order, preserving the anti-livelock guarantee."""
        return (class_rank(seq.request.priority),
                seq.request.arrival, seq.req_id)

    def _preempt_youngest(self, exclude: Optional[Sequence] = None) -> None:
        """Evict the youngest running sequence to free blocks — but only if
        it is younger than the sequence asking (strict class-then-age
        priority).  A young sequence may never displace older work:
        without this guard two prompts that cannot coexist in the pool
        evict each other in an endless recompute ping-pong (each restart
        re-evicts the other's blocks), and neither ever finishes.  With
        it, the younger of the two preempts itself and waits for the
        elder to complete."""
        candidates = [s for s in self.running if s is not exclude]
        if exclude is not None:
            key = self._age_key(exclude)
            candidates = [s for s in candidates if self._age_key(s) > key]
        if not candidates:
            return
        victim = max(candidates, key=self._age_key)
        self._preempt(victim)

    def preempt(self, seq: Sequence) -> None:
        """Public preempt-and-recompute: the engine preempts sequences whose
        physical KV reservation failed (paged real backend) before the step
        executes, so no write can touch another sequence's blocks."""
        self._preempt(seq)

    def _preempt(self, seq: Sequence) -> None:
        """Recompute policy: release blocks, requeue at the front.  A
        half-prefilled sequence restarts from scratch — the fresh Sequence
        built at re-admission has ``prefilled == generated == 0``."""
        self.bm.release(self._seq_key(seq))
        if seq in self.running:
            self.running.remove(seq)
        req = seq.request
        # recompute from scratch: prompt + already-generated tokens count
        self.waiting.appendleft(req)
        tr = self.trace
        if tr is not None and tr.enabled and self.trace_ctx is not None:
            t, rep = self.trace_ctx()
            tr.instant("engine", "preempt", t, replica=rep,
                       args={"req": seq.req_id, "prefilled": seq.prefilled,
                             "generated": seq.generated})
            tr.req_stage(seq.req_id, t, "stall", rep)

    def finish(self, seq: Sequence) -> None:
        self.bm.release(self._seq_key(seq))
        if seq in self.running:
            self.running.remove(seq)
