"""Request routers for the multi-replica serving cluster.

A :class:`Router` maps each arriving :class:`~repro.serving.request.Request`
to one replica (:class:`~repro.serving.engine.ServingEngine`).  Routing is a
pure function of the request and the replicas' *observable* state at dispatch
time — queue depths and KV-block headroom — never of simulator internals, so
the same policies transfer to the real-execution tier unchanged.

Policies:
  * ``RoundRobinRouter``   — cycle through replicas; the static baseline.
  * ``JoinShortestQueue``  — send to the replica with the fewest unfinished
    requests (pending + waiting + running); the classic JSQ policy used by
    SLO-aware SD serving systems (SpecServe, AdaSD).
  * ``KVHeadroomRouter``   — send to the replica with the most free paged-KV
    blocks, tie-broken by queue length.  Because Nightjar's planner reacts to
    memory pressure (speculation off, draft offload), balancing *headroom*
    rather than queue depth keeps more replicas inside the speculation-
    friendly regime at moderate load.

All policies are deterministic (ties broken by replica index) so cluster
runs are exactly reproducible.

Construct by name with :func:`make_router` ("rr" | "jsq" | "kv").
"""
from __future__ import annotations

from typing import List, Sequence

from .engine import ServingEngine
from .request import Request


class Router:
    """Base class: pick the replica index that receives ``req``."""

    name = "router"

    def route(self, req: Request, replicas: Sequence[ServingEngine]) -> int:
        raise NotImplementedError


class RoundRobinRouter(Router):
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, req: Request, replicas: Sequence[ServingEngine]) -> int:
        idx = self._next % len(replicas)
        self._next += 1
        return idx


class JoinShortestQueue(Router):
    name = "jsq"

    def route(self, req: Request, replicas: Sequence[ServingEngine]) -> int:
        return min(range(len(replicas)),
                   key=lambda i: (replicas[i].load, i))


class KVHeadroomRouter(Router):
    name = "kv-headroom"

    def route(self, req: Request, replicas: Sequence[ServingEngine]) -> int:
        def key(i: int):
            bm = replicas[i].scheduler.bm
            # most allocatable blocks first (free + cached-reusable prefix
            # blocks, which evict on demand), then shortest queue, then index
            return (-bm.num_allocatable, replicas[i].load, i)
        return min(range(len(replicas)), key=key)


_ROUTERS = {
    "rr": RoundRobinRouter,
    "round-robin": RoundRobinRouter,
    "jsq": JoinShortestQueue,
    "kv": KVHeadroomRouter,
    "kv-headroom": KVHeadroomRouter,
}


def make_router(name: str) -> Router:
    try:
        return _ROUTERS[name]()
    except KeyError:
        raise KeyError(f"unknown router {name!r}; one of {sorted(_ROUTERS)}")
