"""Request routers for the multi-replica serving cluster.

A :class:`Router` maps each arriving :class:`~repro.serving.request.Request`
to one replica (:class:`~repro.serving.engine.ServingEngine`).  Routing is a
pure function of the request, the dispatch instant ``now`` and the replicas'
*observable* state — queue depths, KV-block headroom and the control plane's
online telemetry — never of simulator internals, so the same policies
transfer to the real-execution tier unchanged.

Policies:
  * ``RoundRobinRouter``   — cycle through replicas; the static baseline.
  * ``JoinShortestQueue``  — send to the replica with the fewest unfinished
    requests (pending + waiting + running); the classic JSQ policy used by
    SLO-aware SD serving systems (SpecServe, AdaSD).
  * ``KVHeadroomRouter``   — send to the replica with the most free paged-KV
    blocks, tie-broken by queue length.  Because Nightjar's planner reacts to
    memory pressure (speculation off, draft offload), balancing *headroom*
    rather than queue depth keeps more replicas inside the speculation-
    friendly regime at moderate load.
  * ``SLOAwareRouter``     — send to the replica with the largest predicted
    TTFT *deadline headroom* (``slo - forecast``), using the control plane's
    roofline queue-delay forecast corrected by the learned residual bias.
    Equivalently: minimise predicted TTFT, which is what the deadline cares
    about — queue depth and KV headroom are only proxies for it.
  * ``PrefixAffinityRouter`` — sticky-route on a *stable* template/prefix
    content hash (serving/controlplane.py ``template_key``; the seeded blake2b
    chain over token ids, never Python's salted ``hash()``) so each
    replica's prefix cache specialises on its own templates instead of every
    replica re-caching every template.  Load-aware spillover: when the home
    replica's predicted-TTFT headroom is exhausted the request overflows to
    the best other replica, but the home mapping survives so the flow
    returns once pressure clears.

All policies are deterministic (ties broken by replica index) so cluster
runs are exactly reproducible.

The cluster passes the *routable* replica subset (draining/retired replicas
excluded) — the returned index is a position in that subset.

Construct by name with :func:`make_router`
("rr" | "jsq" | "kv" | "slo" | "affinity").
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .controlplane import ControlPlane, template_key
from .engine import ServingEngine
from .request import Request


class Router:
    """Base class: pick the replica index that receives ``req``.

    ``control`` is bound by the owning ``ServingCluster`` so headroom-based
    policies share the cluster's telemetry; load-only policies ignore it.
    """

    name = "router"
    control: Optional[ControlPlane] = None

    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        raise NotImplementedError

    def note_replica_dead(self, replica_id: int) -> None:
        """Liveness notification from the cluster: ``replica_id`` has been
        drained or retired and must never be *chosen* again.  Stateless
        routers need no bookkeeping (the cluster already excludes dead
        replicas from the routable set); stateful routers that remember
        replica ids across dispatches (sticky affinity homes) must purge
        them here — a stale id silently re-routes traffic to a corpse."""


class RoundRobinRouter(Router):
    name = "round-robin"

    def __init__(self):
        self._next = 0

    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        idx = self._next % len(replicas)
        self._next += 1
        return idx


class JoinShortestQueue(Router):
    name = "jsq"

    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        return min(range(len(replicas)),
                   key=lambda i: (replicas[i].load, i))


class KVHeadroomRouter(Router):
    name = "kv-headroom"

    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        def key(i: int):
            bm = replicas[i].scheduler.bm
            # most allocatable blocks first (free + cached-reusable prefix
            # blocks, which evict on demand), then shortest queue, then index
            return (-bm.num_allocatable, replicas[i].load, i)
        return min(range(len(replicas)), key=key)


class SLOAwareRouter(Router):
    """Dispatch on predicted-TTFT deadline headroom.

    For each replica the control plane forecasts the TTFT this request
    would see there; the replica with the largest ``slo - forecast``
    headroom wins (= smallest forecast, since the deadline is the
    request's own).  Ties break on load then index.  Without a bound
    control plane it degrades to JSQ."""

    name = "slo"

    def __init__(self, control: Optional[ControlPlane] = None):
        self.control = control

    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        if self.control is None:
            return min(range(len(replicas)),
                       key=lambda i: (replicas[i].load, i))
        return min(range(len(replicas)),
                   key=lambda i: (self.control.forecast_ttft(
                       replicas[i], req, now), replicas[i].load, i))


class PrefixAffinityRouter(Router):
    """Sticky template routing with load-aware spillover.

    The first request of a template picks its *home* replica by best
    predicted headroom (KV headroom without a control plane); subsequent
    requests with the same stable template hash return home — so the
    template's prefix blocks are cached on exactly one replica and every
    follower shares them — unless the home replica's predicted TTFT has
    blown past ``spill_slack``x the request's deadline, in which case the
    request overflows to the best other replica for this dispatch only
    (the home mapping is kept: the flow snaps back once pressure clears).
    Requests with no token ids fall through to best-headroom dispatch."""

    name = "affinity"

    def __init__(self, control: Optional[ControlPlane] = None, *,
                 window_tokens: int = 64, spill_slack: float = 2.0,
                 default_slo: Optional[float] = None):
        self.control = control
        self.window_tokens = window_tokens
        self.spill_slack = spill_slack
        self.default_slo = default_slo
        self.home: Dict[int, int] = {}       # template hash -> replica_id
        self.dead: set = set()               # drained/retired replica ids
        self.spills = 0
        self.rehomes = 0                     # templates moved off a dead home

    def note_replica_dead(self, replica_id: int) -> None:
        """Purge the sticky home map: every template homed on the drained
        replica re-homes (stickily) at its next dispatch.  Without this the
        map keeps pointing at the corpse — any caller that hands ``route``
        a replica set still containing it (an external dispatcher, or the
        cluster's fully-drained fallback tier) gets traffic routed to a
        DRAINING/RETIRED replica, and hit-rate craters because followers
        chase a cache that will never be served again."""
        self.dead.add(replica_id)
        stale = [k for k, rid in self.home.items() if rid == replica_id]
        for k in stale:
            del self.home[k]
        self.rehomes += len(stale)

    # -- pieces ---------------------------------------------------------
    def _best(self, req: Request, replicas: Sequence[ServingEngine],
              now: float) -> int:
        """Best replica for a non-sticky dispatch (position in subset)."""
        if self.control is not None:
            return min(range(len(replicas)),
                       key=lambda i: (self.control.forecast_ttft(
                           replicas[i], req, now), replicas[i].load, i))
        return min(range(len(replicas)),
                   key=lambda i: (-replicas[i].scheduler.bm.num_allocatable,
                                  replicas[i].load, i))

    def _overloaded(self, eng: ServingEngine, req: Request,
                    now: float) -> bool:
        slo = req.slo if req.slo is not None else self.default_slo
        if self.control is None or slo is None:
            return False
        return self.control.forecast_ttft(eng, req, now) \
            > slo * self.spill_slack

    # -- routing --------------------------------------------------------
    def route(self, req: Request, replicas: Sequence[ServingEngine],
              now: float = 0.0) -> int:
        # liveness first: a replica the cluster declared dead may only be
        # used when the caller's whole set is dead (nothing else to serve
        # on) — never stuck-to, never elected as a home
        live = [i for i, e in enumerate(replicas)
                if e.replica_id not in self.dead]
        if not live:
            live = list(range(len(replicas)))
        key = template_key(req.prompt_tokens, self.window_tokens)
        if key is None:
            best = self._best(req, [replicas[i] for i in live], now)
            return live[best]
        by_id = {replicas[i].replica_id: i for i in live}
        home = self.home.get(key)
        if home in by_id:
            pos = by_id[home]
            if not self._overloaded(replicas[pos], req, now):
                return pos
            # spillover: overflow this dispatch, keep the home mapping
            self.spills += 1
            if len(live) == 1:
                return pos
            others = [i for i in live if i != pos]
            best = self._best(req, [replicas[i] for i in others], now)
            return others[best]
        # first sight of this template (or its home drained/retired):
        # elect a new LIVE home by best current headroom — the new
        # mapping is sticky exactly like the first one was
        best = self._best(req, [replicas[i] for i in live], now)
        pos = live[best]
        self.home[key] = replicas[pos].replica_id
        return pos


_ROUTERS = {
    "rr": RoundRobinRouter,
    "round-robin": RoundRobinRouter,
    "jsq": JoinShortestQueue,
    "kv": KVHeadroomRouter,
    "kv-headroom": KVHeadroomRouter,
    "slo": SLOAwareRouter,
    "affinity": PrefixAffinityRouter,
}


def make_router(name: str, **kwargs) -> Router:
    try:
        cls = _ROUTERS[name]
    except KeyError:
        raise KeyError(f"unknown router {name!r}; one of {sorted(_ROUTERS)}")
    return cls(**kwargs)
