"""Multi-replica serving cluster on a shared virtual event clock.

``ServingCluster`` owns N independent :class:`ServingEngine` replicas — each
with its own :class:`ContinuousBatchingScheduler`, MAB planner and
:class:`ElasticMemoryManager` — plus one :class:`Router` that dispatches a
single global arrival stream across them.  This is the fleet tier the paper
motivates ("dynamic request rates from millions of users"): per-replica
planners adapt their speculative length *independently* to the load each
replica actually sees.

Event-clock semantics
---------------------
Every engine advances its own virtual clock as it executes steps; the
cluster interleaves them with a classic discrete-event loop:

  1. the next *engine* event is ``min over replicas of peek_next_event()``;
  2. the next *arrival* event is the head of the global request stream;
  3. whichever is earlier happens: an arrival is admitted (or shed), routed
     (based on replica state observed *now*) and submitted, or the
     earliest-clock replica executes one ``step()``.

Because a replica is only stepped when it holds the minimum clock, replica
timelines interleave correctly in virtual time, and routing decisions see
queue/KV state no newer than the arrival instant — the same information a
real front-end would have.

Control plane (serving/controlplane.py)
---------------------------------------
Every cluster owns a :class:`ControlPlane` (telemetry-only by default).
After each replica step the plane consumes the replica's freshly finished
request stats (the EWMA TTFT/TPOT predictors and the forecast-residual
bias); at each arrival the cluster consults, in order:

  * the **autoscaler** — may ``add_replica`` (a fresh engine joins at the
    current virtual time) or ``drain_replica`` (the least-loaded replica
    stops receiving traffic, finishes its running work, then retires);
  * the **admission controller** — may *shed* the arrival at the door when
    even the best replica's predicted TTFT is hopeless (recorded in
    ``ClusterMetrics.shed``, never as an SLO miss of admitted traffic);
  * the **router** — dispatches over the routable (non-draining) replicas.

Determinism: engines, router tie-breaks, telemetry, controllers and
workload generation are all seeded/deterministic, so a cluster run is
exactly reproducible — two runs of the same config produce byte-identical
routing decisions (golden-value tested in tests/test_cluster.py and
tests/test_controlplane.py).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from .controlplane import (BrownoutController, ControlPlane,
                           DecodePoolAutoscaler, HandoffPricer)
from .engine import ServingEngine
from .faults import FaultInjector, RetryPolicy
from .request import (Metrics, Request, RequestStats, goodput_of, percentile,
                      slo_attainment_of)
from .router import Router

# replica lifecycle states.  FAILED is distinct from DRAINING: a draining
# replica finishes the work it owns; a failed replica's in-flight work is
# LOST (its blocks are gone) and must be re-dispatched elsewhere.
ACTIVE, DRAINING, RETIRED, FAILED = "active", "draining", "retired", "failed"

# replica roles (disaggregated mode; COLOCATED is the classic do-everything
# replica of a non-disaggregated cluster)
PREFILL, DECODE, COLOCATED = "prefill", "decode", "colocated"


@dataclass
class ClusterMetrics:
    """Aggregate + per-replica metrics for one cluster run."""

    per_replica: List[Metrics]
    elapsed: float = 0.0              # virtual makespan across replicas
    assignments: Dict[int, int] = field(default_factory=dict)  # req -> replica
    shed: List[dict] = field(default_factory=list)   # rejected at the door
    autoscale_events: List[dict] = field(default_factory=list)
    replica_states: List[str] = field(default_factory=list)
    replica_spans: List[tuple] = field(default_factory=list)  # (start, end)
    replica_roles: List[str] = field(default_factory=list)
    handoffs: List[dict] = field(default_factory=list)  # prefill->decode
    handoffs_declined: int = 0        # pricer chose colocated fallback
    handoff_transfer_s: float = 0.0   # total modelled interconnect time
    handoff_fallbacks: int = 0        # adoptions that re-prefilled locally
    handoff_failures: int = 0         # injected transfer failures
    handoff_timeouts: int = 0         # injected transfer timeouts
    handoff_retries: int = 0          # transfer retries after a fault
    handoff_aborts: int = 0           # retry budget exhausted -> colocated
    # fault tolerance (serving/faults.py): one dict per replica crash with
    # at/replica/lost/detected_at/recovered_at stamps
    crashes: List[dict] = field(default_factory=list)
    requeues: int = 0                 # crashed requests re-submitted
    retries: int = 0                  # retry attempts scheduled
    failed_requests: List[dict] = field(default_factory=list)  # budget spent
    # overload lifecycle (brownout ladder + request cancellation)
    brownout_events: List[dict] = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        return sum(m.total_tokens for m in self.per_replica)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def latencies(self) -> List[float]:
        return [x for m in self.per_replica for x in m.latencies]

    @property
    def ttfts(self) -> List[float]:
        return [x for m in self.per_replica for x in m.ttfts]

    @property
    def mean_latency(self) -> float:
        lat = self.latencies
        return sum(lat) / len(lat) if lat else 0.0

    @property
    def mean_ttft(self) -> float:
        t = self.ttfts
        return sum(t) / len(t) if t else 0.0

    @property
    def requests(self) -> List[RequestStats]:
        return [r for m in self.per_replica for r in m.requests]

    def ttft_percentile(self, q: float) -> float:
        reqs = self.requests
        return percentile([r.ttft for r in reqs] or self.ttfts, q)

    def tpot_percentile(self, q: float) -> float:
        return percentile([r.tpot for r in self.requests], q)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(0.99)

    @property
    def slo_attainment(self) -> float:
        """Attainment of ADMITTED deadline-carrying traffic (shed requests
        are accounted separately — see ``slo_attainment_offered``)."""
        return slo_attainment_of(self.requests)

    @property
    def offered_slo_count(self) -> int:
        """Deadline-carrying requests in the offered load: finished ones
        plus shed ones — the sample count behind
        ``slo_attainment_offered`` (renderer gate)."""
        return (sum(1 for r in self.requests if r.slo is not None)
                + sum(1 for s in self.shed if s.get("slo") is not None))

    @property
    def slo_attainment_offered(self) -> Optional[float]:
        """Attainment over the OFFERED load: shed deadline-carrying
        requests count as misses (the honest fleet-level number).

        ``None`` when the offered load carries no deadline samples at all
        (e.g. every request shed before any deadline-carrying one
        finished) — n/a by contract, never a fake-perfect ratio
        (tests/test_metrics_edges.py convention)."""
        with_slo = [r for r in self.requests if r.slo is not None]
        shed_slo = sum(1 for s in self.shed if s.get("slo") is not None)
        total = len(with_slo) + shed_slo
        if total == 0:
            return None
        return sum(r.slo_met for r in with_slo) / total

    @property
    def shed_count(self) -> int:
        return len(self.shed)

    @property
    def cancelled(self) -> List[dict]:
        """Client-cancelled requests across the fleet."""
        return [c for m in self.per_replica for c in m.cancelled]

    @property
    def expired(self) -> List[dict]:
        """Deadline-reaped requests across the fleet."""
        return [e for m in self.per_replica for e in m.expired]

    def class_summary(self) -> Dict[str, dict]:
        """Per-priority-class lifecycle accounting: every offered request
        lands in exactly one terminal bucket (finished / shed / cancelled /
        expired / failed), plus per-class TTFT-SLO attainment of finished
        traffic (None when the class carries no deadline samples — n/a by
        contract, never a fake-perfect ratio)."""
        classes: Dict[str, dict] = {}

        def bucket(cls: str) -> dict:
            return classes.setdefault(cls, {
                "finished": 0, "shed": 0, "cancelled": 0, "expired": 0,
                "failed": 0, "offered": 0,
                "slo_samples": 0, "slo_met": 0})

        for r in self.requests:
            b = bucket(r.priority)
            b["finished"] += 1
            if r.slo is not None:
                b["slo_samples"] += 1
                b["slo_met"] += int(r.slo_met)
        for s in self.shed:
            bucket(s.get("priority", "interactive"))["shed"] += 1
        for c in self.cancelled:
            bucket(c.get("priority", "interactive"))["cancelled"] += 1
        for e in self.expired:
            bucket(e.get("priority", "interactive"))["expired"] += 1
        for f in self.failed_requests:
            bucket(f.get("priority", "interactive"))["failed"] += 1
        for b in classes.values():
            b["offered"] = (b["finished"] + b["shed"] + b["cancelled"]
                            + b["expired"] + b["failed"])
            b["slo_attainment"] = (round(b["slo_met"] / b["slo_samples"], 4)
                                   if b["slo_samples"] else None)
        return classes

    @property
    def goodput(self) -> float:
        """Fleet tokens/s from requests that met their TTFT SLO."""
        return goodput_of(self.requests, self.elapsed, self.throughput)

    @property
    def prefix_hit_rate(self) -> float:
        """Aggregate prefix-cache hit rate across the fleet."""
        q = sum(m.prefix.get("queries", 0) for m in self.per_replica)
        h = sum(m.prefix.get("hits", 0) for m in self.per_replica)
        return h / q if q else 0.0

    @property
    def mttd(self) -> Optional[float]:
        """Mean time-to-detect across crashes (crash -> detector firing).
        ``None`` when no crash was detected — n/a by contract, never a
        fake-free 0.0 (tests/test_metrics_edges.py convention)."""
        ds = [c["detected_at"] - c["at"] for c in self.crashes
              if c.get("detected_at") is not None]
        return sum(ds) / len(ds) if ds else None

    @property
    def mttr(self) -> Optional[float]:
        """Mean time-to-recover across crashes (crash -> last lost request
        re-dispatched).  ``None`` when no crash completed recovery."""
        rs = [c["recovered_at"] - c["at"] for c in self.crashes
              if c.get("recovered_at") is not None]
        return sum(rs) / len(rs) if rs else None

    @property
    def recovery_seconds(self) -> Optional[float]:
        """Total virtual seconds spent in crash recovery windows; ``None``
        when no crash recovered (n/a, not free)."""
        rs = [c["recovered_at"] - c["at"] for c in self.crashes
              if c.get("recovered_at") is not None]
        return sum(rs) if rs else None

    @property
    def peak_replicas(self) -> int:
        """Most replicas simultaneously non-retired at any arrival/step."""
        if not self.replica_spans:
            return len(self.per_replica)
        events = []
        for start, end in self.replica_spans:
            events.append((start, 1))
            events.append((end, -1))
        peak = cur = 0
        for _, d in sorted(events, key=lambda e: (e[0], -e[1])):
            cur += d
            peak = max(peak, cur)
        return peak

    @property
    def replica_seconds(self) -> float:
        """Total replica-occupancy (virtual seconds summed over replicas)
        — the capacity cost an autoscaled fleet actually paid."""
        return sum(max(end - start, 0.0)
                   for start, end in self.replica_spans)

    def replica_counts(self) -> List[int]:
        """Requests routed to each replica."""
        n = len(self.per_replica)
        counts = [0] * n
        for idx in self.assignments.values():
            counts[idx] += 1
        return counts

    def per_replica_summary(self) -> List[dict]:
        """Per-replica breakdown: the control-plane observability surface."""
        counts = self.replica_counts()
        out = []
        for i, m in enumerate(self.per_replica):
            # a replica that completed zero requests (retired mid-drain,
            # or every request it saw was shed upstream) has NO latency
            # samples: percentile() would report a fake-perfect 0.0 and
            # slo_attainment a fake-perfect 1.0.  n/a by contract instead
            # (tests/test_metrics_edges.py) — `finished` is the gate.
            n = len(m.requests)
            row = {
                "replica": i,
                "state": (self.replica_states[i]
                          if i < len(self.replica_states) else ACTIVE),
                "role": (self.replica_roles[i]
                         if i < len(self.replica_roles) else COLOCATED),
                "requests": counts[i],
                "finished": n,
                "tok_s": round(m.throughput, 2),
                "p99_ttft_s": round(m.ttft_percentile(0.99), 4) if n else None,
                "slo_attainment": round(m.slo_attainment, 4) if n else None,
                "offloads": m.offload_events,
            }
            if m.prefix:
                row["prefix_hit_rate"] = round(m.prefix_hit_rate, 4)
            out.append(row)
        return out

    def spec_summary(self) -> dict:
        """Fleet-wide speculation aggregates: fold every replica's raw
        per-gamma counters into one Metrics and reuse its formatting, so
        cluster and single-engine summaries agree by construction."""
        merged = Metrics()
        for m in self.per_replica:
            sp = m.spec
            if not sp:
                continue
            ms = merged.spec
            if not ms:
                ms.update(steps=0, spec_steps=0, forced_off_steps=0,
                          restarts=0, per_gamma={})
            for k in ("steps", "spec_steps", "forced_off_steps", "restarts"):
                ms[k] += sp.get(k, 0)
            for gamma, g in sp.get("per_gamma", {}).items():
                t = ms["per_gamma"].setdefault(
                    gamma, {"steps": 0, "proposed": 0, "accepted": 0,
                            "committed": 0, "latency_s": 0.0})
                for k in t:
                    t[k] += g[k]
        return merged.spec_summary()

    def summary(self) -> dict:
        out = {
            "replicas": len(self.per_replica),
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "p50_ttft_s": round(self.ttft_percentile(0.50), 4),
            "p95_ttft_s": round(self.ttft_percentile(0.95), 4),
            "p99_ttft_s": round(self.ttft_percentile(0.99), 4),
            "p50_tpot_s": round(self.tpot_percentile(0.50), 5),
            "p99_tpot_s": round(self.tpot_percentile(0.99), 5),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_tok_s": round(self.goodput, 2),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "per_replica_tok_s": [round(m.throughput, 2)
                                  for m in self.per_replica],
            "per_replica_requests": self.replica_counts(),
            "per_replica": self.per_replica_summary(),
            "switches": sum(m.switch_count for m in self.per_replica),
            "offloads": sum(m.offload_events for m in self.per_replica),
            "reloads": sum(m.reload_events for m in self.per_replica),
            "blocks_allocated": sum(m.blocks_allocated
                                    for m in self.per_replica),
        }
        if self.shed or self.autoscale_events:
            out["shed_count"] = self.shed_count
            offered = self.slo_attainment_offered
            out["offered_slo_count"] = self.offered_slo_count
            out["slo_attainment_offered"] = (
                round(offered, 4) if offered is not None else None)
        if self.autoscale_events:
            out["peak_replicas"] = self.peak_replicas
            out["replica_seconds"] = round(self.replica_seconds, 3)
            out["autoscale"] = {
                "adds": sum(1 for e in self.autoscale_events
                            if e["kind"] == "add"),
                "drains": sum(1 for e in self.autoscale_events
                              if e["kind"] == "drain"),
                "retires": sum(1 for e in self.autoscale_events
                               if e["kind"] == "retire"),
            }
        if (self.handoffs or self.handoffs_declined
                or self.handoff_failures or self.handoff_timeouts
                or self.handoff_aborts):
            out["disagg"] = {
                "handoffs": len(self.handoffs),
                "declined": self.handoffs_declined,
                "transfer_s": round(self.handoff_transfer_s, 4),
                "adopt_fallbacks": self.handoff_fallbacks,
            }
            if (self.handoff_failures or self.handoff_timeouts
                    or self.handoff_aborts):
                out["disagg"].update({
                    "transfer_failures": self.handoff_failures,
                    "transfer_timeouts": self.handoff_timeouts,
                    "transfer_retries": self.handoff_retries,
                    "transfer_aborts": self.handoff_aborts,
                })
        if self.crashes or self.requeues or self.failed_requests:
            mttd, mttr = self.mttd, self.mttr
            out["faults"] = {
                "crashes": len(self.crashes),
                "requests_lost": sum(c["lost"] for c in self.crashes),
                "requeues": self.requeues,
                "retries": self.retries,
                "failed_requests": len(self.failed_requests),
                "mttd_s": round(mttd, 4) if mttd is not None else None,
                "mttr_s": round(mttr, 4) if mttr is not None else None,
            }
        cancelled, expired = self.cancelled, self.expired
        multi_class = len({r.priority for r in self.requests}
                          | {s.get("priority", "interactive")
                             for s in self.shed}) > 1
        if cancelled or expired or self.brownout_events or multi_class:
            out["cancelled"] = len(cancelled)
            out["expired"] = len(expired)
            out["per_class"] = self.class_summary()
        if self.brownout_events:
            out["brownout"] = {
                "transitions": len(self.brownout_events),
                "max_stage": max(e["stage"] for e in self.brownout_events),
                "stages_entered": sorted({e["to"]
                                          for e in self.brownout_events}),
            }
        if any(m.spec for m in self.per_replica):
            out["spec"] = self.spec_summary()
        if any(m.prefix for m in self.per_replica):
            out["prefix_saved_tokens"] = sum(
                m.prefix.get("saved_tokens", 0) for m in self.per_replica)
            out["prefix_hits"] = sum(
                m.prefix.get("hits", 0) for m in self.per_replica)
            out["prefix_hit_rate"] = round(self.prefix_hit_rate, 4)
        return out


class ServingCluster:
    def __init__(self, replicas: Sequence[ServingEngine], router: Router,
                 *, control: Optional[ControlPlane] = None,
                 replica_factory: Optional[
                     Callable[[int], ServingEngine]] = None,
                 roles: Optional[Sequence[str]] = None,
                 pricer: Optional[HandoffPricer] = None,
                 decode_autoscaler: Optional[DecodePoolAutoscaler] = None,
                 faults: Optional[FaultInjector] = None,
                 retry_policy: Optional[RetryPolicy] = None,
                 handoff_max_retries: int = 2,
                 brownout: Optional[BrownoutController] = None,
                 cancels: Optional[Sequence[tuple]] = None):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = list(replicas)
        self.faults = faults
        # fleet brownout ladder + pre-scheduled client cancellations
        # ((t, req_id) pairs — e.g. workload.cancellation_storm); both None
        # by default, leaving the event order byte-identical to before
        self.brownout = brownout
        self.cancels = list(cancels) if cancels else []
        self.retry_policy = retry_policy if retry_policy is not None \
            else RetryPolicy()
        self.handoff_max_retries = handoff_max_retries
        for i, eng in enumerate(self.replicas):
            eng.replica_id = i
            eng.faults = faults
        self.router = router
        self.control = control if control is not None else ControlPlane()
        # headroom-based routers share the cluster's telemetry book
        if getattr(router, "control", None) is None:
            router.control = self.control
        self.replica_factory = replica_factory
        self.state: List[str] = [ACTIVE] * len(self.replicas)
        # disaggregated mode: arrivals land on the PREFILL pool; once a
        # request's prompt is fully materialised its KV blocks may migrate
        # to a DECODE replica (priced per-handoff by `pricer`).  roles=None
        # is the classic colocated cluster, unchanged.
        self.disaggregated = roles is not None
        if roles is not None:
            roles = list(roles)
            if len(roles) != len(self.replicas):
                raise ValueError("roles must match replicas")
            bad = set(roles) - {PREFILL, DECODE}
            if bad:
                raise ValueError(f"unknown roles {sorted(bad)}")
            if PREFILL not in roles:
                raise ValueError("disaggregated cluster needs >=1 prefill "
                                 "replica")
            self.roles: List[str] = roles
        else:
            self.roles = [COLOCATED] * len(self.replicas)
        self.pricer = pricer
        if self.disaggregated and self.pricer is None:
            self.pricer = HandoffPricer(self.control)
        self.decode_autoscaler = decode_autoscaler
        self.assignments: Dict[int, int] = {}
        self.shed: List[dict] = []
        self.autoscale_events: List[dict] = []
        self.handoffs: List[dict] = []
        self.handoff_transfer_s = 0.0
        self._handoff_considered: set = set()
        self._starts = [e.clock for e in self.replicas]
        self._retired_at: Dict[int, float] = {}
        self._record_timeline = False
        # observability seam: attach_trace wires one TraceRecorder through
        # every replica, the brownout controller and the fault injector
        self.trace = None
        # fault-tolerance state: timed control events (crash / corrupt /
        # detect / retry) interleave with engine steps and arrivals on the
        # shared virtual clock.  All empty without a fault plan, so the
        # fault-free path is byte-identical to pre-fault-layer behaviour.
        self._control_events: List[tuple] = []  # heap (t, seq, kind, payload)
        self._ctl_seq = 0
        self.crashes: List[dict] = []
        self.requeues = 0
        self.retries = 0
        self.failed_requests: List[dict] = []
        self.brownout_events: List[dict] = []
        self._attempts: Dict[int, int] = {}     # req_id -> retry attempts
        self.handoff_failures = 0
        self.handoff_timeouts = 0
        self.handoff_retries = 0
        self.handoff_aborts = 0

    # ------------------------------------------------------------------
    # observability seam
    # ------------------------------------------------------------------
    def attach_trace(self, trace) -> None:
        """Wire one :class:`observability.TraceRecorder` through the whole
        fleet: every replica (engine + scheduler + block manager), the
        brownout controller and the fault injector.  Replicas added later
        (autoscale, crash replacement) inherit it via ``add_replica``."""
        self.trace = trace
        for e in self.replicas:
            e.attach_trace(trace)
        if self.brownout is not None:
            self.brownout.trace = trace
        if self.faults is not None:
            self.faults.trace = trace

    def _tracer(self):
        tr = self.trace
        return tr if (tr is not None and tr.enabled) else None

    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.state if s == ACTIVE)

    def _pool(self, role: str, *, state: Optional[str] = None) -> List[int]:
        """Replica indices with ``role`` (optionally filtered by state)."""
        return [i for i in range(len(self.replicas))
                if self.roles[i] == role
                and (state is None or self.state[i] == state)]

    def routable_replicas(self) -> List[ServingEngine]:
        """Replicas the router may dispatch to: active only — draining
        replicas finish their assigned work but accept nothing new.

        Disaggregated mode scopes dispatch to the PREFILL pool (decode
        replicas receive work only through the KV-handoff path), falling
        back to the whole fleet if every prefill replica is gone.

        A fully drained fleet (the operator drained everything by hand)
        still has to land arrivals somewhere deterministic: fall back to
        the draining replicas, and past that to the whole fleet — a
        retired engine is just an idle engine wearing a control-plane
        label, and serving there beats crashing the router.  A FAILED
        replica is NEVER a candidate at any fallback tier: routing there
        would strand the request forever (a crashed engine never steps
        again)."""
        idxs = [i for i in range(len(self.replicas))
                if self.state[i] != FAILED]
        if self.disaggregated:
            pre = [i for i in self._pool(PREFILL) if self.state[i] != FAILED]
            cand = ([i for i in pre if self.state[i] == ACTIVE]
                    or [i for i in pre if self.state[i] != RETIRED])
            if cand:
                return [self.replicas[i] for i in cand]
            # no prefill replica left at all: serve colocated on whatever
            # remains rather than dropping the arrival
        out = [i for i in idxs if self.state[i] == ACTIVE]
        out = out or [i for i in idxs if self.state[i] != RETIRED]
        return [self.replicas[i] for i in (out or idxs)]

    # ------------------------------------------------------------------
    # elastic fleet surface
    # ------------------------------------------------------------------
    def add_replica(self, now: float, *, role: Optional[str] = None) -> int:
        """Bring a fresh replica online at virtual time ``now`` (its clock
        starts there — no retroactive work) and open it for routing.  In
        disaggregated mode ``role`` selects the pool it joins (default
        prefill — the pool classic autoscaling serves)."""
        if self.replica_factory is None:
            raise RuntimeError("cluster has no replica_factory")
        rid = len(self.replicas)
        eng = self.replica_factory(rid)
        eng.replica_id = rid
        eng.clock = max(eng.clock, now)
        eng.record_timeline = self._record_timeline
        if self._record_timeline:
            eng.metrics.use_timeline_ring()
        eng.faults = self.faults
        if self.trace is not None:
            eng.attach_trace(self.trace)
        # birth counts as a heartbeat: a replica that never steps must not
        # look crash-silent to the failure detector from t=0
        self.control.detector.heartbeat(rid, eng.clock)
        self.replicas.append(eng)
        self.state.append(ACTIVE)
        if role is None:
            role = PREFILL if self.disaggregated else COLOCATED
        self.roles.append(role)
        self._starts.append(eng.clock)
        self.autoscale_events.append(
            {"kind": "add", "at": now, "replica": rid, "role": role})
        tr = self._tracer()
        if tr is not None:
            tr.instant("fleet", "replica_add", now,
                       args={"replica": rid, "role": role})
        return rid

    def drain_replica(self, idx: int, now: float) -> None:
        """Stop routing to replica ``idx``; it finishes every request it
        already owns (pending + waiting + running) and then retires —
        draining never drops work."""
        if self.state[idx] != ACTIVE:
            return
        self.state[idx] = DRAINING
        # stateful routers (sticky affinity homes) must forget this replica
        # NOW: a stale home entry would keep steering its templates at a
        # replica that accepts no new traffic
        self.router.note_replica_dead(self.replicas[idx].replica_id)
        self.autoscale_events.append(
            {"kind": "drain", "at": now, "replica": idx})
        tr = self._tracer()
        if tr is not None:
            tr.instant("fleet", "replica_drain", now, args={"replica": idx})
        self._maybe_retire(idx, now)

    def _maybe_retire(self, idx: int, now: float) -> None:
        if self.state[idx] == DRAINING and not self.replicas[idx].has_work():
            # the request queues are empty but the host KV tier's transfer
            # queues may not be: flush them as part of the drain-to-retire
            # transition, otherwise pending spills/restores are silently
            # dropped and their pinned HostKVStore records leak forever
            # (invariant I6 must hold across drain)
            self.replicas[idx].flush_host_transfers()
            self.state[idx] = RETIRED
            self._retired_at[idx] = max(now, self.replicas[idx].clock)
            self.autoscale_events.append(
                {"kind": "retire", "at": self._retired_at[idx],
                 "replica": idx})
            tr = self._tracer()
            if tr is not None:
                tr.instant("fleet", "replica_retire", self._retired_at[idx],
                           args={"replica": idx})

    # ------------------------------------------------------------------
    # fault tolerance: crash / detect / retry control events
    # ------------------------------------------------------------------
    def _schedule_ctl(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._control_events,
                       (t, self._ctl_seq, kind, payload))
        self._ctl_seq += 1

    def _dispatch_ctl(self, t: float, kind: str, payload) -> None:
        if kind == "crash":
            self._on_crash(payload, t)
        elif kind == "corrupt":
            self._on_corrupt(payload, t)
        elif kind == "detect":
            self._on_detect(payload, t)
        elif kind == "retry":
            self._on_retry(payload, t)
        elif kind == "cancelstorm":
            self._on_cancelstorm(payload, t)
        elif kind == "cancel":
            self._on_cancel(payload, t)
        else:  # pragma: no cover - defensive
            raise ValueError(f"unknown control event {kind!r}")

    def _on_crash(self, fault, now: float) -> None:
        """Fail replica ``fault.replica`` at virtual time ``now``.  The
        crash takes effect at the first scheduling point at or after the
        fault time (engine steps are atomic).  All in-flight work is lost
        and re-dispatched after DETECTION — recovery runs off the
        missed-heartbeat signal, not the injector's ground truth."""
        idx = fault.replica
        if idx >= len(self.replicas) or self.state[idx] in (RETIRED, FAILED):
            return
        eng = self.replicas[idx]
        self.state[idx] = FAILED
        # sticky routers must forget this replica immediately, same as the
        # drain path (PrefixAffinityRouter re-homes its templates)
        self.router.note_replica_dead(eng.replica_id)
        lost = eng.force_fail()
        for req in lost:
            # a prompt that finished prefill on the crashed replica but was
            # never handed off must become a candidate again on its
            # recovery replica
            self._handoff_considered.discard(req.req_id)
        self._retired_at[idx] = now    # occupancy span ends at the crash
        rec = {"at": now, "replica": idx, "lost": len(lost),
               "detected_at": None, "recovered_at": None,
               "pending": {r.req_id for r in lost}, "_requests": lost}
        self.crashes.append(rec)
        tr = self._tracer()
        if tr is not None:
            tr.instant("fleet", "crash", now,
                       args={"replica": idx, "lost": len(lost)})
        self._schedule_ctl(now + self.control.detector.timeout_s,
                           "detect", rec)

    def _on_detect(self, rec: dict, now: float) -> None:
        """The failure detector confirms a silent replica and kicks off
        recovery: replace the replica (when a factory exists) and schedule
        every lost request's retry with exponential backoff."""
        idx = rec["replica"]
        if idx not in self.control.detector.suspects(
                now, [self.replicas[idx].replica_id]):
            # stepped since the fault was scheduled (cannot happen for a
            # FAILED replica, defensive): poll again one timeout later
            self._schedule_ctl(now + self.control.detector.timeout_s,
                               "detect", rec)
            return
        rec["detected_at"] = now
        tr = self._tracer()
        if tr is not None:
            tr.instant("fleet", "detect", now,
                       args={"replica": rec["replica"]})
        if self.replica_factory is not None:
            # replace-on-crash reuses the elastic add path (autoscale event
            # stream records it like any scale-up)
            role = self.roles[idx] if self.disaggregated else None
            self.add_replica(now, role=role)
        if not rec["pending"]:
            rec["recovered_at"] = now
        for req in rec["_requests"]:
            self._schedule_retry(req, rec, now)

    def _schedule_retry(self, req: Request, rec: dict, now: float) -> None:
        attempt = self._attempts.get(req.req_id, 0) + 1
        self._attempts[req.req_id] = attempt
        if self.retry_policy.exhausted(attempt):
            # budget spent: the request is surfaced as FAILED in metrics —
            # never silently dropped
            self.failed_requests.append(
                {"req_id": req.req_id, "at": now, "attempts": attempt - 1,
                 "priority": req.priority})
            tr = self._tracer()
            if tr is not None:
                tr.req_end(req.req_id, now, "failed",
                           attempts=attempt - 1, priority=req.priority)
            rec["pending"].discard(req.req_id)
            if not rec["pending"] and rec["recovered_at"] is None:
                rec["recovered_at"] = now
            return
        self.retries += 1
        # jitter (opt-in on the policy) draws from the injector's dedicated
        # retry stream — the corruption RNG never sees these draws
        rng = self.faults.retry_rng if self.faults is not None else None
        self._schedule_ctl(now + self.retry_policy.backoff(attempt, rng=rng),
                           "retry", (req, rec))

    def _on_retry(self, payload, now: float) -> None:
        """Re-dispatch one crashed request through the router.  Admission
        control is NOT re-consulted: the request was already admitted once
        and shedding it now would drop accepted work.  It restarts from
        its prompt (re-prefill); greedy decode makes the committed stream
        byte-identical to a fault-free run."""
        req, rec = payload
        self.requeues += 1
        tr = self._tracer()
        if tr is not None:
            # close the stall span opened at the crash at the retry instant
            # (the engine's re-submit then folds into this queue stage)
            tr.req_stage(req.req_id, now, "queue")
            tr.instant("fleet", "requeue", now, args={"req": req.req_id})
        self.submit(req, now=now)
        rec["pending"].discard(req.req_id)
        if not rec["pending"] and rec["recovered_at"] is None:
            rec["recovered_at"] = now

    def _on_corrupt(self, fault, now: float) -> None:
        """Corrupt host-KV records on one replica (checksum catches them
        at restore time; the prefix cold-re-prefills)."""
        idx = fault.replica
        if idx >= len(self.replicas):
            return
        hs = getattr(self.replicas[idx].scheduler.bm, "host_store", None)
        if hs is not None and self.faults is not None:
            self.faults.corrupt_host_records(hs, fault)

    def _on_cancelstorm(self, storm, now: float) -> None:
        """A cancellation storm fires: sample victims from the requests in
        flight NOW (seeded) and schedule each one's cancel inside the storm
        window."""
        if self.faults is None:
            return
        live = {rid for i, e in enumerate(self.replicas)
                if self.state[i] != FAILED
                for rid in e.inflight_req_ids()}
        for t, rid in self.faults.pick_cancel_victims(storm, live):
            self._schedule_ctl(max(t, now), "cancel", rid)

    def _on_cancel(self, req_id: int, now: float) -> None:
        """Client-cancel one request on whichever replica owns it (the
        assignment book tracks handoffs).  A no-op when the request already
        finished, was shed, or its replica failed — cancellation is
        idempotent and never invents accounting."""
        idx = self.assignments.get(req_id)
        if idx is None or idx >= len(self.replicas) \
                or self.state[idx] == FAILED:
            return
        eng = self.replicas[idx]
        if eng.cancel_request(req_id):
            # its dispatch forecast will never resolve — drop the record so
            # the residual estimator never folds a phantom sample
            self.control.tel(eng.replica_id)._forecasts.pop(req_id, None)

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: Optional[float] = None) -> int:
        """Route one request and enqueue it on the chosen replica."""
        if now is None:
            now = req.arrival
        routable = self.routable_replicas()
        pos = self.router.route(req, routable, now=now)
        eng = routable[pos]
        self.control.note_dispatch(eng, req, now)
        eng.submit(req)
        self.assignments[req.req_id] = eng.replica_id
        return eng.replica_id

    def _handle_arrival(self, req: Request) -> Optional[int]:
        """Autoscale -> admission -> route, at the arrival instant.
        Returns the replica id, or None when the request was shed."""
        self.control.begin_arrival()
        try:
            return self._handle_arrival_inner(req)
        finally:
            self.control.end_arrival()

    def _handle_arrival_inner(self, req: Request) -> Optional[int]:
        now = req.arrival
        scaler = self.control.autoscaler
        admission = self.control.admission
        min_forecast = None
        if scaler is not None or admission is not None \
                or self.brownout is not None:
            routable = self.routable_replicas()
            min_forecast = min(self.control.forecast_ttft(e, req, now)
                               for e in routable)
        if scaler is not None:
            # in disaggregated mode the classic TTFT-attainment autoscaler
            # governs the PREFILL pool only (TTFT is a prefill-side
            # property once decode is offloaded); the decode pool has its
            # own controller below
            if self.disaggregated:
                scaled = self._pool(PREFILL)
            else:
                scaled = list(range(len(self.replicas)))
            active = [i for i in scaled if self.state[i] == ACTIVE]
            loads = [self.replicas[i].load for i in active]
            n_alive = sum(1 for i in scaled if self.state[i] != RETIRED)
            action = scaler.decide(now, len(active), loads,
                                   min_forecast, req.slo, n_alive=n_alive)
            if action == "up" and self.replica_factory is not None:
                self.add_replica(
                    now, role=PREFILL if self.disaggregated else None)
            elif action == "down" and len(active) > 1:
                idx = min(active,
                          key=lambda i: (self.replicas[i].load, i))
                self.drain_replica(idx, now)
            if action is not None:
                # the routable set changed: a fresh replica is dispatchable
                # immediately, and a drained one no longer is — the
                # admission decision must see the post-action fleet (a
                # drained replica's low forecast must not keep the door
                # open for traffic it can no longer take)
                min_forecast = min(self.control.forecast_ttft(e, req, now)
                                   for e in self.routable_replicas())
        if self.decode_autoscaler is not None and self.disaggregated:
            dec_active = self._pool(DECODE, state=ACTIVE)
            snaps = [self.control.snapshot(self.replicas[i], now)
                     for i in dec_active]
            n_alive = sum(1 for i in self._pool(DECODE)
                          if self.state[i] != RETIRED)
            d_action = self.decode_autoscaler.decide(now, snaps,
                                                     n_alive=n_alive)
            if d_action == "up" and self.replica_factory is not None:
                self.add_replica(now, role=DECODE)
            elif d_action == "down" and len(dec_active) > 1:
                idx = min(dec_active,
                          key=lambda i: (self.replicas[i].load, i))
                self.drain_replica(idx, now)
        # brownout top-rung shedding fires before classic admission: at that
        # rung the ladder has already decided the fleet is saturated, and
        # its class ordering (best_effort first, interactive never) must not
        # be overridden by the class-blind forecast check below
        if self.brownout is not None and min_forecast is not None \
                and self.brownout.should_shed(req, min_forecast):
            self.shed.append({"req_id": req.req_id, "at": now,
                              "slo": req.slo, "priority": req.priority,
                              "by": "brownout"})
            self.control.note_shed(now)
            tr = self._tracer()
            if tr is not None:
                # a shed request never enters the system: fleet instant
                # only, no request lane (keeps span balance clean)
                tr.instant("fleet", "shed", now,
                           args={"req": req.req_id, "by": "brownout",
                                 "priority": req.priority})
            return None
        if admission is not None and min_forecast is not None \
                and admission.should_shed(req, min_forecast):
            self.shed.append({"req_id": req.req_id, "at": now,
                              "slo": req.slo, "priority": req.priority})
            self.control.note_shed(now)
            tr = self._tracer()
            if tr is not None:
                tr.instant("fleet", "shed", now,
                           args={"req": req.req_id, "by": "admission",
                                 "priority": req.priority})
            return None
        return self.submit(req, now=now)

    # ------------------------------------------------------------------
    # disaggregated prefill/decode handoff
    # ------------------------------------------------------------------
    def _consider_handoffs(self, src_idx: int) -> None:
        """After a prefill replica's step: migrate each freshly completed
        prompt to the decode pool iff the priced transfer wins.

        A sequence is a candidate exactly once, at the step its prefill
        completes and before it decodes a single token (the KV image is
        whole-prompt, nothing speculative in flight).  Declined candidates
        decode where they prefilled — the colocated fallback — and are
        never reconsidered, so pricing is a one-shot decision made on the
        same telemetry snapshot routing would see."""
        src = self.replicas[src_idx]
        now = src.clock
        dsts = self._pool(DECODE, state=ACTIVE)
        if not dsts:
            return
        extracted = 0
        for seq in list(src.scheduler.running):
            if (seq.prompt_remaining != 0 or seq.done
                    or seq.generated != 0):
                continue
            rid = seq.req_id
            if rid in self._handoff_considered:
                continue
            self._handoff_considered.add(rid)
            # KV-headroom gate: a destination must be able to host the
            # whole prompt ON TOP of the handoffs already in flight to it.
            # On memory-tight profiles the decode pool saturates long
            # before the prefill pool — migrating past its capacity would
            # trade one replica's queue for another's preempt/recompute
            # thrash, so a prompt no decode replica can host simply decodes
            # where it prefilled (the colocated fallback, never worse).
            plen = max(seq.request.prompt_len, 1)
            hosts = [i for i in dsts if self.replicas[i].scheduler.bm
                     .can_allocate(plen + sum(
                         item[2].prompt_len
                         for item in self.replicas[i]._handoffs))]
            if not hosts:
                if self.pricer is not None:
                    self.pricer.declined += 1
                continue
            dst_i = min(hosts, key=lambda i: (
                self.control.forecast_ttft(self.replicas[i], None, now),
                self.replicas[i].load, i))
            dst = self.replicas[dst_i]
            if self.pricer is not None and not self.pricer.decide(
                    src, dst, seq.request, now):
                continue
            transfer_s = (self.pricer.transfer_seconds(
                src, seq.request.prompt_len) if self.pricer else 0.0)
            # injected transfer faults: each failed/timed-out attempt
            # wastes interconnect time; past the retry cap the sequence
            # simply decodes where it prefilled (the colocated fallback
            # PR 7 guarantees is never worse) — candidacy was already
            # consumed, so it is not reconsidered
            waste = 0.0
            aborted = False
            if self.faults is not None:
                attempts = 0
                while True:
                    fault = self.faults.next_handoff_fault(now + waste)
                    if fault is None:
                        break
                    if fault.mode == "timeout":
                        waste += transfer_s * fault.timeout_factor
                        self.handoff_timeouts += 1
                    else:
                        waste += transfer_s
                        self.handoff_failures += 1
                    attempts += 1
                    if attempts > self.handoff_max_retries:
                        aborted = True
                        break
                    self.handoff_retries += 1
                self.handoff_transfer_s += waste
            if aborted:
                self.handoff_aborts += 1
                continue
            payload = src.extract_for_handoff(seq)
            dst.accept_handoff(seq.request,
                               t_ready=now + waste + transfer_s,
                               payload=payload)
            tr = self._tracer()
            if tr is not None:
                # KV migration: the request rides the interconnect until
                # t_ready, when adoption opens its decode stage on dst
                tr.req_stage(rid, now, "transfer", src.replica_id)
                tr.instant("fleet", "handoff", now,
                           args={"req": rid, "src": src.replica_id,
                                 "dst": dst.replica_id,
                                 "transfer_s": transfer_s, "waste_s": waste})
            self.control.note_handoff(src, dst, rid)
            self.assignments[rid] = dst.replica_id
            self.handoff_transfer_s += transfer_s
            self.handoffs.append(
                {"req_id": rid, "at": now, "src": src.replica_id,
                 "dst": dst.replica_id,
                 "transfer_s": round(transfer_s, 6)})
            extracted += 1
        if (extracted and src.scheduler.num_waiting
                and not src.scheduler.num_running):
            # the handoff emptied the running set while requests sat in the
            # waiting queue (admission had failed against blocks the
            # migrated sequences held): an idle engine only retries
            # admission on its next arrival, and with none pending it
            # would deadlock — retry NOW against the freed pool.  If the
            # head still cannot be admitted the step is a no-op and the
            # replica is stuck exactly as a colocated one would be.
            src.step()

    # ------------------------------------------------------------------
    # fleet brownout ladder
    # ------------------------------------------------------------------
    def _apply_brownout(self, now: float) -> None:
        """Evaluate the ladder (when a check is due) and push the current
        rung's knobs to every live replica.  Application is idempotent —
        the same stage re-applied is a no-op — and covers replicas added
        after the last transition (a crash-replacement engine must inherit
        the fleet's degradation state, not join at full service)."""
        bo = self.brownout
        if bo is None or not bo.due(now):
            return
        live = [i for i in range(len(self.replicas))
                if self.state[i] not in (RETIRED, FAILED)]
        snaps = [self.control.snapshot(self.replicas[i], now) for i in live]
        ev = bo.evaluate(now, snaps)
        if ev is not None:
            self.brownout_events.append(ev)
        cap = bo.output_cap_for("best_effort")
        for i in live:
            e = self.replicas[i]
            e.spec_forced_off = bo.spec_off
            e.best_effort_cap = cap
            if e.memmgr is not None:
                e.memmgr.force_offload = bo.offload_draft

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(e.has_work() for e in self.replicas)

    def peek_next_event(self) -> Optional[float]:
        evs = [t for t in (e.peek_next_event() for e in self.replicas)
               if t is not None]
        return min(evs) if evs else None

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 5_000_000,
            record_timeline: bool = False) -> ClusterMetrics:
        """Discrete-event loop: route arrivals / step the earliest replica.

        ``record_timeline`` opts in to per-step timeline dicts on every
        replica (ring-bounded); off by default — long benches that never
        read them pay nothing."""
        self._record_timeline = record_timeline
        for e in self.replicas:
            e.record_timeline = record_timeline
            if record_timeline:
                e.metrics.use_timeline_ring()
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        self._starts = [e.clock for e in self.replicas]
        if self.faults is not None:
            for i, e in enumerate(self.replicas):
                self.control.detector.heartbeat(e.replica_id, e.clock)
            for t, kind, payload in self.faults.timed_events():
                self._schedule_ctl(t, kind, payload)
        # pre-scheduled client cancellations (workload.cancellation_storm):
        # explicit (t, req_id) pairs, so brownout-on/off cells of a bench
        # grid cancel the SAME requests at the SAME instants
        for t, rid in self.cancels:
            self._schedule_ctl(float(t), "cancel", int(rid))
        pi = 0
        steps = 0
        while steps < max_steps:
            # a FAILED replica never steps again: its events are gone
            evs = [(t, i) for i, t in
                   enumerate(e.peek_next_event() for e in self.replicas)
                   if t is not None and self.state[i] != FAILED]
            t_engine = min(evs)[0] if evs else float("inf")
            t_arrival = (pending[pi].arrival if pi < len(pending)
                         else float("inf"))
            # timed control events (crash / corrupt / detect / retry) fire
            # ahead of engine steps and arrivals at the same instant; the
            # heap is empty without a fault plan, leaving the fault-free
            # event order byte-identical to the pre-fault-layer loop
            if self._control_events and \
                    self._control_events[0][0] <= min(t_engine, t_arrival):
                t, _, kind, payload = heapq.heappop(self._control_events)
                self._dispatch_ctl(t, kind, payload)
                steps += 1
                continue
            if pi < len(pending) and t_arrival <= t_engine:
                self._handle_arrival(pending[pi])
                pi += 1
                continue
            if not evs:
                break
            _, idx = min(evs)
            self.replicas[idx].step()
            if self.disaggregated and self.roles[idx] == PREFILL:
                self._consider_handoffs(idx)
            self.control.observe_step(self.replicas[idx])
            self._apply_brownout(self.replicas[idx].clock)
            self._maybe_retire(idx, self.replicas[idx].clock)
            steps += 1

        per = [e.finalize_metrics(self._starts[i])
               for i, e in enumerate(self.replicas)]
        makespan = max((e.clock - self._starts[i]
                        for i, e in enumerate(self.replicas)
                        if e.metrics.total_tokens or e.clock > self._starts[i]),
                       default=0.0)
        end = max((e.clock for e in self.replicas), default=0.0)
        spans = [(self._starts[i],
                  self._retired_at.get(i, max(end, self._starts[i])))
                 for i in range(len(self.replicas))]
        # externally visible crash records: drop the internal request
        # objects / pending sets so the list is JSON-serialisable
        crashes = [{k: v for k, v in c.items()
                    if k not in ("pending", "_requests")}
                   for c in self.crashes]
        return ClusterMetrics(per_replica=per, elapsed=makespan,
                              assignments=dict(self.assignments),
                              shed=list(self.shed),
                              autoscale_events=list(self.autoscale_events),
                              replica_states=list(self.state),
                              replica_spans=spans,
                              replica_roles=list(self.roles),
                              handoffs=list(self.handoffs),
                              handoffs_declined=(self.pricer.declined
                                                 if self.pricer else 0),
                              handoff_transfer_s=self.handoff_transfer_s,
                              handoff_fallbacks=sum(
                                  e.handoffs_refused for e in self.replicas),
                              handoff_failures=self.handoff_failures,
                              handoff_timeouts=self.handoff_timeouts,
                              handoff_retries=self.handoff_retries,
                              handoff_aborts=self.handoff_aborts,
                              crashes=crashes,
                              requeues=self.requeues,
                              retries=self.retries,
                              failed_requests=list(self.failed_requests),
                              brownout_events=list(self.brownout_events))
