"""Multi-replica serving cluster on a shared virtual event clock.

``ServingCluster`` owns N independent :class:`ServingEngine` replicas — each
with its own :class:`ContinuousBatchingScheduler`, MAB planner and
:class:`ElasticMemoryManager` — plus one :class:`Router` that dispatches a
single global arrival stream across them.  This is the fleet tier the paper
motivates ("dynamic request rates from millions of users"): per-replica
planners adapt their speculative length *independently* to the load each
replica actually sees.

Event-clock semantics
---------------------
Every engine advances its own virtual clock as it executes steps; the
cluster interleaves them with a classic discrete-event loop:

  1. the next *engine* event is ``min over replicas of peek_next_event()``;
  2. the next *arrival* event is the head of the global request stream;
  3. whichever is earlier happens: an arrival is admitted (or shed), routed
     (based on replica state observed *now*) and submitted, or the
     earliest-clock replica executes one ``step()``.

Because a replica is only stepped when it holds the minimum clock, replica
timelines interleave correctly in virtual time, and routing decisions see
queue/KV state no newer than the arrival instant — the same information a
real front-end would have.

Control plane (serving/controlplane.py)
---------------------------------------
Every cluster owns a :class:`ControlPlane` (telemetry-only by default).
After each replica step the plane consumes the replica's freshly finished
request stats (the EWMA TTFT/TPOT predictors and the forecast-residual
bias); at each arrival the cluster consults, in order:

  * the **autoscaler** — may ``add_replica`` (a fresh engine joins at the
    current virtual time) or ``drain_replica`` (the least-loaded replica
    stops receiving traffic, finishes its running work, then retires);
  * the **admission controller** — may *shed* the arrival at the door when
    even the best replica's predicted TTFT is hopeless (recorded in
    ``ClusterMetrics.shed``, never as an SLO miss of admitted traffic);
  * the **router** — dispatches over the routable (non-draining) replicas.

Determinism: engines, router tie-breaks, telemetry, controllers and
workload generation are all seeded/deterministic, so a cluster run is
exactly reproducible — two runs of the same config produce byte-identical
routing decisions (golden-value tested in tests/test_cluster.py and
tests/test_controlplane.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from .controlplane import ControlPlane, DecodePoolAutoscaler, HandoffPricer
from .engine import ServingEngine
from .request import (Metrics, Request, RequestStats, goodput_of, percentile,
                      slo_attainment_of)
from .router import Router

# replica lifecycle states
ACTIVE, DRAINING, RETIRED = "active", "draining", "retired"

# replica roles (disaggregated mode; COLOCATED is the classic do-everything
# replica of a non-disaggregated cluster)
PREFILL, DECODE, COLOCATED = "prefill", "decode", "colocated"


@dataclass
class ClusterMetrics:
    """Aggregate + per-replica metrics for one cluster run."""

    per_replica: List[Metrics]
    elapsed: float = 0.0              # virtual makespan across replicas
    assignments: Dict[int, int] = field(default_factory=dict)  # req -> replica
    shed: List[dict] = field(default_factory=list)   # rejected at the door
    autoscale_events: List[dict] = field(default_factory=list)
    replica_states: List[str] = field(default_factory=list)
    replica_spans: List[tuple] = field(default_factory=list)  # (start, end)
    replica_roles: List[str] = field(default_factory=list)
    handoffs: List[dict] = field(default_factory=list)  # prefill->decode
    handoffs_declined: int = 0        # pricer chose colocated fallback
    handoff_transfer_s: float = 0.0   # total modelled interconnect time
    handoff_fallbacks: int = 0        # adoptions that re-prefilled locally

    @property
    def total_tokens(self) -> int:
        return sum(m.total_tokens for m in self.per_replica)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def latencies(self) -> List[float]:
        return [x for m in self.per_replica for x in m.latencies]

    @property
    def ttfts(self) -> List[float]:
        return [x for m in self.per_replica for x in m.ttfts]

    @property
    def mean_latency(self) -> float:
        lat = self.latencies
        return sum(lat) / len(lat) if lat else 0.0

    @property
    def mean_ttft(self) -> float:
        t = self.ttfts
        return sum(t) / len(t) if t else 0.0

    @property
    def requests(self) -> List[RequestStats]:
        return [r for m in self.per_replica for r in m.requests]

    def ttft_percentile(self, q: float) -> float:
        reqs = self.requests
        return percentile([r.ttft for r in reqs] or self.ttfts, q)

    def tpot_percentile(self, q: float) -> float:
        return percentile([r.tpot for r in self.requests], q)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(0.99)

    @property
    def slo_attainment(self) -> float:
        """Attainment of ADMITTED deadline-carrying traffic (shed requests
        are accounted separately — see ``slo_attainment_offered``)."""
        return slo_attainment_of(self.requests)

    @property
    def offered_slo_count(self) -> int:
        """Deadline-carrying requests in the offered load: finished ones
        plus shed ones — the sample count behind
        ``slo_attainment_offered`` (renderer gate)."""
        return (sum(1 for r in self.requests if r.slo is not None)
                + sum(1 for s in self.shed if s.get("slo") is not None))

    @property
    def slo_attainment_offered(self) -> Optional[float]:
        """Attainment over the OFFERED load: shed deadline-carrying
        requests count as misses (the honest fleet-level number).

        ``None`` when the offered load carries no deadline samples at all
        (e.g. every request shed before any deadline-carrying one
        finished) — n/a by contract, never a fake-perfect ratio
        (tests/test_metrics_edges.py convention)."""
        with_slo = [r for r in self.requests if r.slo is not None]
        shed_slo = sum(1 for s in self.shed if s.get("slo") is not None)
        total = len(with_slo) + shed_slo
        if total == 0:
            return None
        return sum(r.slo_met for r in with_slo) / total

    @property
    def shed_count(self) -> int:
        return len(self.shed)

    @property
    def goodput(self) -> float:
        """Fleet tokens/s from requests that met their TTFT SLO."""
        return goodput_of(self.requests, self.elapsed, self.throughput)

    @property
    def prefix_hit_rate(self) -> float:
        """Aggregate prefix-cache hit rate across the fleet."""
        q = sum(m.prefix.get("queries", 0) for m in self.per_replica)
        h = sum(m.prefix.get("hits", 0) for m in self.per_replica)
        return h / q if q else 0.0

    @property
    def peak_replicas(self) -> int:
        """Most replicas simultaneously non-retired at any arrival/step."""
        if not self.replica_spans:
            return len(self.per_replica)
        events = []
        for start, end in self.replica_spans:
            events.append((start, 1))
            events.append((end, -1))
        peak = cur = 0
        for _, d in sorted(events, key=lambda e: (e[0], -e[1])):
            cur += d
            peak = max(peak, cur)
        return peak

    @property
    def replica_seconds(self) -> float:
        """Total replica-occupancy (virtual seconds summed over replicas)
        — the capacity cost an autoscaled fleet actually paid."""
        return sum(max(end - start, 0.0)
                   for start, end in self.replica_spans)

    def replica_counts(self) -> List[int]:
        """Requests routed to each replica."""
        n = len(self.per_replica)
        counts = [0] * n
        for idx in self.assignments.values():
            counts[idx] += 1
        return counts

    def per_replica_summary(self) -> List[dict]:
        """Per-replica breakdown: the control-plane observability surface."""
        counts = self.replica_counts()
        out = []
        for i, m in enumerate(self.per_replica):
            # a replica that completed zero requests (retired mid-drain,
            # or every request it saw was shed upstream) has NO latency
            # samples: percentile() would report a fake-perfect 0.0 and
            # slo_attainment a fake-perfect 1.0.  n/a by contract instead
            # (tests/test_metrics_edges.py) — `finished` is the gate.
            n = len(m.requests)
            row = {
                "replica": i,
                "state": (self.replica_states[i]
                          if i < len(self.replica_states) else ACTIVE),
                "role": (self.replica_roles[i]
                         if i < len(self.replica_roles) else COLOCATED),
                "requests": counts[i],
                "finished": n,
                "tok_s": round(m.throughput, 2),
                "p99_ttft_s": round(m.ttft_percentile(0.99), 4) if n else None,
                "slo_attainment": round(m.slo_attainment, 4) if n else None,
                "offloads": m.offload_events,
            }
            if m.prefix:
                row["prefix_hit_rate"] = round(m.prefix_hit_rate, 4)
            out.append(row)
        return out

    def summary(self) -> dict:
        out = {
            "replicas": len(self.per_replica),
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "p50_ttft_s": round(self.ttft_percentile(0.50), 4),
            "p95_ttft_s": round(self.ttft_percentile(0.95), 4),
            "p99_ttft_s": round(self.ttft_percentile(0.99), 4),
            "p50_tpot_s": round(self.tpot_percentile(0.50), 5),
            "p99_tpot_s": round(self.tpot_percentile(0.99), 5),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_tok_s": round(self.goodput, 2),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "per_replica_tok_s": [round(m.throughput, 2)
                                  for m in self.per_replica],
            "per_replica_requests": self.replica_counts(),
            "per_replica": self.per_replica_summary(),
            "switches": sum(m.switch_count for m in self.per_replica),
            "offloads": sum(m.offload_events for m in self.per_replica),
            "reloads": sum(m.reload_events for m in self.per_replica),
            "blocks_allocated": sum(m.blocks_allocated
                                    for m in self.per_replica),
        }
        if self.shed or self.autoscale_events:
            out["shed_count"] = self.shed_count
            offered = self.slo_attainment_offered
            out["offered_slo_count"] = self.offered_slo_count
            out["slo_attainment_offered"] = (
                round(offered, 4) if offered is not None else None)
        if self.autoscale_events:
            out["peak_replicas"] = self.peak_replicas
            out["replica_seconds"] = round(self.replica_seconds, 3)
            out["autoscale"] = {
                "adds": sum(1 for e in self.autoscale_events
                            if e["kind"] == "add"),
                "drains": sum(1 for e in self.autoscale_events
                              if e["kind"] == "drain"),
                "retires": sum(1 for e in self.autoscale_events
                               if e["kind"] == "retire"),
            }
        if self.handoffs or self.handoffs_declined:
            out["disagg"] = {
                "handoffs": len(self.handoffs),
                "declined": self.handoffs_declined,
                "transfer_s": round(self.handoff_transfer_s, 4),
                "adopt_fallbacks": self.handoff_fallbacks,
            }
        if any(m.prefix for m in self.per_replica):
            out["prefix_saved_tokens"] = sum(
                m.prefix.get("saved_tokens", 0) for m in self.per_replica)
            out["prefix_hits"] = sum(
                m.prefix.get("hits", 0) for m in self.per_replica)
            out["prefix_hit_rate"] = round(self.prefix_hit_rate, 4)
        return out


class ServingCluster:
    def __init__(self, replicas: Sequence[ServingEngine], router: Router,
                 *, control: Optional[ControlPlane] = None,
                 replica_factory: Optional[
                     Callable[[int], ServingEngine]] = None,
                 roles: Optional[Sequence[str]] = None,
                 pricer: Optional[HandoffPricer] = None,
                 decode_autoscaler: Optional[DecodePoolAutoscaler] = None):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = list(replicas)
        for i, eng in enumerate(self.replicas):
            eng.replica_id = i
        self.router = router
        self.control = control if control is not None else ControlPlane()
        # headroom-based routers share the cluster's telemetry book
        if getattr(router, "control", None) is None:
            router.control = self.control
        self.replica_factory = replica_factory
        self.state: List[str] = [ACTIVE] * len(self.replicas)
        # disaggregated mode: arrivals land on the PREFILL pool; once a
        # request's prompt is fully materialised its KV blocks may migrate
        # to a DECODE replica (priced per-handoff by `pricer`).  roles=None
        # is the classic colocated cluster, unchanged.
        self.disaggregated = roles is not None
        if roles is not None:
            roles = list(roles)
            if len(roles) != len(self.replicas):
                raise ValueError("roles must match replicas")
            bad = set(roles) - {PREFILL, DECODE}
            if bad:
                raise ValueError(f"unknown roles {sorted(bad)}")
            if PREFILL not in roles:
                raise ValueError("disaggregated cluster needs >=1 prefill "
                                 "replica")
            self.roles: List[str] = roles
        else:
            self.roles = [COLOCATED] * len(self.replicas)
        self.pricer = pricer
        if self.disaggregated and self.pricer is None:
            self.pricer = HandoffPricer(self.control)
        self.decode_autoscaler = decode_autoscaler
        self.assignments: Dict[int, int] = {}
        self.shed: List[dict] = []
        self.autoscale_events: List[dict] = []
        self.handoffs: List[dict] = []
        self.handoff_transfer_s = 0.0
        self._handoff_considered: set = set()
        self._starts = [e.clock for e in self.replicas]
        self._retired_at: Dict[int, float] = {}
        self._record_timeline = True

    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.state if s == ACTIVE)

    def _pool(self, role: str, *, state: Optional[str] = None) -> List[int]:
        """Replica indices with ``role`` (optionally filtered by state)."""
        return [i for i in range(len(self.replicas))
                if self.roles[i] == role
                and (state is None or self.state[i] == state)]

    def routable_replicas(self) -> List[ServingEngine]:
        """Replicas the router may dispatch to: active only — draining
        replicas finish their assigned work but accept nothing new.

        Disaggregated mode scopes dispatch to the PREFILL pool (decode
        replicas receive work only through the KV-handoff path), falling
        back to the whole fleet if every prefill replica is gone.

        A fully drained fleet (the operator drained everything by hand)
        still has to land arrivals somewhere deterministic: fall back to
        the draining replicas, and past that to the whole fleet — a
        retired engine is just an idle engine wearing a control-plane
        label, and serving there beats crashing the router."""
        idxs = list(range(len(self.replicas)))
        if self.disaggregated:
            pre = self._pool(PREFILL)
            cand = ([i for i in pre if self.state[i] == ACTIVE]
                    or [i for i in pre if self.state[i] != RETIRED])
            if cand:
                return [self.replicas[i] for i in cand]
            # no prefill replica left at all: serve colocated on whatever
            # remains rather than dropping the arrival
        out = [i for i in idxs if self.state[i] == ACTIVE]
        out = out or [i for i in idxs if self.state[i] != RETIRED]
        return [self.replicas[i] for i in (out or idxs)]

    # ------------------------------------------------------------------
    # elastic fleet surface
    # ------------------------------------------------------------------
    def add_replica(self, now: float, *, role: Optional[str] = None) -> int:
        """Bring a fresh replica online at virtual time ``now`` (its clock
        starts there — no retroactive work) and open it for routing.  In
        disaggregated mode ``role`` selects the pool it joins (default
        prefill — the pool classic autoscaling serves)."""
        if self.replica_factory is None:
            raise RuntimeError("cluster has no replica_factory")
        rid = len(self.replicas)
        eng = self.replica_factory(rid)
        eng.replica_id = rid
        eng.clock = max(eng.clock, now)
        eng.record_timeline = self._record_timeline
        self.replicas.append(eng)
        self.state.append(ACTIVE)
        if role is None:
            role = PREFILL if self.disaggregated else COLOCATED
        self.roles.append(role)
        self._starts.append(eng.clock)
        self.autoscale_events.append(
            {"kind": "add", "at": now, "replica": rid, "role": role})
        return rid

    def drain_replica(self, idx: int, now: float) -> None:
        """Stop routing to replica ``idx``; it finishes every request it
        already owns (pending + waiting + running) and then retires —
        draining never drops work."""
        if self.state[idx] != ACTIVE:
            return
        self.state[idx] = DRAINING
        # stateful routers (sticky affinity homes) must forget this replica
        # NOW: a stale home entry would keep steering its templates at a
        # replica that accepts no new traffic
        self.router.note_replica_dead(self.replicas[idx].replica_id)
        self.autoscale_events.append(
            {"kind": "drain", "at": now, "replica": idx})
        self._maybe_retire(idx, now)

    def _maybe_retire(self, idx: int, now: float) -> None:
        if self.state[idx] == DRAINING and not self.replicas[idx].has_work():
            # the request queues are empty but the host KV tier's transfer
            # queues may not be: flush them as part of the drain-to-retire
            # transition, otherwise pending spills/restores are silently
            # dropped and their pinned HostKVStore records leak forever
            # (invariant I6 must hold across drain)
            self.replicas[idx].flush_host_transfers()
            self.state[idx] = RETIRED
            self._retired_at[idx] = max(now, self.replicas[idx].clock)
            self.autoscale_events.append(
                {"kind": "retire", "at": self._retired_at[idx],
                 "replica": idx})

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: Optional[float] = None) -> int:
        """Route one request and enqueue it on the chosen replica."""
        if now is None:
            now = req.arrival
        routable = self.routable_replicas()
        pos = self.router.route(req, routable, now=now)
        eng = routable[pos]
        self.control.note_dispatch(eng, req, now)
        eng.submit(req)
        self.assignments[req.req_id] = eng.replica_id
        return eng.replica_id

    def _handle_arrival(self, req: Request) -> Optional[int]:
        """Autoscale -> admission -> route, at the arrival instant.
        Returns the replica id, or None when the request was shed."""
        self.control.begin_arrival()
        try:
            return self._handle_arrival_inner(req)
        finally:
            self.control.end_arrival()

    def _handle_arrival_inner(self, req: Request) -> Optional[int]:
        now = req.arrival
        scaler = self.control.autoscaler
        admission = self.control.admission
        min_forecast = None
        if scaler is not None or admission is not None:
            routable = self.routable_replicas()
            min_forecast = min(self.control.forecast_ttft(e, req, now)
                               for e in routable)
        if scaler is not None:
            # in disaggregated mode the classic TTFT-attainment autoscaler
            # governs the PREFILL pool only (TTFT is a prefill-side
            # property once decode is offloaded); the decode pool has its
            # own controller below
            if self.disaggregated:
                scaled = self._pool(PREFILL)
            else:
                scaled = list(range(len(self.replicas)))
            active = [i for i in scaled if self.state[i] == ACTIVE]
            loads = [self.replicas[i].load for i in active]
            n_alive = sum(1 for i in scaled if self.state[i] != RETIRED)
            action = scaler.decide(now, len(active), loads,
                                   min_forecast, req.slo, n_alive=n_alive)
            if action == "up" and self.replica_factory is not None:
                self.add_replica(
                    now, role=PREFILL if self.disaggregated else None)
            elif action == "down" and len(active) > 1:
                idx = min(active,
                          key=lambda i: (self.replicas[i].load, i))
                self.drain_replica(idx, now)
            if action is not None:
                # the routable set changed: a fresh replica is dispatchable
                # immediately, and a drained one no longer is — the
                # admission decision must see the post-action fleet (a
                # drained replica's low forecast must not keep the door
                # open for traffic it can no longer take)
                min_forecast = min(self.control.forecast_ttft(e, req, now)
                                   for e in self.routable_replicas())
        if self.decode_autoscaler is not None and self.disaggregated:
            dec_active = self._pool(DECODE, state=ACTIVE)
            snaps = [self.control.snapshot(self.replicas[i], now)
                     for i in dec_active]
            n_alive = sum(1 for i in self._pool(DECODE)
                          if self.state[i] != RETIRED)
            d_action = self.decode_autoscaler.decide(now, snaps,
                                                     n_alive=n_alive)
            if d_action == "up" and self.replica_factory is not None:
                self.add_replica(now, role=DECODE)
            elif d_action == "down" and len(dec_active) > 1:
                idx = min(dec_active,
                          key=lambda i: (self.replicas[i].load, i))
                self.drain_replica(idx, now)
        if admission is not None and min_forecast is not None \
                and admission.should_shed(req, min_forecast):
            self.shed.append({"req_id": req.req_id, "at": now,
                              "slo": req.slo})
            self.control.note_shed(now)
            return None
        return self.submit(req, now=now)

    # ------------------------------------------------------------------
    # disaggregated prefill/decode handoff
    # ------------------------------------------------------------------
    def _consider_handoffs(self, src_idx: int) -> None:
        """After a prefill replica's step: migrate each freshly completed
        prompt to the decode pool iff the priced transfer wins.

        A sequence is a candidate exactly once, at the step its prefill
        completes and before it decodes a single token (the KV image is
        whole-prompt, nothing speculative in flight).  Declined candidates
        decode where they prefilled — the colocated fallback — and are
        never reconsidered, so pricing is a one-shot decision made on the
        same telemetry snapshot routing would see."""
        src = self.replicas[src_idx]
        now = src.clock
        dsts = self._pool(DECODE, state=ACTIVE)
        if not dsts:
            return
        extracted = 0
        for seq in list(src.scheduler.running):
            if (seq.prompt_remaining != 0 or seq.done
                    or seq.generated != 0):
                continue
            rid = seq.req_id
            if rid in self._handoff_considered:
                continue
            self._handoff_considered.add(rid)
            # KV-headroom gate: a destination must be able to host the
            # whole prompt ON TOP of the handoffs already in flight to it.
            # On memory-tight profiles the decode pool saturates long
            # before the prefill pool — migrating past its capacity would
            # trade one replica's queue for another's preempt/recompute
            # thrash, so a prompt no decode replica can host simply decodes
            # where it prefilled (the colocated fallback, never worse).
            plen = max(seq.request.prompt_len, 1)
            hosts = [i for i in dsts if self.replicas[i].scheduler.bm
                     .can_allocate(plen + sum(
                         item[2].prompt_len
                         for item in self.replicas[i]._handoffs))]
            if not hosts:
                if self.pricer is not None:
                    self.pricer.declined += 1
                continue
            dst_i = min(hosts, key=lambda i: (
                self.control.forecast_ttft(self.replicas[i], None, now),
                self.replicas[i].load, i))
            dst = self.replicas[dst_i]
            if self.pricer is not None and not self.pricer.decide(
                    src, dst, seq.request, now):
                continue
            transfer_s = (self.pricer.transfer_seconds(
                src, seq.request.prompt_len) if self.pricer else 0.0)
            payload = src.extract_for_handoff(seq)
            dst.accept_handoff(seq.request, t_ready=now + transfer_s,
                               payload=payload)
            self.control.note_handoff(src, dst, rid)
            self.assignments[rid] = dst.replica_id
            self.handoff_transfer_s += transfer_s
            self.handoffs.append(
                {"req_id": rid, "at": now, "src": src.replica_id,
                 "dst": dst.replica_id,
                 "transfer_s": round(transfer_s, 6)})
            extracted += 1
        if (extracted and src.scheduler.num_waiting
                and not src.scheduler.num_running):
            # the handoff emptied the running set while requests sat in the
            # waiting queue (admission had failed against blocks the
            # migrated sequences held): an idle engine only retries
            # admission on its next arrival, and with none pending it
            # would deadlock — retry NOW against the freed pool.  If the
            # head still cannot be admitted the step is a no-op and the
            # replica is stuck exactly as a colocated one would be.
            src.step()

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(e.has_work() for e in self.replicas)

    def peek_next_event(self) -> Optional[float]:
        evs = [t for t in (e.peek_next_event() for e in self.replicas)
               if t is not None]
        return min(evs) if evs else None

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 5_000_000,
            record_timeline: bool = True) -> ClusterMetrics:
        """Discrete-event loop: route arrivals / step the earliest replica."""
        self._record_timeline = record_timeline
        for e in self.replicas:
            e.record_timeline = record_timeline
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        self._starts = [e.clock for e in self.replicas]
        pi = 0
        steps = 0
        while steps < max_steps:
            evs = [(t, i) for i, t in
                   enumerate(e.peek_next_event() for e in self.replicas)
                   if t is not None]
            t_engine = min(evs)[0] if evs else float("inf")
            if pi < len(pending) and pending[pi].arrival <= t_engine:
                self._handle_arrival(pending[pi])
                pi += 1
                continue
            if not evs:
                break
            _, idx = min(evs)
            self.replicas[idx].step()
            if self.disaggregated and self.roles[idx] == PREFILL:
                self._consider_handoffs(idx)
            self.control.observe_step(self.replicas[idx])
            self._maybe_retire(idx, self.replicas[idx].clock)
            steps += 1

        per = [e.finalize_metrics(self._starts[i])
               for i, e in enumerate(self.replicas)]
        makespan = max((e.clock - self._starts[i]
                        for i, e in enumerate(self.replicas)
                        if e.metrics.total_tokens or e.clock > self._starts[i]),
                       default=0.0)
        end = max((e.clock for e in self.replicas), default=0.0)
        spans = [(self._starts[i],
                  self._retired_at.get(i, max(end, self._starts[i])))
                 for i in range(len(self.replicas))]
        return ClusterMetrics(per_replica=per, elapsed=makespan,
                              assignments=dict(self.assignments),
                              shed=list(self.shed),
                              autoscale_events=list(self.autoscale_events),
                              replica_states=list(self.state),
                              replica_spans=spans,
                              replica_roles=list(self.roles),
                              handoffs=list(self.handoffs),
                              handoffs_declined=(self.pricer.declined
                                                 if self.pricer else 0),
                              handoff_transfer_s=self.handoff_transfer_s,
                              handoff_fallbacks=sum(
                                  e.handoffs_refused for e in self.replicas))
