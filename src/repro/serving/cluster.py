"""Multi-replica serving cluster on a shared virtual event clock.

``ServingCluster`` owns N independent :class:`ServingEngine` replicas — each
with its own :class:`ContinuousBatchingScheduler`, MAB planner and
:class:`ElasticMemoryManager` — plus one :class:`Router` that dispatches a
single global arrival stream across them.  This is the fleet tier the paper
motivates ("dynamic request rates from millions of users"): per-replica
planners adapt their speculative length *independently* to the load each
replica actually sees.

Event-clock semantics
---------------------
Every engine advances its own virtual clock as it executes steps; the
cluster interleaves them with a classic discrete-event loop:

  1. the next *engine* event is ``min over replicas of peek_next_event()``;
  2. the next *arrival* event is the head of the global request stream;
  3. whichever is earlier happens: an arrival is routed (based on replica
     state observed *now*) and submitted, or the earliest-clock replica
     executes one ``step()``.

Because a replica is only stepped when it holds the minimum clock, replica
timelines interleave correctly in virtual time, and routing decisions see
queue/KV state no newer than the arrival instant — the same information a
real front-end would have.

Determinism: engines, router tie-breaks and workload generation are all
seeded/deterministic, so a cluster run is exactly reproducible (golden-value
tested in tests/test_cluster.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .engine import ServingEngine
from .request import (Metrics, Request, RequestStats, goodput_of, percentile,
                      slo_attainment_of)
from .router import Router


@dataclass
class ClusterMetrics:
    """Aggregate + per-replica metrics for one cluster run."""

    per_replica: List[Metrics]
    elapsed: float = 0.0              # virtual makespan across replicas
    assignments: Dict[int, int] = field(default_factory=dict)  # req -> replica

    @property
    def total_tokens(self) -> int:
        return sum(m.total_tokens for m in self.per_replica)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def latencies(self) -> List[float]:
        return [x for m in self.per_replica for x in m.latencies]

    @property
    def ttfts(self) -> List[float]:
        return [x for m in self.per_replica for x in m.ttfts]

    @property
    def mean_latency(self) -> float:
        lat = self.latencies
        return sum(lat) / len(lat) if lat else 0.0

    @property
    def mean_ttft(self) -> float:
        t = self.ttfts
        return sum(t) / len(t) if t else 0.0

    @property
    def requests(self) -> List[RequestStats]:
        return [r for m in self.per_replica for r in m.requests]

    def ttft_percentile(self, q: float) -> float:
        reqs = self.requests
        return percentile([r.ttft for r in reqs] or self.ttfts, q)

    def tpot_percentile(self, q: float) -> float:
        return percentile([r.tpot for r in self.requests], q)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(0.99)

    @property
    def slo_attainment(self) -> float:
        return slo_attainment_of(self.requests)

    @property
    def goodput(self) -> float:
        """Fleet tokens/s from requests that met their TTFT SLO."""
        return goodput_of(self.requests, self.elapsed, self.throughput)

    def replica_counts(self) -> List[int]:
        """Requests routed to each replica."""
        n = len(self.per_replica)
        counts = [0] * n
        for idx in self.assignments.values():
            counts[idx] += 1
        return counts

    def summary(self) -> dict:
        out = {
            "replicas": len(self.per_replica),
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "p50_ttft_s": round(self.ttft_percentile(0.50), 4),
            "p95_ttft_s": round(self.ttft_percentile(0.95), 4),
            "p99_ttft_s": round(self.ttft_percentile(0.99), 4),
            "p50_tpot_s": round(self.tpot_percentile(0.50), 5),
            "p99_tpot_s": round(self.tpot_percentile(0.99), 5),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_tok_s": round(self.goodput, 2),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "per_replica_tok_s": [round(m.throughput, 2)
                                  for m in self.per_replica],
            "per_replica_requests": self.replica_counts(),
            "switches": sum(m.switch_count for m in self.per_replica),
            "offloads": sum(m.offload_events for m in self.per_replica),
            "reloads": sum(m.reload_events for m in self.per_replica),
            "blocks_allocated": sum(m.blocks_allocated
                                    for m in self.per_replica),
        }
        if any(m.prefix for m in self.per_replica):
            out["prefix_saved_tokens"] = sum(
                m.prefix.get("saved_tokens", 0) for m in self.per_replica)
            out["prefix_hits"] = sum(
                m.prefix.get("hits", 0) for m in self.per_replica)
        return out


class ServingCluster:
    def __init__(self, replicas: Sequence[ServingEngine], router: Router):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = list(replicas)
        for i, eng in enumerate(self.replicas):
            eng.replica_id = i
        self.router = router
        self.assignments: Dict[int, int] = {}

    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    def submit(self, req: Request) -> int:
        """Route one request and enqueue it on the chosen replica."""
        idx = self.router.route(req, self.replicas)
        self.replicas[idx].submit(req)
        self.assignments[req.req_id] = idx
        return idx

    def has_work(self) -> bool:
        return any(e.has_work() for e in self.replicas)

    def peek_next_event(self) -> Optional[float]:
        evs = [t for t in (e.peek_next_event() for e in self.replicas)
               if t is not None]
        return min(evs) if evs else None

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 5_000_000,
            record_timeline: bool = True) -> ClusterMetrics:
        """Discrete-event loop: route arrivals / step the earliest replica."""
        for e in self.replicas:
            e.record_timeline = record_timeline
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        starts = [e.clock for e in self.replicas]
        pi = 0
        steps = 0
        while steps < max_steps:
            evs = [(t, i) for i, t in
                   enumerate(e.peek_next_event() for e in self.replicas)
                   if t is not None]
            t_engine = min(evs)[0] if evs else float("inf")
            if pi < len(pending) and pending[pi].arrival <= t_engine:
                self.submit(pending[pi])
                pi += 1
                continue
            if not evs:
                break
            _, idx = min(evs)
            self.replicas[idx].step()
            steps += 1

        per = [e.finalize_metrics(starts[i])
               for i, e in enumerate(self.replicas)]
        makespan = max((e.clock - starts[i]
                        for i, e in enumerate(self.replicas)
                        if e.metrics.total_tokens or e.clock > starts[i]),
                       default=0.0)
        return ClusterMetrics(per_replica=per, elapsed=makespan,
                              assignments=dict(self.assignments))
