"""Multi-replica serving cluster on a shared virtual event clock.

``ServingCluster`` owns N independent :class:`ServingEngine` replicas — each
with its own :class:`ContinuousBatchingScheduler`, MAB planner and
:class:`ElasticMemoryManager` — plus one :class:`Router` that dispatches a
single global arrival stream across them.  This is the fleet tier the paper
motivates ("dynamic request rates from millions of users"): per-replica
planners adapt their speculative length *independently* to the load each
replica actually sees.

Event-clock semantics
---------------------
Every engine advances its own virtual clock as it executes steps; the
cluster interleaves them with a classic discrete-event loop:

  1. the next *engine* event is ``min over replicas of peek_next_event()``;
  2. the next *arrival* event is the head of the global request stream;
  3. whichever is earlier happens: an arrival is admitted (or shed), routed
     (based on replica state observed *now*) and submitted, or the
     earliest-clock replica executes one ``step()``.

Because a replica is only stepped when it holds the minimum clock, replica
timelines interleave correctly in virtual time, and routing decisions see
queue/KV state no newer than the arrival instant — the same information a
real front-end would have.

Control plane (serving/controlplane.py)
---------------------------------------
Every cluster owns a :class:`ControlPlane` (telemetry-only by default).
After each replica step the plane consumes the replica's freshly finished
request stats (the EWMA TTFT/TPOT predictors and the forecast-residual
bias); at each arrival the cluster consults, in order:

  * the **autoscaler** — may ``add_replica`` (a fresh engine joins at the
    current virtual time) or ``drain_replica`` (the least-loaded replica
    stops receiving traffic, finishes its running work, then retires);
  * the **admission controller** — may *shed* the arrival at the door when
    even the best replica's predicted TTFT is hopeless (recorded in
    ``ClusterMetrics.shed``, never as an SLO miss of admitted traffic);
  * the **router** — dispatches over the routable (non-draining) replicas.

Determinism: engines, router tie-breaks, telemetry, controllers and
workload generation are all seeded/deterministic, so a cluster run is
exactly reproducible — two runs of the same config produce byte-identical
routing decisions (golden-value tested in tests/test_cluster.py and
tests/test_controlplane.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from .controlplane import ControlPlane
from .engine import ServingEngine
from .request import (Metrics, Request, RequestStats, goodput_of, percentile,
                      slo_attainment_of)
from .router import Router

# replica lifecycle states
ACTIVE, DRAINING, RETIRED = "active", "draining", "retired"


@dataclass
class ClusterMetrics:
    """Aggregate + per-replica metrics for one cluster run."""

    per_replica: List[Metrics]
    elapsed: float = 0.0              # virtual makespan across replicas
    assignments: Dict[int, int] = field(default_factory=dict)  # req -> replica
    shed: List[dict] = field(default_factory=list)   # rejected at the door
    autoscale_events: List[dict] = field(default_factory=list)
    replica_states: List[str] = field(default_factory=list)
    replica_spans: List[tuple] = field(default_factory=list)  # (start, end)

    @property
    def total_tokens(self) -> int:
        return sum(m.total_tokens for m in self.per_replica)

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.elapsed if self.elapsed else 0.0

    @property
    def latencies(self) -> List[float]:
        return [x for m in self.per_replica for x in m.latencies]

    @property
    def ttfts(self) -> List[float]:
        return [x for m in self.per_replica for x in m.ttfts]

    @property
    def mean_latency(self) -> float:
        lat = self.latencies
        return sum(lat) / len(lat) if lat else 0.0

    @property
    def mean_ttft(self) -> float:
        t = self.ttfts
        return sum(t) / len(t) if t else 0.0

    @property
    def requests(self) -> List[RequestStats]:
        return [r for m in self.per_replica for r in m.requests]

    def ttft_percentile(self, q: float) -> float:
        reqs = self.requests
        return percentile([r.ttft for r in reqs] or self.ttfts, q)

    def tpot_percentile(self, q: float) -> float:
        return percentile([r.tpot for r in self.requests], q)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(0.99)

    @property
    def slo_attainment(self) -> float:
        """Attainment of ADMITTED deadline-carrying traffic (shed requests
        are accounted separately — see ``slo_attainment_offered``)."""
        return slo_attainment_of(self.requests)

    @property
    def slo_attainment_offered(self) -> float:
        """Attainment over the OFFERED load: shed deadline-carrying
        requests count as misses (the honest fleet-level number)."""
        with_slo = [r for r in self.requests if r.slo is not None]
        shed_slo = sum(1 for s in self.shed if s.get("slo") is not None)
        total = len(with_slo) + shed_slo
        if total == 0:
            return 1.0
        return sum(r.slo_met for r in with_slo) / total

    @property
    def shed_count(self) -> int:
        return len(self.shed)

    @property
    def goodput(self) -> float:
        """Fleet tokens/s from requests that met their TTFT SLO."""
        return goodput_of(self.requests, self.elapsed, self.throughput)

    @property
    def prefix_hit_rate(self) -> float:
        """Aggregate prefix-cache hit rate across the fleet."""
        q = sum(m.prefix.get("queries", 0) for m in self.per_replica)
        h = sum(m.prefix.get("hits", 0) for m in self.per_replica)
        return h / q if q else 0.0

    @property
    def peak_replicas(self) -> int:
        """Most replicas simultaneously non-retired at any arrival/step."""
        if not self.replica_spans:
            return len(self.per_replica)
        events = []
        for start, end in self.replica_spans:
            events.append((start, 1))
            events.append((end, -1))
        peak = cur = 0
        for _, d in sorted(events, key=lambda e: (e[0], -e[1])):
            cur += d
            peak = max(peak, cur)
        return peak

    @property
    def replica_seconds(self) -> float:
        """Total replica-occupancy (virtual seconds summed over replicas)
        — the capacity cost an autoscaled fleet actually paid."""
        return sum(max(end - start, 0.0)
                   for start, end in self.replica_spans)

    def replica_counts(self) -> List[int]:
        """Requests routed to each replica."""
        n = len(self.per_replica)
        counts = [0] * n
        for idx in self.assignments.values():
            counts[idx] += 1
        return counts

    def per_replica_summary(self) -> List[dict]:
        """Per-replica breakdown: the control-plane observability surface."""
        counts = self.replica_counts()
        out = []
        for i, m in enumerate(self.per_replica):
            row = {
                "replica": i,
                "state": (self.replica_states[i]
                          if i < len(self.replica_states) else ACTIVE),
                "requests": counts[i],
                "tok_s": round(m.throughput, 2),
                "p99_ttft_s": round(m.ttft_percentile(0.99), 4),
                "slo_attainment": round(m.slo_attainment, 4),
                "offloads": m.offload_events,
            }
            if m.prefix:
                row["prefix_hit_rate"] = round(m.prefix_hit_rate, 4)
            out.append(row)
        return out

    def summary(self) -> dict:
        out = {
            "replicas": len(self.per_replica),
            "throughput_tok_s": round(self.throughput, 2),
            "mean_latency_s": round(self.mean_latency, 4),
            "mean_ttft_s": round(self.mean_ttft, 4),
            "p50_ttft_s": round(self.ttft_percentile(0.50), 4),
            "p95_ttft_s": round(self.ttft_percentile(0.95), 4),
            "p99_ttft_s": round(self.ttft_percentile(0.99), 4),
            "p50_tpot_s": round(self.tpot_percentile(0.50), 5),
            "p99_tpot_s": round(self.tpot_percentile(0.99), 5),
            "slo_attainment": round(self.slo_attainment, 4),
            "goodput_tok_s": round(self.goodput, 2),
            "total_tokens": self.total_tokens,
            "elapsed_s": round(self.elapsed, 3),
            "per_replica_tok_s": [round(m.throughput, 2)
                                  for m in self.per_replica],
            "per_replica_requests": self.replica_counts(),
            "per_replica": self.per_replica_summary(),
            "switches": sum(m.switch_count for m in self.per_replica),
            "offloads": sum(m.offload_events for m in self.per_replica),
            "reloads": sum(m.reload_events for m in self.per_replica),
            "blocks_allocated": sum(m.blocks_allocated
                                    for m in self.per_replica),
        }
        if self.shed or self.autoscale_events:
            out["shed_count"] = self.shed_count
            out["slo_attainment_offered"] = round(
                self.slo_attainment_offered, 4)
        if self.autoscale_events:
            out["peak_replicas"] = self.peak_replicas
            out["replica_seconds"] = round(self.replica_seconds, 3)
            out["autoscale"] = {
                "adds": sum(1 for e in self.autoscale_events
                            if e["kind"] == "add"),
                "drains": sum(1 for e in self.autoscale_events
                              if e["kind"] == "drain"),
                "retires": sum(1 for e in self.autoscale_events
                               if e["kind"] == "retire"),
            }
        if any(m.prefix for m in self.per_replica):
            out["prefix_saved_tokens"] = sum(
                m.prefix.get("saved_tokens", 0) for m in self.per_replica)
            out["prefix_hits"] = sum(
                m.prefix.get("hits", 0) for m in self.per_replica)
            out["prefix_hit_rate"] = round(self.prefix_hit_rate, 4)
        return out


class ServingCluster:
    def __init__(self, replicas: Sequence[ServingEngine], router: Router,
                 *, control: Optional[ControlPlane] = None,
                 replica_factory: Optional[
                     Callable[[int], ServingEngine]] = None):
        if not replicas:
            raise ValueError("cluster needs at least one replica")
        self.replicas = list(replicas)
        for i, eng in enumerate(self.replicas):
            eng.replica_id = i
        self.router = router
        self.control = control if control is not None else ControlPlane()
        # headroom-based routers share the cluster's telemetry book
        if getattr(router, "control", None) is None:
            router.control = self.control
        self.replica_factory = replica_factory
        self.state: List[str] = [ACTIVE] * len(self.replicas)
        self.assignments: Dict[int, int] = {}
        self.shed: List[dict] = []
        self.autoscale_events: List[dict] = []
        self._starts = [e.clock for e in self.replicas]
        self._retired_at: Dict[int, float] = {}
        self._record_timeline = True

    # ------------------------------------------------------------------
    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    @property
    def num_active(self) -> int:
        return sum(1 for s in self.state if s == ACTIVE)

    def routable_replicas(self) -> List[ServingEngine]:
        """Replicas the router may dispatch to: active only — draining
        replicas finish their assigned work but accept nothing new.

        A fully drained fleet (the operator drained everything by hand)
        still has to land arrivals somewhere deterministic: fall back to
        the draining replicas, and past that to the whole fleet — a
        retired engine is just an idle engine wearing a control-plane
        label, and serving there beats crashing the router."""
        out = [e for e, s in zip(self.replicas, self.state) if s == ACTIVE]
        out = out or [e for e, s in zip(self.replicas, self.state)
                      if s != RETIRED]
        return out or list(self.replicas)

    # ------------------------------------------------------------------
    # elastic fleet surface
    # ------------------------------------------------------------------
    def add_replica(self, now: float) -> int:
        """Bring a fresh replica online at virtual time ``now`` (its clock
        starts there — no retroactive work) and open it for routing."""
        if self.replica_factory is None:
            raise RuntimeError("cluster has no replica_factory")
        rid = len(self.replicas)
        eng = self.replica_factory(rid)
        eng.replica_id = rid
        eng.clock = max(eng.clock, now)
        eng.record_timeline = self._record_timeline
        self.replicas.append(eng)
        self.state.append(ACTIVE)
        self._starts.append(eng.clock)
        self.autoscale_events.append(
            {"kind": "add", "at": now, "replica": rid})
        return rid

    def drain_replica(self, idx: int, now: float) -> None:
        """Stop routing to replica ``idx``; it finishes every request it
        already owns (pending + waiting + running) and then retires —
        draining never drops work."""
        if self.state[idx] != ACTIVE:
            return
        self.state[idx] = DRAINING
        self.autoscale_events.append(
            {"kind": "drain", "at": now, "replica": idx})
        self._maybe_retire(idx, now)

    def _maybe_retire(self, idx: int, now: float) -> None:
        if self.state[idx] == DRAINING and not self.replicas[idx].has_work():
            self.state[idx] = RETIRED
            self._retired_at[idx] = max(now, self.replicas[idx].clock)
            self.autoscale_events.append(
                {"kind": "retire", "at": self._retired_at[idx],
                 "replica": idx})

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: Optional[float] = None) -> int:
        """Route one request and enqueue it on the chosen replica."""
        if now is None:
            now = req.arrival
        routable = self.routable_replicas()
        pos = self.router.route(req, routable, now=now)
        eng = routable[pos]
        self.control.note_dispatch(eng, req, now)
        eng.submit(req)
        self.assignments[req.req_id] = eng.replica_id
        return eng.replica_id

    def _handle_arrival(self, req: Request) -> Optional[int]:
        """Autoscale -> admission -> route, at the arrival instant.
        Returns the replica id, or None when the request was shed."""
        self.control.begin_arrival()
        try:
            return self._handle_arrival_inner(req)
        finally:
            self.control.end_arrival()

    def _handle_arrival_inner(self, req: Request) -> Optional[int]:
        now = req.arrival
        scaler = self.control.autoscaler
        admission = self.control.admission
        min_forecast = None
        if scaler is not None or admission is not None:
            routable = self.routable_replicas()
            min_forecast = min(self.control.forecast_ttft(e, req, now)
                               for e in routable)
        if scaler is not None:
            loads = [e.load for e, s in zip(self.replicas, self.state)
                     if s == ACTIVE]
            n_alive = sum(1 for s in self.state if s != RETIRED)
            action = scaler.decide(now, self.num_active, loads,
                                   min_forecast, req.slo, n_alive=n_alive)
            if action == "up" and self.replica_factory is not None:
                self.add_replica(now)
            elif action == "down" and self.num_active > 1:
                active = [(e.load, e.replica_id) for e, s
                          in zip(self.replicas, self.state) if s == ACTIVE]
                _, idx = min(active)
                self.drain_replica(idx, now)
            if action is not None:
                # the routable set changed: a fresh replica is dispatchable
                # immediately, and a drained one no longer is — the
                # admission decision must see the post-action fleet (a
                # drained replica's low forecast must not keep the door
                # open for traffic it can no longer take)
                min_forecast = min(self.control.forecast_ttft(e, req, now)
                                   for e in self.routable_replicas())
        if admission is not None and min_forecast is not None \
                and admission.should_shed(req, min_forecast):
            self.shed.append({"req_id": req.req_id, "at": now,
                              "slo": req.slo})
            self.control.note_shed(now)
            return None
        return self.submit(req, now=now)

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        return any(e.has_work() for e in self.replicas)

    def peek_next_event(self) -> Optional[float]:
        evs = [t for t in (e.peek_next_event() for e in self.replicas)
               if t is not None]
        return min(evs) if evs else None

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 5_000_000,
            record_timeline: bool = True) -> ClusterMetrics:
        """Discrete-event loop: route arrivals / step the earliest replica."""
        self._record_timeline = record_timeline
        for e in self.replicas:
            e.record_timeline = record_timeline
        pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
        self._starts = [e.clock for e in self.replicas]
        pi = 0
        steps = 0
        while steps < max_steps:
            evs = [(t, i) for i, t in
                   enumerate(e.peek_next_event() for e in self.replicas)
                   if t is not None]
            t_engine = min(evs)[0] if evs else float("inf")
            if pi < len(pending) and pending[pi].arrival <= t_engine:
                self._handle_arrival(pending[pi])
                pi += 1
                continue
            if not evs:
                break
            _, idx = min(evs)
            self.replicas[idx].step()
            self.control.observe_step(self.replicas[idx])
            self._maybe_retire(idx, self.replicas[idx].clock)
            steps += 1

        per = [e.finalize_metrics(self._starts[i])
               for i, e in enumerate(self.replicas)]
        makespan = max((e.clock - self._starts[i]
                        for i, e in enumerate(self.replicas)
                        if e.metrics.total_tokens or e.clock > self._starts[i]),
                       default=0.0)
        end = max((e.clock for e in self.replicas), default=0.0)
        spans = [(self._starts[i],
                  self._retired_at.get(i, max(end, self._starts[i])))
                 for i in range(len(self.replicas))]
        return ClusterMetrics(per_replica=per, elapsed=makespan,
                              assignments=dict(self.assignments),
                              shed=list(self.shed),
                              autoscale_events=list(self.autoscale_events),
                              replica_states=list(self.state),
                              replica_spans=spans)
