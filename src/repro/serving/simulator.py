"""Simulated execution backend — paper-scale serving on the analytical tier.

Latencies come from the roofline cost model; acceptance is a per-request
Bernoulli chain (a request's per-token acceptance probability alpha_i is
drawn from the dataset's Beta distribution).  Everything else — scheduler,
planner, elastic memory manager — is the real thing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..core.bandits import Policy, make_policy
from ..core.cswitch import CSwitchTable
from .cluster import DECODE, PREFILL, ServingCluster
from .controlplane import (AdmissionController, AutoscaleController,
                           BrownoutController, ControlPlane,
                           DecodePoolAutoscaler, HandoffPricer)
from .costmodel import HardwareProfile, RooflineCostModel, TPU_V5E, kv_bytes_per_token
from .engine import ServingEngine, StepOutcome
from .kv_cache import BlockManager
from .memory_manager import ElasticMemoryManager
from .request import Request, Sequence
from .router import make_router
from .scheduler import ContinuousBatchingScheduler


class SimulatedBackend:
    def __init__(self, target: ModelConfig, draft: ModelConfig,
                 cost_model: RooflineCostModel, *, seed: int = 0,
                 block_size: int = 16):
        self.target = target
        self.draft = draft
        self.cm = cost_model
        self.rng = np.random.default_rng(seed)
        self.block_size = block_size

    def host_transfer_latency(self, n_spill: int, n_restore: int) -> float:
        """Modelled host KV tier transfer cost for one drain (engine
        ``_drain_host_transfers``).  Restores gate the admitted sequence's
        prefill, so their host→device copy is synchronous and priced at the
        PCIe-analogue ``host_link_bw`` over both pools' block bytes; spills
        ride the async DMA stream (§6.2 semantics, same as draft offload)
        and cost nothing on the critical path."""
        if n_restore <= 0:
            return 0.0
        per_tok = (kv_bytes_per_token(self.target)
                   + kv_bytes_per_token(self.draft))
        return n_restore * self.block_size * per_tok / self.cm.hw.host_link_bw

    def kv_transfer_seconds(self, n_tokens: int) -> float:
        """Modelled prefill→decode KV migration time for one handoff
        (disaggregated fleets): both pools' KV bytes for the prompt, moved
        over the inter-replica interconnect — ICI where the profile has
        one, else the PCIe-analogue host link (the PR 6 spill path's
        bandwidth class) — plus one fixed step overhead for the batched
        block-descriptor exchange.  This is what the ``HandoffPricer``
        charges against the queue-delay forecast saved."""
        per_tok = (kv_bytes_per_token(self.target)
                   + kv_bytes_per_token(self.draft))
        bw = self.cm.hw.ici_bw or self.cm.hw.host_link_bw
        return n_tokens * per_tok / bw + self.cm.hw.step_overhead

    # ------------------------------------------------------------------
    def _ctx(self, seqs: List[Sequence]) -> int:
        return max((s.context_len for s in seqs), default=1)

    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float:
        # continuous batching processes prefill as a token stream (no
        # padded-batch waste): cost ~ total prompt tokens + one weight pass
        total = sum(s.request.prompt_len for s in seqs)
        t = self.cm.prefill_latency(self.target, 1, total)
        if with_draft:
            t += self.cm.prefill_latency(self.draft, 1, total)
        return t

    def draft_catchup(self, seqs: List[Sequence]) -> float:
        delta_max = max((s.delta for s in seqs), default=0)
        if delta_max == 0:
            return 0.0
        return self.cm.prefill_latency(self.draft, len(seqs), delta_max)

    def hybrid_step(self, chunks: List, decode: List[Sequence], gamma: int,
                    *, with_draft: bool) -> StepOutcome:
        """Mixed batch: prefill chunks fused with the decode batch.

        ``n_committed`` is per DECODE sequence; chunk progress is recorded by
        the engine.  With no chunks in flight this is exactly ``step`` (same
        cost, same acceptance draws)."""
        prefill_tokens = sum(n for _, n in chunks)
        if prefill_tokens == 0:
            return self.step(decode, gamma)
        assert gamma == 0, "speculation is disabled while chunks are in flight"
        B = len(decode)
        ctx = self._ctx(decode) if decode else 1
        prefill_ctx = max((s.prefilled + n for s, n in chunks), default=1)
        lat = self.cm.hybrid_step_latency(self.target, prefill_tokens, B, ctx,
                                          prefill_ctx=prefill_ctx)
        if with_draft:
            # the draft prefills the same chunk stream to keep its KV current
            lat += self.cm.prefill_latency(self.draft, 1, prefill_tokens)
        n = [min(1, s.request.output_len - s.generated) for s in decode]
        return StepOutcome(n_committed=n, latency=lat)

    def step(self, seqs: List[Sequence], gamma: int) -> StepOutcome:
        B = len(seqs)
        ctx = self._ctx(seqs)
        if gamma == 0:
            lat = self.cm.ar_step_latency(self.target, B, ctx)
            n = [min(1, s.request.output_len - s.generated) for s in seqs]
            return StepOutcome(n_committed=n, latency=lat)
        lat = self.cm.spec_step_latency(self.target, self.draft, B, ctx, gamma)
        n_committed = []
        for s in seqs:
            # chain acceptance: accept while Bernoulli(alpha) succeeds
            acc = 0
            while acc < gamma and self.rng.uniform() < s.request.alpha:
                acc += 1
            n = acc + 1  # bonus / correction token
            n = min(n, s.request.output_len - s.generated)
            n_committed.append(max(n, 0))
        return StepOutcome(n_committed=n_committed, latency=lat)

    def release(self, seq: Sequence) -> None:
        pass


# ---------------------------------------------------------------------------
# Convenience constructor for paper-style experiments
# ---------------------------------------------------------------------------


@dataclass
class SimConfig:
    target: ModelConfig
    draft: ModelConfig
    hw: HardwareProfile = TPU_V5E
    gamma_max: int = 5
    block_size: int = 16
    max_batch: int = 64
    chunk_tokens: int = 0     # >0: chunked-prefill hybrid batching budget
    prefix_caching: bool = False   # CoW prefix sharing (chunked path)
    prefill_order: str = "fifo"    # waiting-queue admission: fifo | slo
    tau_low_frac: float = 0.1
    t_persist: int = 3
    enable_offload: bool = True
    kv_reserve_frac: float = 0.1
    seed: int = 0
    num_blocks: Optional[int] = None  # explicit device pool size (None =
                                      # derive from the roofline HBM budget)
    kv_offload: bool = False  # host-memory spill tier for evicted prefix
                              # blocks (requires prefix_caching)
    host_kv_blocks: int = 0   # host tier capacity (0 = 4x the device pool)


def build_sim_engine(cfg: SimConfig, policy_name: str = "nightjar",
                     *, policy: Optional[Policy] = None,
                     trace=None) -> ServingEngine:
    cm = RooflineCostModel(cfg.hw)
    backend = SimulatedBackend(cfg.target, cfg.draft, cm, seed=cfg.seed,
                               block_size=cfg.block_size)

    capacity_tokens = cm.kv_capacity_tokens(cfg.target, cfg.draft,
                                            reserve_frac=cfg.kv_reserve_frac)
    num_blocks = (cfg.num_blocks if cfg.num_blocks is not None
                  else max(capacity_tokens // cfg.block_size, 64))
    host_store = None
    if cfg.kv_offload and cfg.prefix_caching:
        from .kv_cache import HostKVStore
        host_store = HostKVStore(cfg.host_kv_blocks or 4 * num_blocks)
    bm = BlockManager(num_blocks, cfg.block_size,
                      prefix_caching=cfg.prefix_caching,
                      host_store=host_store)
    sched = ContinuousBatchingScheduler(
        bm, max_batch=cfg.max_batch,
        chunk_tokens=cfg.chunk_tokens if cfg.chunk_tokens > 0 else None,
        prefill_order=cfg.prefill_order)

    block_bytes = cfg.block_size * kv_bytes_per_token(cfg.target)
    draft_blocks = max(math.ceil(cm.weight_bytes(cfg.draft) / block_bytes), 1)

    memmgr = None
    if cfg.enable_offload:
        memmgr = ElasticMemoryManager(
            bm,
            draft_blocks=draft_blocks,
            tau_low_frac=cfg.tau_low_frac,
            t_persist=cfg.t_persist,
            offload_latency=cm.offload_latency(cfg.draft),
            reload_latency=cm.reload_latency(cfg.draft),
            migrate_fn=lambda plan: len(plan) * bm.block_size
            * kv_bytes_per_token(cfg.target) / cfg.hw.hbm_bw,
        )

    if policy is None:
        cswitch = CSwitchTable.from_cost_model(cm, cfg.draft)
        policy = make_policy(policy_name, cfg.gamma_max, cswitch=cswitch,
                             seed=cfg.seed)
    eng = ServingEngine(backend, sched, policy, memmgr,
                        gamma_max=cfg.gamma_max)
    if trace is not None:
        eng.attach_trace(trace)
    return eng


def build_sim_cluster(cfg: SimConfig, n_replicas: int,
                      policy_name: str = "nightjar", *,
                      router: str = "jsq",
                      router_kwargs: Optional[dict] = None,
                      shed_factor: Optional[float] = None,
                      class_weights: Optional[dict] = None,
                      autoscale: Optional[dict] = None,
                      disaggregate: Optional[dict] = None,
                      fault_plan=None,
                      retry_policy=None,
                      brownout=None,
                      cancels=None,
                      trace=None) -> ServingCluster:
    """N independent simulated replicas behind one router + control plane.

    Every replica gets its OWN scheduler, planner, elastic memory manager
    and acceptance RNG (seed offset by replica index so replicas do not see
    correlated acceptance draws), exactly like N separate serving processes
    behind a front-end.  Replicas the autoscaler adds later come from the
    same seeded factory (seed offset by replica id), so an elastic run is
    exactly as reproducible as a static one.

    ``shed_factor`` enables admission control (shed at the door when every
    replica's predicted TTFT exceeds ``slo * shed_factor``); ``autoscale``
    is a kwargs dict for :class:`AutoscaleController` (e.g.
    ``dict(min_replicas=1, max_replicas=4)``) enabling elastic scaling —
    the cluster then STARTS at ``min_replicas`` and grows on demand.

    ``disaggregate`` splits the fleet into prefill and decode pools:
    ``dict(prefill=2, decode=2)`` (overrides ``n_replicas``), optionally
    ``margin_s`` (pricer hysteresis) and ``decode_autoscale`` (kwargs for
    :class:`DecodePoolAutoscaler`).  Arrivals land on the prefill pool
    (which must run chunked prefill) and migrate to a decode replica
    after prefill whenever the priced KV handoff beats staying put.

    ``fault_plan`` (a :class:`~repro.serving.faults.FaultPlan` or a spec
    string for :meth:`FaultPlan.parse`) arms a seeded
    :class:`~repro.serving.faults.FaultInjector` (seed = ``cfg.seed``, so
    the same plan + seed reproduces the exact same fault schedule);
    ``retry_policy`` overrides the crash-recovery
    :class:`~repro.serving.faults.RetryPolicy`.

    ``class_weights`` makes admission shedding priority-aware (per-class
    threshold multipliers — see :class:`AdmissionController`).
    ``brownout`` arms the fleet brownout ladder: a kwargs dict for
    :class:`BrownoutController` (or a pre-built instance); ``cancels`` is
    an explicit client-cancellation schedule of ``(t, req_id)`` pairs
    (e.g. ``workload.cancellation_storm``).

    ``trace`` attaches a :class:`~repro.serving.observability.TraceRecorder`
    through the whole fleet (engines, brownout controller, fault injector;
    replicas added later inherit it)."""

    def factory(i: int) -> ServingEngine:
        return build_sim_engine(replace(cfg, seed=cfg.seed + i), policy_name)

    admission = None
    if shed_factor is not None and shed_factor > 0:
        admission = AdmissionController(shed_factor=shed_factor,
                                        class_weights=class_weights)
    autoscaler = None
    if autoscale is not None:
        autoscaler = AutoscaleController(**autoscale)
        n_replicas = autoscaler.min_replicas
    roles = None
    pricer = None
    decode_autoscaler = None
    if disaggregate is not None:
        if cfg.chunk_tokens <= 0:
            raise ValueError("disaggregation requires chunked prefill "
                             "(cfg.chunk_tokens > 0)")
        n_prefill = int(disaggregate.get("prefill", max(n_replicas // 2, 1)))
        n_decode = int(disaggregate.get("decode",
                                        max(n_replicas - n_prefill, 1)))
        if autoscaler is not None:
            n_prefill = autoscaler.min_replicas
        roles = [PREFILL] * n_prefill + [DECODE] * n_decode
        n_replicas = len(roles)
        da = disaggregate.get("decode_autoscale")
        if da is not None:
            decode_autoscaler = DecodePoolAutoscaler(**da)
    faults = None
    if fault_plan is not None:
        from .faults import FaultInjector, FaultPlan
        plan = (FaultPlan.parse(fault_plan) if isinstance(fault_plan, str)
                else fault_plan)
        if not plan.empty:
            faults = FaultInjector(plan, seed=cfg.seed)
    bo = None
    if brownout is not None:
        bo = (brownout if isinstance(brownout, BrownoutController)
              else BrownoutController(**brownout))
    engines = [factory(i) for i in range(n_replicas)]
    control = ControlPlane(admission=admission, autoscaler=autoscaler)
    if disaggregate is not None:
        pricer = HandoffPricer(control,
                               margin_s=disaggregate.get("margin_s", 0.0))
    cluster = ServingCluster(engines, make_router(router,
                                                  **(router_kwargs or {})),
                             control=control, replica_factory=factory,
                             roles=roles, pricer=pricer,
                             decode_autoscaler=decode_autoscaler,
                             faults=faults, retry_policy=retry_policy,
                             brownout=bo, cancels=cancels)
    if trace is not None:
        cluster.attach_trace(trace)
    return cluster
