"""Nightjar serving stack.

Single replica: ``engine.ServingEngine`` — a steppable, clock-driven driver
(``submit`` / ``step(now)`` / ``peek_next_event``) over a pluggable backend
(simulated roofline tier or real JAX tier), coupling the continuous-batching
scheduler, the MAB planner and the elastic memory manager.

Fleet: ``cluster.ServingCluster`` — N replicas advanced by a shared virtual
event clock behind a ``router.Router`` dispatch policy (round-robin /
join-shortest-queue / KV-headroom / predicted-TTFT SLO headroom / sticky
prefix affinity), governed by the ``controlplane.ControlPlane`` — per-replica
EWMA telemetry + queue-delay forecasts feeding admission control (load
shedding with hysteresis) and elastic replica autoscaling
(``add_replica`` / ``drain_replica`` on the shared clock).
``simulator.build_sim_cluster`` builds the whole thing on the analytical
tier.
"""
from . import (cluster, controlplane, costmodel, engine, kv_cache,  # noqa: F401
               memory_manager, request, router, scheduler, simulator,
               workload)
