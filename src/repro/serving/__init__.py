"""Nightjar serving stack.

Single replica: ``engine.ServingEngine`` — a steppable, clock-driven driver
(``submit`` / ``step(now)`` / ``peek_next_event``) over a pluggable backend
(simulated roofline tier or real JAX tier), coupling the continuous-batching
scheduler, the MAB planner and the elastic memory manager.

Fleet: ``cluster.ServingCluster`` — N replicas advanced by a shared virtual
event clock behind a ``router.Router`` dispatch policy (round-robin /
join-shortest-queue / KV-headroom-aware).  ``simulator.build_sim_cluster``
builds the whole thing on the analytical tier.
"""
from . import (cluster, costmodel, engine, kv_cache, memory_manager,  # noqa: F401
               request, router, scheduler, simulator, workload)
