from . import (costmodel, engine, kv_cache, memory_manager, request,  # noqa: F401
               scheduler, simulator, workload)
