"""Deterministic fault injection for the serving cluster.

A :class:`FaultPlan` is a declarative description of failures to inject
into a `ServingCluster` run on the shared virtual clock:

- **crash**: a replica fails permanently at virtual time ``t`` — its
  in-flight work is lost, its blocks are gone (FAILED state, distinct
  from DRAINING).
- **straggler**: a transient window ``[start, end)`` during which one
  replica's step latency is multiplied by ``slowdown`` (a slow NIC, a
  noisy neighbour).  Multiple overlapping windows compound.
- **handoff**: disagg KV transfers failing or timing out during a
  window, with a per-fault count budget so capped retries can drain it.
- **corrupt**: host-KV offload records on one replica having their
  payload corrupted at time ``t`` (a bad DMA, bit rot) — caught by the
  blake2b record checksum on restore, never served.
- **cancelstorm**: a seeded fraction of the requests in flight at
  ``start`` being client-cancelled at seeded times inside
  ``[start, end)`` (a bulk client disconnect, an upstream timeout
  sweep).  Victims and times come from a dedicated RNG stream so the
  storm never perturbs corruption draws.

Everything is validated at construction and seeded, so two runs of the
same plan are byte-identical — the same determinism contract every
golden e2e in this repo relies on.

The injector itself holds only pure-function queries plus small
consume-once budgets; the cluster event loop owns the clock and asks.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "CrashFault",
    "StragglerFault",
    "HandoffFault",
    "CorruptionFault",
    "CancelStorm",
    "FaultPlan",
    "RetryPolicy",
    "FaultInjector",
]


# ---------------------------------------------------------------------------
# Fault descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CrashFault:
    """Replica ``replica`` fails permanently at virtual time ``at``."""

    replica: int
    at: float


@dataclass(frozen=True)
class StragglerFault:
    """Replica ``replica`` runs ``slowdown``x slower in [start, end)."""

    replica: int
    start: float
    end: float
    slowdown: float


@dataclass(frozen=True)
class HandoffFault:
    """KV handoff transfers fail during [start, end).

    ``mode`` is "fail" (transfer errors immediately, costing one
    transfer time) or "timeout" (costs ``timeout_factor`` transfer
    times before the failure surfaces).  ``count`` bounds how many
    transfer attempts this fault poisons; capped retries can therefore
    outlast it.  count <= 0 means unbounded within the window.
    """

    start: float
    end: float
    mode: str = "fail"
    count: int = 0
    timeout_factor: float = 3.0


@dataclass(frozen=True)
class CorruptionFault:
    """Corrupt ``count`` unpinned host-KV records on ``replica`` at ``at``."""

    replica: int
    at: float
    count: int = 1


@dataclass(frozen=True)
class CancelStorm:
    """Cancel ``frac`` of the in-flight requests at seeded times in
    ``[start, end)`` — a bulk client disconnect."""

    frac: float
    start: float
    end: float


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FaultPlan:
    """Validated, declarative collection of faults.

    Raises ``ValueError`` at construction for negative times, more than
    one crash per replica, inverted straggler windows or slowdown < 1.
    """

    crashes: Tuple[CrashFault, ...] = ()
    stragglers: Tuple[StragglerFault, ...] = ()
    handoffs: Tuple[HandoffFault, ...] = ()
    corruptions: Tuple[CorruptionFault, ...] = ()
    cancelstorms: Tuple[CancelStorm, ...] = ()

    def __post_init__(self) -> None:
        seen = set()
        for c in self.crashes:
            if c.at < 0:
                raise ValueError(f"crash time must be >= 0, got {c.at}")
            if c.replica < 0:
                raise ValueError(f"crash replica must be >= 0, got {c.replica}")
            if c.replica in seen:
                raise ValueError(
                    f"replica {c.replica} has more than one crash fault; "
                    "a crashed replica never comes back")
            seen.add(c.replica)
        for s in self.stragglers:
            if s.start < 0 or s.end < 0:
                raise ValueError(f"straggler times must be >= 0: {s}")
            if s.end <= s.start:
                raise ValueError(f"straggler window must have end > start: {s}")
            if s.slowdown < 1.0:
                raise ValueError(f"straggler slowdown must be >= 1: {s}")
        for h in self.handoffs:
            if h.start < 0 or h.end <= h.start:
                raise ValueError(f"handoff window must have 0 <= start < end: {h}")
            if h.mode not in ("fail", "timeout"):
                raise ValueError(f"handoff mode must be fail|timeout: {h}")
        for k in self.corruptions:
            if k.at < 0 or k.replica < 0 or k.count < 1:
                raise ValueError(f"corruption fault invalid: {k}")
        for cs in self.cancelstorms:
            if not 0.0 < cs.frac <= 1.0:
                raise ValueError(f"cancelstorm frac must be in (0, 1]: {cs}")
            if cs.start < 0 or cs.end <= cs.start:
                raise ValueError(
                    f"cancelstorm window must have 0 <= start < end: {cs}")

    @property
    def empty(self) -> bool:
        return not (self.crashes or self.stragglers
                    or self.handoffs or self.corruptions
                    or self.cancelstorms)

    # -- CLI spec ----------------------------------------------------------
    #
    #   crash:<replica>@<t>
    #   straggle:<replica>@<start>..<end>x<slowdown>
    #   handoff:<fail|timeout>@<start>..<end>[#<count>]
    #   corrupt:<replica>@<t>[#<count>]
    #   cancelstorm:<frac>@<start>..<end>
    #
    # joined by ';', e.g.  "crash:0@2.5;straggle:1@3..5x4;handoff:fail@2..4"

    @staticmethod
    def parse(spec: str) -> "FaultPlan":
        crashes: List[CrashFault] = []
        stragglers: List[StragglerFault] = []
        handoffs: List[HandoffFault] = []
        corruptions: List[CorruptionFault] = []
        cancelstorms: List[CancelStorm] = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            try:
                kind, rest = part.split(":", 1)
                head, at = rest.split("@", 1)
            except ValueError:
                raise ValueError(f"bad fault spec {part!r}") from None
            if kind == "crash":
                crashes.append(CrashFault(int(head), float(at)))
            elif kind == "straggle":
                window, x = at.split("x", 1)
                start, end = window.split("..", 1)
                stragglers.append(StragglerFault(
                    int(head), float(start), float(end), float(x)))
            elif kind == "handoff":
                count = 0
                if "#" in at:
                    at, c = at.split("#", 1)
                    count = int(c)
                start, end = at.split("..", 1)
                handoffs.append(HandoffFault(
                    float(start), float(end), mode=head, count=count))
            elif kind == "corrupt":
                count = 1
                if "#" in at:
                    at, c = at.split("#", 1)
                    count = int(c)
                corruptions.append(CorruptionFault(int(head), float(at), count))
            elif kind == "cancelstorm":
                start, end = at.split("..", 1)
                cancelstorms.append(CancelStorm(
                    float(head), float(start), float(end)))
            else:
                raise ValueError(f"unknown fault kind {kind!r} in {part!r}")
        return FaultPlan(tuple(crashes), tuple(stragglers),
                         tuple(handoffs), tuple(corruptions),
                         tuple(cancelstorms))


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with a cap and a hard retry budget.

    Attempt numbers are 1-based: ``backoff(1)`` is the delay before the
    first retry.  A request whose attempts exceed ``budget`` is
    surfaced as failed in metrics — never silently dropped.

    ``jitter_frac`` spreads retries by up to ±that fraction of the
    deterministic delay (thundering-herd decorrelation after a crash
    re-dispatches a whole replica's worth of work at once).  Jitter is
    strictly opt-in AND requires a caller-supplied ``rng`` — the default
    policy's schedule is a pure function of ``attempt``, which every
    golden chaos stream depends on.  The cluster threads the injector's
    dedicated ``retry_rng`` stream through, so jittered runs stay
    byte-reproducible under the same seed without perturbing any other
    fault draw.
    """

    budget: int = 3
    backoff_base: float = 0.05
    backoff_cap: float = 1.0
    jitter_frac: float = 0.0

    def __post_init__(self) -> None:
        if self.budget < 0:
            raise ValueError("retry budget must be >= 0")
        if self.backoff_base <= 0 or self.backoff_cap <= 0:
            raise ValueError("backoff base/cap must be > 0")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")

    def backoff(self, attempt: int, *, rng=None) -> float:
        if attempt < 1:
            raise ValueError("attempt is 1-based")
        delay = min(self.backoff_base * (2.0 ** (attempt - 1)),
                    self.backoff_cap)
        if self.jitter_frac > 0.0 and rng is not None:
            delay *= 1.0 + self.jitter_frac * float(rng.uniform(-1.0, 1.0))
        return delay

    def exhausted(self, attempt: int) -> bool:
        return attempt > self.budget


# ---------------------------------------------------------------------------
# Injector
# ---------------------------------------------------------------------------


class FaultInjector:
    """Seeded runtime view of a :class:`FaultPlan`.

    Pure queries (``latency_multiplier``) plus consume-once budgets
    (``next_handoff_fault``); timed one-shot events (crash, corruption)
    are surfaced once via :meth:`timed_events` for the cluster loop to
    schedule.  Determinism: with a fixed plan + seed, every answer is a
    pure function of the call sequence, which the virtual clock makes
    reproducible.
    """

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        # dedicated streams: storm victim/time draws and retry jitter must
        # not advance the corruption RNG (or each other) — adding a storm
        # or enabling jitter leaves every other fault's draws byte-identical
        self.cancel_rng = np.random.default_rng([seed, 0xCA9C])
        self.retry_rng = np.random.default_rng([seed, 0xB0FF])
        # per-HandoffFault remaining poison budget (0 = unbounded)
        self._handoff_left = [h.count for h in plan.handoffs]
        self.stats = {"handoff_faults": 0, "corrupted_records": 0,
                      "storm_cancels": 0}
        # observability seam: the cluster's attach_trace wires this so
        # consumed faults land in the trace as fleet instants
        self.trace = None

    def _trace_instant(self, name: str, t: float, **args) -> None:
        tr = self.trace
        if tr is not None and tr.enabled:
            tr.instant("fault", name, t, args=args)

    # -- timed one-shots ---------------------------------------------------

    def timed_events(self) -> List[Tuple[float, str, object]]:
        """(time, kind, fault) for crash/corrupt/cancelstorm events,
        time-sorted.  A storm fires ONCE at its window start: victims are
        drawn from the requests in flight at that instant and their cancel
        times land inside the window."""
        evs: List[Tuple[float, str, object]] = []
        for c in self.plan.crashes:
            evs.append((c.at, "crash", c))
        for k in self.plan.corruptions:
            evs.append((k.at, "corrupt", k))
        for s in self.plan.cancelstorms:
            evs.append((s.start, "cancelstorm", s))
        evs.sort(key=lambda e: (e[0], e[1]))
        return evs

    # -- cancellation storms ----------------------------------------------

    def pick_cancel_victims(self, storm: CancelStorm,
                            live_ids) -> List[Tuple[float, int]]:
        """Seeded (cancel_time, req_id) schedule for one storm: a
        ``storm.frac`` sample (at least one when any are live) of the
        in-flight ids, each at a uniform time in the storm window."""
        ids = sorted(live_ids)
        if not ids:
            return []
        n = min(max(int(round(storm.frac * len(ids))), 1), len(ids))
        idx = self.cancel_rng.choice(len(ids), size=n, replace=False)
        times = self.cancel_rng.uniform(storm.start, storm.end, size=n)
        out = sorted((float(t), ids[int(i)]) for t, i in zip(times, idx))
        self.stats["storm_cancels"] += n
        self._trace_instant("cancelstorm", storm.start, victims=n)
        return out

    # -- stragglers --------------------------------------------------------

    def latency_multiplier(self, replica: int, t: float) -> float:
        """Product of every straggler window covering (replica, t)."""
        mult = 1.0
        for s in self.plan.stragglers:
            if s.replica == replica and s.start <= t < s.end:
                mult *= s.slowdown
        return mult

    # -- handoffs ----------------------------------------------------------

    def next_handoff_fault(self, t: float) -> Optional[HandoffFault]:
        """Consume one poisoned-transfer budget covering time ``t``.

        Returns the fault a transfer attempt at ``t`` hits, or None if
        transfers are healthy.  Each call consumes one unit of the
        matched fault's count budget (unbounded when count <= 0), so a
        capped-retry loop can outlast a bounded fault.
        """
        for i, h in enumerate(self.plan.handoffs):
            if h.start <= t < h.end:
                if h.count > 0:
                    if self._handoff_left[i] <= 0:
                        continue
                    self._handoff_left[i] -= 1
                self.stats["handoff_faults"] += 1
                self._trace_instant(f"handoff_{h.mode}", t)
                return h
        return None

    # -- corruption --------------------------------------------------------

    def corrupt_host_records(self, host_store, fault: CorruptionFault) -> int:
        """Flip payload bytes of up to ``fault.count`` unpinned records.

        Pinned records (an in-flight restore already holds them) are
        never touched — the device copy is authoritative mid-transfer.
        Selection is seeded so runs reproduce.  Returns #corrupted.
        """
        victims = [h for h in host_store.records if h not in host_store.pinned]
        if not victims:
            return 0
        n = min(fault.count, len(victims))
        idx = self.rng.choice(len(victims), size=n, replace=False)
        done = 0
        for i in sorted(int(j) for j in idx):
            if host_store.corrupt(victims[i]):
                done += 1
        self.stats["corrupted_records"] += done
        self._trace_instant("corrupt", fault.at, replica=fault.replica,
                            records=done)
        return done
