"""Real-execution backends: actual JAX models behind the serving engine.

Two implementations share the Backend protocol:

* :class:`RealBackend` — the paged-KV runtime (production path for
  attention-family models).  ``(L, num_blocks, block_size, KH, hd)``
  key/value pools per model are allocated ONCE and driven by the
  :class:`BlockManager` block tables: admission, decode, speculative
  verification, chunked prefill, eviction and completion touch only int32
  tables and sampled tokens — the cache tensors never travel and are never
  gathered, scattered or re-bucketed.  One multi-query paged-attention
  kernel (Pallas on TPU, jnp oracle on this CPU container) serves plain
  decode (T=1), speculative verify (T=gamma+1) and chunked-prefill appends
  (T=chunk), so ``hybrid_step`` runs the chunked scheduler's mixed
  chunk+decode batches on real execution end-to-end.

* :class:`DenseSlotBackend` — the legacy dense slot-cache implementation
  (whole-cache gather/scatter per step, per-sequence Python prefill loop),
  kept for the SSM/hybrid/encdec families whose recurrent state is O(1)
  and not paged, and as the baseline for the dense-vs-paged equivalence
  tests and ``--only backend`` benchmarks.

:func:`make_real_backend` picks the implementation per model family.

Latencies are wall-clock (block_until_ready) — this is what the planner
learns from on this tier, and what the C_switch profiler measures.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spec_decode import (make_ar_step, make_paged_ar_step,
                                make_paged_spec_step, make_spec_step)
from ..models.registry import ModelAPI
from .engine import StepOutcome
from .kv_cache import BlockManager, OutOfBlocks
from .paged_runtime import PagedKVRuntime, bucket_size, num_blocks_for
from .request import Sequence


def _bucket(n: int) -> int:
    return bucket_size(n)


def make_real_backend(target: ModelAPI, draft: ModelAPI, **kw):
    """Paged runtime when both models have a paged-KV path (attention
    families); dense slot caches otherwise (SSM/hybrid/encdec state is O(1)
    per sequence and lives in fixed slots)."""
    if target.supports_paged and draft.supports_paged:
        return RealBackend(target, draft, **kw)
    for k in ("block_manager", "num_blocks", "block_size", "cost_model",
              "use_kernel"):
        kw.pop(k, None)
    return DenseSlotBackend(target, draft, **kw)


# ---------------------------------------------------------------------------
# Paged-KV backend
# ---------------------------------------------------------------------------


class RealBackend:
    """Zero-copy continuous batching over paged KV pools.

    When ``block_manager`` is the scheduler's own instance, the scheduler's
    logical admission decisions and the physical pool are one and the same
    object (the intended wiring — see ``launch/serve.py``).  Without one, a
    private BlockManager sized for ``max_batch x max_seq`` (or from
    ``cost_model.kv_capacity_tokens``) is created and mirrored internally.
    """

    def __init__(self, target: ModelAPI, draft: ModelAPI, *,
                 max_batch: int = 8, max_seq: int = 256, seed: int = 0,
                 sampling: str = "greedy", temperature: float = 1.0,
                 block_manager: Optional[BlockManager] = None,
                 block_size: int = 8, num_blocks: Optional[int] = None,
                 cost_model=None, use_kernel: bool = False):
        if not (target.supports_paged and draft.supports_paged):
            raise NotImplementedError(
                "RealBackend is the paged-KV runtime; use make_real_backend "
                "(or DenseSlotBackend) for SSM/hybrid/encdec families")
        self.target = target
        self.draft = draft
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.key = jax.random.PRNGKey(seed)

        k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
        self.tparams = target.init(k1)
        self.dparams = draft.init(k2)
        self.dparams_host: Optional[dict] = None  # offloaded copy

        if block_manager is None:
            if num_blocks is None:
                if cost_model is not None:
                    num_blocks = num_blocks_for(cost_model, target.cfg,
                                                draft.cfg, block_size)
                else:
                    num_blocks = (-(-max_batch * max_seq // block_size)
                                  + 2 * max_batch)
            block_manager = BlockManager(num_blocks, block_size)
            self._owns_bm = True
        else:
            self._owns_bm = False
        self.bm = block_manager
        self.use_kernel = use_kernel
        self.tkv = PagedKVRuntime(target, self.bm)
        self.dkv = PagedKVRuntime(draft, self.bm)

        self.last_token: Dict[int, int] = {}
        self.tokens_out: Dict[int, List[int]] = {}

        # page donation keeps the pools in place on accelerators; CPU jax
        # cannot donate and would only warn
        donate = jax.default_backend() != "cpu"
        spec = make_paged_spec_step(target, draft, sampling=sampling,
                                    temperature=temperature)
        self._spec_jit = jax.jit(spec, static_argnames=("gamma",),
                                 donate_argnums=(3, 4) if donate else ())
        ar = make_paged_ar_step(target, sampling=sampling,
                                temperature=temperature)
        self._ar_jit = jax.jit(ar, donate_argnums=(2,) if donate else ())

        def _extend_target(key, params, pages, tokens, tables, start, valid):
            """Multi-token extension + next-token sample at each row's last
            valid position (batched prefill / chunked-prefill appends fused
            with T=1 decode rows)."""
            logits, pages = target.decode_step_paged(
                params, pages, tokens, tables, start, valid,
                use_kernel=use_kernel)
            idx = jnp.maximum(valid - 1, 0)[:, None, None]
            lg = jnp.take_along_axis(logits, idx, axis=1)[:, 0] / temperature
            if sampling == "greedy":
                nxt = jnp.argmax(lg, axis=-1)
            else:
                nxt = jax.random.categorical(key, lg)
            return nxt, pages

        def _extend_draft(params, pages, tokens, tables, start, valid):
            _, pages = draft.decode_step_paged(params, pages, tokens, tables,
                                               start, valid,
                                               use_kernel=use_kernel)
            return pages

        self._extend_t = jax.jit(_extend_target,
                                 donate_argnums=(2,) if donate else ())
        self._extend_d = jax.jit(_extend_draft,
                                 donate_argnums=(1,) if donate else ())

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def offload_draft(self) -> None:
        self.dparams_host = jax.tree.map(np.asarray, self.dparams)
        self.dparams = None

    def reload_draft(self) -> None:
        assert self.dparams_host is not None
        self.dparams = jax.tree.map(jnp.asarray, self.dparams_host)

    @property
    def draft_resident(self) -> bool:
        return self.dparams is not None

    # ------------------------------------------------------------------
    # block-table bookkeeping (int32 only — the pages only move for CoW
    # forks and elastic migration, both batched block-migration launches)
    # ------------------------------------------------------------------
    def _ensure_alloc(self, req_id: int, tokens: int) -> None:
        if req_id in self.bm.tables:
            self.bm.ensure_capacity(req_id, tokens)
        else:
            # private BlockManager: mirror the scheduler's admission
            self.bm.allocate(req_id, tokens)

    def on_admit(self, seq: Sequence) -> None:
        """A sequence admitted with a cached prefix starts with that many
        tokens already materialised — in BOTH pools (only draft-covered
        prefixes are ever registered, see scheduler.note_prefill_progress)."""
        self.tkv.ctx[seq.req_id] = seq.prefilled
        self.dkv.ctx[seq.req_id] = seq.prefilled

    def apply_host_transfers(self) -> None:
        """Drain the BlockManager's host-tier queues: gather freshly
        spilled blocks' pages (BOTH pools) into their ``HostKVStore``
        records, then scatter queued restores back into their target device
        blocks — spills strictly first, so a block spilled and re-matched
        in the same scheduling round restores the payload captured here.
        Runs BEFORE CoW copies and step writes (``_apply_pending_copies``)
        so eviction-time content is read before anything overwrites it."""
        hs = getattr(self.bm, "host_store", None)
        if hs is None:
            return
        spills = self.bm.drain_pending_spills()
        if spills:
            t0 = time.perf_counter()
            ids = [b for b, _ in spills]
            tpay = self.tkv.spill_blocks(ids)
            dpay = self.dkv.spill_blocks(ids)
            for i, (_, h) in enumerate(spills):
                rec = hs.records.get(h)
                if rec is None:
                    continue          # host LRU dropped it before the copy
                rec.data = {f"t:{k}": v[:, i] for k, v in tpay.items()}
                rec.data.update(
                    {f"d:{k}": v[:, i] for k, v in dpay.items()})
                hs.seal(h)  # re-stamp the checksum over the filled pages
                hs.stats["spilled_blocks"] += 1
            hs.stats["spill_s"] += time.perf_counter() - t0
        restores = self.bm.drain_pending_restores()
        if restores:
            t0 = time.perf_counter()
            # a queued restore's record is pinned from match to drain, so
            # it cannot have been evicted from the host tier in between, and
            # the fault injector never corrupts pinned records — so a
            # checksum mismatch here is real memory corruption, not noise
            assert all(hs.verify(h) for h, _ in restores), \
                "pinned host record fails its checksum at restore drain"
            recs = [(b, hs.take(h)) for h, b in restores]
            assert all(r is not None and r.data for _, r in recs), \
                "pinned host record lost before its restore drained"
            ids = [b for b, _ in recs]
            self.tkv.restore_blocks(ids, {
                k: np.stack([r.data[f"t:{k}"] for _, r in recs], axis=1)
                for k in self.tkv.pages})
            self.dkv.restore_blocks(ids, {
                k: np.stack([r.data[f"d:{k}"] for _, r in recs], axis=1)
                for k in self.dkv.pages})
            jax.block_until_ready(self.tkv.pages["k_pages"])
            jax.block_until_ready(self.dkv.pages["k_pages"])
            hs.stats["restore_s"] += time.perf_counter() - t0

    def _apply_pending_copies(self) -> None:
        """Execute the BlockManager's queued CoW forks on-device (one
        batched block-migration launch per pool) BEFORE this step's writes,
        so a privatised block carries its shared content when written.
        Host-tier spills/restores drain first: a spill must read its
        block's pages before a CoW copy or step write can touch them."""
        self.apply_host_transfers()
        copies = self.bm.drain_pending_copies()
        if not copies:
            return
        src = [c[0] for c in copies]
        dst = [c[1] for c in copies]
        self.tkv.apply_copies(src, dst, use_kernel=self.use_kernel)
        self.dkv.apply_copies(src, dst, use_kernel=self.use_kernel)

    def reserve(self, seqs: List[Sequence], gamma: int) -> List[Sequence]:
        """Grow block tables to cover this step's gamma+1 KV writes BEFORE
        executing, so a paged write can never land in another sequence's
        blocks; any shared block the write range covers is privatised first
        (copy-on-write).  Returns the sequences whose reservation failed —
        the engine preempts those (recompute policy) instead of running
        them."""
        failed = []
        for s in seqs:
            ctx = self.tkv.ctx.get(s.req_id, 0)
            need = ctx + gamma + 1
            try:
                if self.bm.prefix_caching and s.req_id in self.bm.tables:
                    self.bm.fork_for_write(s.req_id, ctx, need)
                self._ensure_alloc(s.req_id, need)
            except OutOfBlocks:
                failed.append(s)
        return failed

    # ------------------------------------------------------------------
    # elastic physical pool (memory-manager hooks, §6.3/6.4 on real tier)
    # ------------------------------------------------------------------
    def grow_pools(self, extra_blocks: int) -> None:
        """§6.3: extend both physical paged pools in lockstep with
        ``BlockManager.expand`` (ElasticMemoryManager ``grow_fn``)."""
        self.tkv.grow(extra_blocks)
        self.dkv.grow(extra_blocks)

    def shrink_pools(self, to_blocks: Optional[int] = None) -> None:
        """§6.4 step 5: trim both pools after the logical contraction
        committed (ElasticMemoryManager ``shrink_fn``)."""
        nb = self.bm.base_blocks if to_blocks is None else to_blocks
        self.tkv.shrink(nb)
        self.dkv.shrink(nb)

    def migrate_pools(self, plan) -> float:
        """§6.4 step 3: execute the contraction's block moves on both pools
        (ElasticMemoryManager ``migrate_fn``); returns wall-clock seconds.
        Contraction-time spills flush first — the spilled high blocks'
        pages must be captured before migration reuses their below-boundary
        targets and before ``shrink_pools`` trims the high region."""
        self.apply_host_transfers()
        t0 = time.perf_counter()
        self.tkv.apply_plan(plan, use_kernel=self.use_kernel)
        self.dkv.apply_plan(plan, use_kernel=self.use_kernel)
        jax.block_until_ready(self.tkv.pages["k_pages"])
        jax.block_until_ready(self.dkv.pages["k_pages"])
        return time.perf_counter() - t0

    def _fill_rows(self, rows: List[Tuple[Sequence, List[int], int, int]]
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """rows of (seq, tokens, start, n_valid) -> padded step operands."""
        Bb = _bucket(len(rows))
        Tb = _bucket(max(len(r[1]) for r in rows))
        tokens = np.zeros((Bb, Tb), np.int32)
        start = np.zeros((Bb,), np.int32)
        valid = np.zeros((Bb,), np.int32)
        for i, (_, toks, c, nv) in enumerate(rows):
            tokens[i, :len(toks)] = toks
            start[i] = c
            valid[i] = nv
        return tokens, start, valid, Bb

    # ------------------------------------------------------------------
    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float:
        """Batched prefill: every admitted prompt in ONE padded extension
        call (start=0), its KV scattered straight into the paged pool."""
        t0 = time.perf_counter()
        rows = []
        for s in seqs:
            if self._owns_bm and s.req_id in self.bm.tables:
                self.bm.release(s.req_id)  # recompute after preemption
            self._ensure_alloc(s.req_id, s.request.prompt_len + 1)
            toks = list(s.request.prompt_tokens)
            rows.append((s, toks, 0, len(toks)))
        self._apply_pending_copies()
        tokens, start, valid, Bb = self._fill_rows(rows)
        tables, _ = self.tkv.batch_tables(seqs, Bb)
        nxt, self.tkv.pages = self._extend_t(
            self._next_key(), self.tparams, self.tkv.pages, tokens, tables,
            start, valid)
        nxt = np.asarray(jax.block_until_ready(nxt))
        do_draft = with_draft and self.draft_resident
        if do_draft:
            self.dkv.pages = self._extend_d(self.dparams, self.dkv.pages,
                                            tokens, tables, start, valid)
            jax.block_until_ready(self.dkv.pages)
        for i, s in enumerate(seqs):
            P = s.request.prompt_len
            self.tkv.ctx[s.req_id] = P
            self.tokens_out[s.req_id] = [int(nxt[i])]
            self.last_token[s.req_id] = int(nxt[i])
            s.generated = 0  # first token counted at the first decode commit
            if do_draft:
                self.dkv.ctx[s.req_id] = P
                s.delta = 0
            else:
                self.dkv.ctx[s.req_id] = 0
                s.delta = P
        return time.perf_counter() - t0

    def draft_catchup(self, seqs: List[Sequence]) -> float:
        """Re-prefill the draft pool for sequences whose draft state lags
        (the physical C_switch cost) — one batched paged extension."""
        if not self.draft_resident:
            return 0.0
        rows = []
        for s in seqs:
            ctx = self.tkv.ctx.get(s.req_id)
            if ctx is None:
                continue
            dctx = self.dkv.ctx.get(s.req_id, 0)
            if dctx > ctx:
                dctx = 0  # stale (preempt-and-recompute): full re-prefill
            if dctx >= ctx:
                continue
            stream = (list(s.request.prompt_tokens)
                      + self.tokens_out.get(s.req_id, []))
            rows.append((s, stream[dctx:ctx], dctx, ctx - dctx))
        if not rows:
            return 0.0
        t0 = time.perf_counter()
        tokens, start, valid, Bb = self._fill_rows(rows)
        tables, _ = self.dkv.batch_tables([r[0] for r in rows], Bb)
        self.dkv.pages = self._extend_d(self.dparams, self.dkv.pages, tokens,
                                        tables, start, valid)
        jax.block_until_ready(self.dkv.pages)
        for s, _, _, _ in rows:
            self.dkv.ctx[s.req_id] = self.tkv.ctx[s.req_id]
            s.delta = 0
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def hybrid_step(self, chunks, decode: List[Sequence], gamma: int,
                    *, with_draft: bool) -> StepOutcome:
        """One fused mixed step on real execution: prefill chunks (ragged
        multi-token appends into freshly grown blocks) batched together with
        the T=1 decode rows in a single padded extension call."""
        if not chunks:
            if not decode:
                return StepOutcome(n_committed=[], latency=0.0)
            return self.step(decode, gamma)
        assert gamma == 0, "speculation is disabled while chunks are in flight"
        if self.reserve(decode, 0):
            raise OutOfBlocks("decode rows not reserved — engine must "
                              "preempt before hybrid_step")
        rows = []
        for s, n in chunks:
            c = s.prefilled  # authoritative (survives preempt-and-recompute)
            self.tkv.ctx[s.req_id] = c
            if c == 0:
                self.dkv.ctx[s.req_id] = 0  # fresh / restarted sequence
            self._ensure_alloc(s.req_id, c + n)
            toks = list(s.request.prompt_tokens[c:c + n])
            rows.append((s, toks, c, n))
        n_chunks = len(rows)
        for s in decode:
            rows.append((s, [self.last_token[s.req_id]],
                         self.tkv.ctx[s.req_id], 1))

        t0 = time.perf_counter()
        # CoW forks queued at schedule/reserve time execute BEFORE the
        # step's writes (their cost is real step latency)
        self._apply_pending_copies()
        tokens, start, valid, Bb = self._fill_rows(rows)
        tables, _ = self.tkv.batch_tables([r[0] for r in rows], Bb)
        nxt, self.tkv.pages = self._extend_t(
            self._next_key(), self.tparams, self.tkv.pages, tokens, tables,
            start, valid)
        nxt = np.asarray(jax.block_until_ready(nxt))

        do_draft = with_draft and self.draft_resident
        if do_draft:
            # the draft consumes the same chunk stream to keep its KV current
            # (decode rows stay out: gamma=0 commits are charged to delta)
            drows = [r for r in rows[:n_chunks]
                     if self.dkv.ctx.get(r[0].req_id, 0) == r[2]]
            if drows:
                dtokens, dstart, dvalid, Db = self._fill_rows(drows)
                dtables, _ = self.dkv.batch_tables([r[0] for r in drows], Db)
                self.dkv.pages = self._extend_d(
                    self.dparams, self.dkv.pages, dtokens, dtables, dstart,
                    dvalid)
                jax.block_until_ready(self.dkv.pages)
                for s, _, c, n in drows:
                    self.dkv.ctx[s.req_id] = c + n
        latency = time.perf_counter() - t0

        for i, (s, _, c, n) in enumerate(rows):
            if i < n_chunks:
                self.tkv.ctx[s.req_id] = c + n
                if c + n == s.request.prompt_len:
                    # final chunk: the sampled token is the first output x_N
                    self.tokens_out[s.req_id] = [int(nxt[i])]
                    self.last_token[s.req_id] = int(nxt[i])
            else:
                self.tokens_out[s.req_id].append(int(nxt[i]))
                self.last_token[s.req_id] = int(nxt[i])
                self.tkv.ctx[s.req_id] = c + 1
        return StepOutcome(n_committed=[1] * len(decode), latency=latency)

    # ------------------------------------------------------------------
    def step(self, seqs: List[Sequence], gamma: int) -> StepOutcome:
        if self.reserve(seqs, gamma):
            raise OutOfBlocks("decode batch not reserved — engine must "
                              "preempt before step")
        self._apply_pending_copies()
        n = len(seqs)
        Bb = _bucket(n)
        tables, lengths = self.tkv.batch_tables(seqs, Bb)
        last = np.zeros((Bb,), np.int32)
        for i, s in enumerate(seqs):
            last[i] = self.last_token[s.req_id]

        t0 = time.perf_counter()
        if gamma == 0:
            nxt, self.tkv.pages = self._ar_jit(
                self._next_key(), self.tparams, self.tkv.pages, tables,
                lengths, last)
            nxt = np.asarray(jax.block_until_ready(nxt))
            latency = time.perf_counter() - t0
            n_committed = []
            for i, s in enumerate(seqs):
                self.tokens_out[s.req_id].append(int(nxt[i]))
                self.last_token[s.req_id] = int(nxt[i])
                self.tkv.ctx[s.req_id] += 1
                n_committed.append(1)
            return StepOutcome(n_committed=n_committed, latency=latency)

        res = self._spec_jit(self._next_key(), self.tparams, self.dparams,
                             self.tkv.pages, self.dkv.pages, tables, lengths,
                             last, gamma=gamma)
        jax.block_until_ready(res.n_accepted)
        latency = time.perf_counter() - t0
        self.tkv.pages, self.dkv.pages = res.tcache, res.dcache
        toks = np.asarray(res.tokens)
        n_acc = np.asarray(res.n_accepted)
        last_np = np.asarray(res.last_token)
        n_committed = []
        for i, s in enumerate(seqs):
            committed = [int(t) for t in toks[i] if t >= 0]
            self.tokens_out[s.req_id].extend(committed)
            self.last_token[s.req_id] = int(last_np[i])
            n_keep = int(n_acc[i]) + 1
            self.tkv.ctx[s.req_id] += n_keep
            self.dkv.ctx[s.req_id] = self.tkv.ctx[s.req_id]
            n_committed.append(n_keep)
        return StepOutcome(n_committed=n_committed, latency=latency)

    # ------------------------------------------------------------------
    # disaggregated prefill/decode handoff (physical KV migration)
    # ------------------------------------------------------------------
    def export_handoff(self, seq: Sequence) -> dict:
        """Capture a fully-prefilled sequence's physical state for migration
        to a decode replica: both pools' block payloads (the same batched
        gather the host-offload spill path uses) plus the sampler
        bookkeeping the decode loop needs (materialised lengths, the first
        sampled output token, last-token id).  Called by the engine BEFORE
        it releases the source block tables."""
        rid = seq.req_id
        table = list(self.bm.tables.get(rid, ()))
        out = {
            "ctx": int(self.tkv.ctx.get(rid, 0)),
            "dctx": int(self.dkv.ctx.get(rid, 0)),
            "tokens_out": list(self.tokens_out.get(rid, [])),
            "last_token": self.last_token.get(rid),
            "n_blocks": len(table),
        }
        if table:
            out["tkv"] = self.tkv.spill_blocks(table)
            out["dkv"] = self.dkv.spill_blocks(table)
        return out

    def import_handoff(self, seq: Sequence, payload: dict) -> None:
        """Adopt a migrated sequence: scatter the exported block payloads
        into this replica's freshly allocated blocks (same data movement as
        ``restore_blocks`` on the host-offload path) and rebuild the decode
        bookkeeping, so the next decode step continues byte-identically to
        never having moved."""
        kv = payload.get("kv")
        if not kv:
            return
        rid = seq.req_id
        ctx = int(kv.get("ctx", 0))
        self._ensure_alloc(rid, max(ctx, 1))
        table = list(self.bm.tables.get(rid, ()))
        # the destination table covers exactly the materialised ctx tokens;
        # a source tail block past ctx (allocation rounding) is never read,
        # so restoring the common prefix is sufficient
        n = min(len(table), int(kv.get("n_blocks", 0)))
        if n:
            ids = table[:n]
            self.tkv.restore_blocks(
                ids, {k: v[:, :n] for k, v in kv["tkv"].items()})
            self.dkv.restore_blocks(
                ids, {k: v[:, :n] for k, v in kv["dkv"].items()})
        self.tkv.ctx[rid] = ctx
        self.dkv.ctx[rid] = int(kv.get("dctx", 0))
        self.tokens_out[rid] = list(kv.get("tokens_out", []))
        if kv.get("last_token") is not None:
            self.last_token[rid] = int(kv["last_token"])

    # ------------------------------------------------------------------
    def release(self, seq: Sequence) -> None:
        self.tkv.ctx.pop(seq.req_id, None)
        self.dkv.ctx.pop(seq.req_id, None)
        self.last_token.pop(seq.req_id, None)
        # engine flow releases through scheduler.finish first, leaving this a
        # no-op there; direct backend users (benchmarks) free their blocks
        if seq.req_id in self.bm.tables:
            self.bm.release(seq.req_id)

    def output_tokens(self, req_id: int) -> List[int]:
        return self.tokens_out.get(req_id, [])


# ---------------------------------------------------------------------------
# Legacy dense slot-cache backend (SSM/hybrid/encdec families + baselines)
# ---------------------------------------------------------------------------


def _gather(cache, idx):
    def g(x):
        if x.ndim == 1:
            return x[idx]
        return x[:, idx]
    return jax.tree.map(g, cache)


def _scatter(cache, compact, idx, n_real):
    def s(x, c):
        if x.ndim == 1:
            return x.at[idx[:n_real]].set(c[:n_real])
        return x.at[:, idx[:n_real]].set(c[:, :n_real])
    return jax.tree.map(s, cache, compact)


class DenseSlotBackend:
    """Slot-based continuous batching over dense caches:

      * caches are allocated once for ``max_batch`` slots x ``max_seq``
        positions;
      * each step gathers the active slots into a compact batch (padded to a
        power-of-two bucket), runs the jitted AR / speculative step, and
        scatters the updated slot caches back;
      * prefill is a one-sequence-at-a-time Python loop.

    This is the seed implementation, superseded by :class:`RealBackend` for
    attention families and retained for O(1)-state families and as the
    dense baseline in tests/benchmarks.
    """

    def __init__(self, target: ModelAPI, draft: ModelAPI, *, max_batch: int = 8,
                 max_seq: int = 256, seed: int = 0, sampling: str = "greedy",
                 temperature: float = 1.0):
        self.target = target
        self.draft = draft
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.key = jax.random.PRNGKey(seed)

        k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
        self.tparams = target.init(k1)
        self.dparams = draft.init(k2)
        self.dparams_host: Optional[dict] = None  # offloaded copy

        self.tcache = target.init_cache(max_batch, max_seq)
        self.dcache = draft.init_cache(max_batch, max_seq)
        self.last_token = np.zeros(max_batch, np.int32)
        self.tokens_out: Dict[int, List[int]] = {}
        self.slot_of: Dict[int, int] = {}
        self._free_slots = list(range(max_batch))[::-1]

        self._spec = make_spec_step(target, draft, sampling=sampling,
                                    temperature=temperature)
        self._ar = make_ar_step(target, sampling=sampling,
                                temperature=temperature)
        self._spec_jit = jax.jit(self._spec, static_argnames=("gamma",))
        self._ar_jit = jax.jit(self._ar)
        self._prefill_t = jax.jit(lambda p, b: target.prefill(p, b, max_seq))
        self._prefill_d = jax.jit(lambda p, b: draft.prefill(p, b, max_seq))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def offload_draft(self) -> None:
        self.dparams_host = jax.tree.map(np.asarray, self.dparams)
        self.dparams = None

    def reload_draft(self) -> None:
        assert self.dparams_host is not None
        self.dparams = jax.tree.map(jnp.asarray, self.dparams_host)

    @property
    def draft_resident(self) -> bool:
        return self.dparams is not None

    # ------------------------------------------------------------------
    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float:
        t0 = time.perf_counter()
        for s in seqs:
            slot = self._free_slots.pop()
            self.slot_of[s.req_id] = slot
            s.slot = slot
            toks = np.asarray(s.request.prompt_tokens, np.int32)[None, :]
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache1 = self._prefill_t(self.tparams, batch)
            logits.block_until_ready()
            self.tcache = _scatter(self.tcache, cache1, np.array([slot]), 1)
            nxt = int(np.argmax(np.asarray(logits[0, 0])))
            self.last_token[slot] = nxt
            self.tokens_out[s.req_id] = [nxt]
            s.generated = 0  # first token counted at the first decode commit
            if with_draft and self.draft_resident:
                _, dcache1 = self._prefill_d(self.dparams, batch)
                self.dcache = _scatter(self.dcache, dcache1, np.array([slot]), 1)
                s.delta = 0
            else:
                s.delta = s.request.prompt_len
        return time.perf_counter() - t0

    def draft_catchup(self, seqs: List[Sequence]) -> float:
        """Re-prefill the draft cache for sequences whose draft state lags
        (the physical C_switch cost)."""
        if not self.draft_resident:
            return 0.0
        t0 = time.perf_counter()
        for s in seqs:
            if s.delta <= 0:
                continue
            slot = self.slot_of[s.req_id]
            ctx = (list(s.request.prompt_tokens)
                   + self.tokens_out[s.req_id][:-1])
            batch = {"tokens": jnp.asarray(np.asarray(ctx, np.int32)[None, :])}
            _, dcache1 = self._prefill_d(self.dparams, batch)
            jax.block_until_ready(dcache1)
            self.dcache = _scatter(self.dcache, dcache1, np.array([slot]), 1)
            s.delta = 0
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def hybrid_step(self, chunks, decode: List[Sequence], gamma: int,
                    *, with_draft: bool) -> StepOutcome:
        """Chunked prefill needs paged caches (RealBackend); the dense slot
        tier still runs monolithic prefill only."""
        if chunks:
            raise NotImplementedError(
                "chunked prefill needs the paged-KV RealBackend — the dense "
                "slot backend prefills monolithically (chunk_tokens=0)")
        return self.step(decode, gamma)

    def step(self, seqs: List[Sequence], gamma: int) -> StepOutcome:
        n = len(seqs)
        bucket = min(_bucket(n), self.max_batch)
        slots = np.array([self.slot_of[s.req_id] for s in seqs], np.int32)
        idx = np.concatenate([slots, np.zeros(bucket - n, np.int32)])

        tc = _gather(self.tcache, idx)
        last = jnp.asarray(self.last_token[idx])

        t0 = time.perf_counter()
        if gamma == 0:
            nxt, tc_new = self._ar_jit(self._next_key(), self.tparams, tc, last)
            jax.block_until_ready(nxt)
            latency = time.perf_counter() - t0
            self.tcache = _scatter(self.tcache, tc_new, idx, n)
            nxt_np = np.asarray(nxt)
            n_committed = []
            for i, s in enumerate(seqs):
                self.tokens_out[s.req_id].append(int(nxt_np[i]))
                self.last_token[slots[i]] = int(nxt_np[i])
                n_committed.append(1)
            return StepOutcome(n_committed=n_committed, latency=latency)

        dc = _gather(self.dcache, idx)
        res = self._spec_jit(self._next_key(), self.tparams, self.dparams,
                             tc, dc, last, gamma=gamma)
        jax.block_until_ready(res.n_accepted)
        latency = time.perf_counter() - t0
        self.tcache = _scatter(self.tcache, res.tcache, idx, n)
        self.dcache = _scatter(self.dcache, res.dcache, idx, n)
        toks = np.asarray(res.tokens)
        n_acc = np.asarray(res.n_accepted)
        last_np = np.asarray(res.last_token)
        n_committed = []
        for i, s in enumerate(seqs):
            committed = [int(t) for t in toks[i] if t >= 0]
            self.tokens_out[s.req_id].extend(committed)
            self.last_token[slots[i]] = int(last_np[i])
            n_committed.append(int(n_acc[i]) + 1)
        return StepOutcome(n_committed=n_committed, latency=latency)

    # ------------------------------------------------------------------
    def release(self, seq: Sequence) -> None:
        slot = self.slot_of.pop(seq.req_id, None)
        if slot is not None:
            self._free_slots.append(slot)

    def output_tokens(self, req_id: int) -> List[int]:
        return self.tokens_out.get(req_id, [])
