"""Real-execution backend: actual JAX models behind the serving engine.

Slot-based continuous batching over dense caches:

  * caches are allocated once for ``max_batch`` slots x ``max_seq`` positions;
  * each step gathers the active slots into a compact batch (padded to a
    power-of-two bucket so the jit cache stays small), runs the jitted
    AR / speculative step, and scatters the updated slot caches back;
  * latencies are wall-clock (block_until_ready) — this is what the planner
    learns from on this tier, and what the C_switch profiler measures.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.spec_decode import make_ar_step, make_spec_step
from ..models.registry import ModelAPI
from .engine import StepOutcome
from .request import Sequence


def _gather(cache, idx):
    def g(x):
        if x.ndim == 1:
            return x[idx]
        return x[:, idx]
    return jax.tree.map(g, cache)


def _scatter(cache, compact, idx, n_real):
    def s(x, c):
        if x.ndim == 1:
            return x.at[idx[:n_real]].set(c[:n_real])
        return x.at[:, idx[:n_real]].set(c[:, :n_real])
    return jax.tree.map(s, cache, compact)


def _bucket(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


class RealBackend:
    def __init__(self, target: ModelAPI, draft: ModelAPI, *, max_batch: int = 8,
                 max_seq: int = 256, seed: int = 0, sampling: str = "greedy",
                 temperature: float = 1.0):
        self.target = target
        self.draft = draft
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling
        self.key = jax.random.PRNGKey(seed)

        k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
        self.tparams = target.init(k1)
        self.dparams = draft.init(k2)
        self.dparams_host: Optional[dict] = None  # offloaded copy

        self.tcache = target.init_cache(max_batch, max_seq)
        self.dcache = draft.init_cache(max_batch, max_seq)
        self.last_token = np.zeros(max_batch, np.int32)
        self.tokens_out: Dict[int, List[int]] = {}
        self.slot_of: Dict[int, int] = {}
        self._free_slots = list(range(max_batch))[::-1]

        self._spec = make_spec_step(target, draft, sampling=sampling,
                                    temperature=temperature)
        self._ar = make_ar_step(target, sampling=sampling,
                                temperature=temperature)
        self._spec_jit = jax.jit(self._spec, static_argnames=("gamma",))
        self._ar_jit = jax.jit(self._ar)
        self._prefill_t = jax.jit(lambda p, b: target.prefill(p, b, max_seq))
        self._prefill_d = jax.jit(lambda p, b: draft.prefill(p, b, max_seq))

    # ------------------------------------------------------------------
    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def offload_draft(self) -> None:
        self.dparams_host = jax.tree.map(np.asarray, self.dparams)
        self.dparams = None

    def reload_draft(self) -> None:
        assert self.dparams_host is not None
        self.dparams = jax.tree.map(jnp.asarray, self.dparams_host)

    @property
    def draft_resident(self) -> bool:
        return self.dparams is not None

    # ------------------------------------------------------------------
    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float:
        t0 = time.perf_counter()
        for s in seqs:
            slot = self._free_slots.pop()
            self.slot_of[s.req_id] = slot
            s.slot = slot
            toks = np.asarray(s.request.prompt_tokens, np.int32)[None, :]
            batch = {"tokens": jnp.asarray(toks)}
            logits, cache1 = self._prefill_t(self.tparams, batch)
            logits.block_until_ready()
            self.tcache = _scatter(self.tcache, cache1, np.array([slot]), 1)
            nxt = int(np.argmax(np.asarray(logits[0, 0])))
            self.last_token[slot] = nxt
            self.tokens_out[s.req_id] = [nxt]
            s.generated = 0  # first token counted at the first decode commit
            if with_draft and self.draft_resident:
                _, dcache1 = self._prefill_d(self.dparams, batch)
                self.dcache = _scatter(self.dcache, dcache1, np.array([slot]), 1)
                s.delta = 0
            else:
                s.delta = s.request.prompt_len
        return time.perf_counter() - t0

    def draft_catchup(self, seqs: List[Sequence]) -> float:
        """Re-prefill the draft cache for sequences whose draft state lags
        (the physical C_switch cost)."""
        if not self.draft_resident:
            return 0.0
        t0 = time.perf_counter()
        for s in seqs:
            if s.delta <= 0:
                continue
            slot = self.slot_of[s.req_id]
            ctx = (list(s.request.prompt_tokens)
                   + self.tokens_out[s.req_id][:-1])
            batch = {"tokens": jnp.asarray(np.asarray(ctx, np.int32)[None, :])}
            _, dcache1 = self._prefill_d(self.dparams, batch)
            jax.block_until_ready(dcache1)
            self.dcache = _scatter(self.dcache, dcache1, np.array([slot]), 1)
            s.delta = 0
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def hybrid_step(self, chunks, decode: List[Sequence], gamma: int,
                    *, with_draft: bool) -> StepOutcome:
        """Chunked prefill needs paged (not dense slot) caches on the real
        tier; until that lands, hybrid mode is simulation-only (ROADMAP
        open item)."""
        if chunks:
            raise NotImplementedError(
                "chunked prefill is not supported on the real-execution "
                "backend yet — run with chunk_tokens=0 or the sim tier")
        return self.step(decode, gamma)

    def step(self, seqs: List[Sequence], gamma: int) -> StepOutcome:
        n = len(seqs)
        bucket = min(_bucket(n), self.max_batch)
        slots = np.array([self.slot_of[s.req_id] for s in seqs], np.int32)
        idx = np.concatenate([slots, np.zeros(bucket - n, np.int32)])

        tc = _gather(self.tcache, idx)
        last = jnp.asarray(self.last_token[idx])

        t0 = time.perf_counter()
        if gamma == 0:
            nxt, tc_new = self._ar_jit(self._next_key(), self.tparams, tc, last)
            jax.block_until_ready(nxt)
            latency = time.perf_counter() - t0
            self.tcache = _scatter(self.tcache, tc_new, idx, n)
            nxt_np = np.asarray(nxt)
            n_committed = []
            for i, s in enumerate(seqs):
                self.tokens_out[s.req_id].append(int(nxt_np[i]))
                self.last_token[slots[i]] = int(nxt_np[i])
                n_committed.append(1)
            return StepOutcome(n_committed=n_committed, latency=latency)

        dc = _gather(self.dcache, idx)
        res = self._spec_jit(self._next_key(), self.tparams, self.dparams,
                             tc, dc, last, gamma=gamma)
        jax.block_until_ready(res.n_accepted)
        latency = time.perf_counter() - t0
        self.tcache = _scatter(self.tcache, res.tcache, idx, n)
        self.dcache = _scatter(self.dcache, res.dcache, idx, n)
        toks = np.asarray(res.tokens)
        n_acc = np.asarray(res.n_accepted)
        last_np = np.asarray(res.last_token)
        n_committed = []
        for i, s in enumerate(seqs):
            committed = [int(t) for t in toks[i] if t >= 0]
            self.tokens_out[s.req_id].extend(committed)
            self.last_token[slots[i]] = int(last_np[i])
            n_committed.append(int(n_acc[i]) + 1)
        return StepOutcome(n_committed=n_committed, latency=latency)

    # ------------------------------------------------------------------
    def release(self, seq: Sequence) -> None:
        slot = self.slot_of.pop(seq.req_id, None)
        if slot is not None:
            self._free_slots.append(slot)

    def output_tokens(self, req_id: int) -> List[int]:
        return self.tokens_out.get(req_id, [])
