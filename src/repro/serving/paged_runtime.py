"""Paged-KV runtime state for the real-execution backend.

One :class:`PagedKVRuntime` per model (target / draft) owns the physical
``(L, num_blocks + 1, block_size, KH, hd)`` key/value page arrays (the last
block is the write-off "trash" block absorbing padded-slot writes) and the
host-side per-sequence materialised lengths.  The logical layout — which
sequence owns which blocks — lives in the existing :class:`BlockManager`;
this class only turns those tables into padded int32 device operands.

The zero-copy contract: admission, decode, speculative verification,
chunked prefill, eviction and completion never touch the page tensors from
the host.  Per step, the only host->device traffic is the (B, width) block
tables, (B,) lengths and the token ids; the only device->host traffic is
the sampled tokens (and acceptance counts).  The pages themselves are
donated through the jitted step functions so XLA updates them in place.
"""
from __future__ import annotations

from typing import Dict, List, Sequence as Seq, Tuple

import jax.numpy as jnp
import numpy as np

from ..models.registry import ModelAPI
from .kv_cache import BlockManager, MigrationPlan
from .request import Sequence


def bucket_size(n: int) -> int:
    """Next power of two (jit-cache-friendly padding)."""
    return 1 << max(n - 1, 0).bit_length()


def num_blocks_for(cost_model, target_cfg, draft_cfg, block_size: int, *,
                   min_blocks: int = 64, max_blocks: int = 4096,
                   reserve_frac: float = 0.1) -> int:
    """Size the physical pool from the roofline HBM budget: the tokens that
    fit beside the weights (``RooflineCostModel.kv_capacity_tokens``) divided
    into blocks, clamped to a sane range for the reduced-model real tier."""
    toks = cost_model.kv_capacity_tokens(target_cfg, draft_cfg,
                                         reserve_frac=reserve_frac)
    return int(min(max(toks // block_size, min_blocks), max_blocks))


class PagedKVRuntime:
    """Physical paged KV pool + host length bookkeeping for one model."""

    def __init__(self, api: ModelAPI, bm: BlockManager):
        if not api.supports_paged:
            raise NotImplementedError(
                f"family {api.cfg.family!r} has no paged-KV path")
        self.api = api
        self.bm = bm
        self.num_blocks = bm.total_blocks
        self.block_size = bm.block_size
        self.trash = self.num_blocks          # id of the write-off block
        self.pages = api.init_paged_cache(self.num_blocks, self.block_size)
        self.ctx: Dict[int, int] = {}         # req_id -> materialised tokens

    @property
    def bytes_per_block(self) -> int:
        k = self.pages["k_pages"]
        L, _, bs, kh, hd = k.shape
        return 2 * L * bs * kh * hd * k.dtype.itemsize  # k + v

    def transfer_bytes(self, n_blocks: int) -> int:
        """Wire bytes for migrating ``n_blocks`` of this pool between
        replicas (the disaggregated handoff path — what the sim tier prices
        at interconnect bandwidth)."""
        return n_blocks * self.bytes_per_block

    # ------------------------------------------------------------------
    # copy-on-write + elastic physical pool (§6.3/6.4 on the real tier)
    # ------------------------------------------------------------------
    def apply_copies(self, src: Seq[int], dst: Seq[int], *,
                     use_kernel: bool = False) -> None:
        """Execute block copies src[i] -> dst[i] on-device in ONE batched
        block-migration launch (the CoW fork path and the §6.4 step-3 data
        movement share the same kernel).  No host round-trip: the pages stay
        on-device, only the int32 index vectors travel."""
        if not len(src):
            return
        from ..kernels import block_migration
        s = jnp.asarray(list(src), jnp.int32)
        d = jnp.asarray(list(dst), jnp.int32)
        for key in ("k_pages", "v_pages"):
            self.pages[key] = block_migration.migrate_blocks(
                self.pages[key], s, d, use_kernel=use_kernel)

    def apply_plan(self, plan: MigrationPlan, *, use_kernel: bool = False
                   ) -> None:
        """§6.4 step 3 on the physical paged pools."""
        self.apply_copies(plan.src, plan.dst, use_kernel=use_kernel)

    def spill_blocks(self, ids: Seq[int]) -> Dict[str, np.ndarray]:
        """Device→host gather of whole blocks for the host KV offload tier:
        ONE batched index gather per page array (k/v), materialised to host
        numpy.  Returned arrays have shape (L, n, block_size, KH, hd) with
        the block axis second, so ``arr[:, i]`` is block ``ids[i]``'s
        payload for a single :class:`~.kv_cache.HostBlockRecord`."""
        idx = jnp.asarray(list(ids), jnp.int32)
        return {key: np.asarray(arr[:, idx])
                for key, arr in self.pages.items()}

    def restore_blocks(self, ids: Seq[int],
                       payloads: Dict[str, np.ndarray]) -> None:
        """Host→device scatter of spilled block payloads back into the page
        arrays — one batched index-vector scatter per pool, the same data
        movement shape as the block-migration path with the source staged
        from host memory.  ``payloads`` mirrors :meth:`spill_blocks`'s
        (L, n, block_size, KH, hd) layout."""
        if not len(ids):
            return
        idx = jnp.asarray(list(ids), jnp.int32)
        for key in self.pages:
            self.pages[key] = self.pages[key].at[:, idx].set(
                jnp.asarray(payloads[key], self.pages[key].dtype))

    def grow(self, extra_blocks: int) -> None:
        """§6.3 expansion of the physical pool: extend both page arrays by
        ``extra_blocks``, keeping the trash block LAST.  The old trash slot
        is recycled as ordinary storage — its garbage content is never read
        because per-sequence lengths gate every attention read, and every
        block is written before its positions become readable."""
        def pad(x):
            L, nb1, bs, kh, hd = x.shape
            z = jnp.zeros((L, extra_blocks, bs, kh, hd), x.dtype)
            return jnp.concatenate([x, z], axis=1)
        self.pages = {k: pad(v) for k, v in self.pages.items()}
        self.num_blocks += extra_blocks
        self.trash = self.num_blocks

    def shrink(self, to_blocks: int) -> None:
        """§6.4 step 5 on the physical pool: trim to ``to_blocks`` + trash.
        Must run after the BlockManager committed its contraction (no table
        references an id >= to_blocks).  The surviving slot at index
        ``to_blocks`` becomes the new trash block."""
        assert to_blocks <= self.num_blocks, (to_blocks, self.num_blocks)
        self.pages = {k: v[:, :to_blocks + 1] for k, v in self.pages.items()}
        self.num_blocks = to_blocks
        self.trash = to_blocks

    def batch_tables(self, seqs: Seq[Sequence], batch: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Padded (batch, width) int32 block tables + (batch,) materialised
        lengths for one step.  Rows beyond ``len(seqs)`` and table entries
        beyond a sequence's allocation are the trash id, which both satisfies
        the kernel's "any valid id" padding contract and guarantees padded
        slots can only ever write to the trash block."""
        # the physical pool must follow BlockManager.expand()/contraction in
        # lockstep (``grow``/``shrink``, wired through the memory manager's
        # grow_fn/shrink_fn hooks) — a drifted allocator would hand out ids
        # colliding with the trash block / falling outside the pages, so
        # fail loudly instead of corrupting KV
        assert self.bm.total_blocks == self.num_blocks, (
            "BlockManager pool size drifted from the physical paged pool "
            f"({self.bm.total_blocks} != {self.num_blocks}); wire "
            "PagedKVRuntime.grow/shrink into the ElasticMemoryManager "
            "(see RealBackend.grow_pools/shrink_pools)")
        rows: List[List[int]] = [list(self.bm.tables.get(s.req_id, ()))
                                 for s in seqs]
        width = bucket_size(max((len(r) for r in rows), default=1) or 1)
        tables = np.full((batch, width), self.trash, np.int32)
        lengths = np.zeros((batch,), np.int32)
        for i, (s, row) in enumerate(zip(seqs, rows)):
            tables[i, :len(row)] = row
            lengths[i] = self.ctx.get(s.req_id, 0)
        return tables, lengths
