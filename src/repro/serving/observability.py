"""Flight recorder: deterministic structured tracing + metrics registry.

Two cooperating pieces, both driven entirely by the shared *virtual* clock
(no wall-clock reads anywhere — a trace is a pure function of config + seed,
so two same-seed runs emit byte-identical trace files):

:class:`TraceRecorder`
    Structured spans/events for one serving run.  Per-request lifecycle is
    modelled as a contiguous *stage machine*: ``queue`` from arrival, then
    ``prefill`` / ``decode`` / ``transfer`` (disaggregated KV handoff) /
    ``stall`` (preempt-recompute, crash recovery), closed by a terminal
    instant (``finished`` / ``cancelled`` / ``expired`` / ``failed``).
    Each stage transition closes the previous span, so a finished request's
    stage durations partition its end-to-end latency *by construction*
    (span-balance invariant, tests/test_observability.py).  On top of the
    request lanes ride per-step engine spans (``batch``, ``gamma``,
    committed/accepted tokens — the MAB's reward surface) and fleet point
    events (brownout rung transitions, autoscale, crash/detect/recover,
    admission shed, draft offload/reload, KV spill/restore, faults).

    The recorder is attached via ``ServingEngine.attach_trace`` /
    ``ServingCluster.attach_trace`` (or the ``trace=`` kwarg of
    ``build_sim_engine`` / ``build_sim_cluster``).  Detached (the default)
    every hook is a single ``is None`` check — the committed token streams,
    step counts and ``Metrics.summary()`` are byte-identical to a build
    without the recorder.  Attached, memory is bounded: events live in a
    ring buffer (oldest evicted first, ``dropped`` counts evictions).

:class:`MetricsRegistry`
    Prometheus-flavoured counters / gauges / histograms with windowed
    time-series snapshots (``snapshot``/``series``) and deterministic text
    exposition (``exposition``) for the real tier's scrape endpoint.

Exporters: ``export_jsonl`` (one sorted-key JSON object per line — the
input format of ``benchmarks/trace_report.py``) and ``export_chrome``
(Chrome trace-event JSON, Perfetto-viewable: replica = process, request =
thread lane, engine steps on lane 0).
"""
from __future__ import annotations

import json
from collections import deque
from typing import Dict, List, Optional, Tuple

# request lifecycle stages (the waterfall axes) and terminal outcomes
STAGES = ("queue", "prefill", "decode", "transfer", "stall")
OUTCOMES = ("finished", "cancelled", "expired", "failed", "shed")

# ring capacities: events are ~7 small dict entries each; 256k events is a
# few tens of MB worst-case, far below the unbounded-timeline behaviour
# this layer replaces
EVENT_RING_CAP = 262_144

# default latency histogram buckets (seconds, virtual time)
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def _r(t: float) -> float:
    """Canonical time/duration rounding: one shared quantum so exporters,
    reports and golden tests all see the same digits."""
    return round(float(t), 9)


def _fmt_value(v) -> str:
    """Deterministic Prometheus sample rendering (repr is stable for
    floats in CPython; ints render without a decimal point)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Counter:
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Gauge:
    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name, self.help, self.value = name, help, 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name, self.help = name, help
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, le in enumerate(self.buckets):
            if v <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class MetricsRegistry:
    """Counters/gauges/histograms with windowed snapshots + Prometheus
    text exposition.  Creation is memoized by name; re-registering a name
    as a different type raises."""

    def __init__(self, *, series_capacity: int = 4096):
        self._metrics: Dict[str, object] = {}
        # windowed time-series: one row per snapshot(t), ring-bounded
        self.series: deque = deque(maxlen=series_capacity)

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name!r} already registered as "
                             f"{type(m).__name__}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(name, Histogram, help, buckets)

    def snapshot(self, t: float) -> dict:
        """Capture every metric's current value as one time-series row."""
        row: dict = {"t": _r(t)}
        for name, m in self._metrics.items():
            if isinstance(m, Histogram):
                row[name] = {"count": m.count, "sum": _r(m.sum)}
            else:
                row[name] = _r(m.value) if isinstance(m.value, float) \
                    else m.value
        self.series.append(row)
        return row

    def exposition(self) -> str:
        """Prometheus text format (deterministic: insertion order, repr
        floats).  The real tier serves this from a scrape endpoint; the
        sim tier writes it to ``--metrics-out``."""
        lines: List[str] = []
        for name, m in self._metrics.items():
            kind = {"Counter": "counter", "Gauge": "gauge",
                    "Histogram": "histogram"}[type(m).__name__]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {kind}")
            if isinstance(m, Histogram):
                acc = 0
                for le, c in zip(m.buckets, m.counts):
                    acc += c
                    lines.append(f'{name}_bucket{{le="{_fmt_value(le)}"}} '
                                 f"{acc}")
                lines.append(f'{name}_bucket{{le="+Inf"}} {m.count}')
                lines.append(f"{name}_sum {_fmt_value(m.sum)}")
                lines.append(f"{name}_count {m.count}")
            else:
                lines.append(f"{name} {_fmt_value(m.value)}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# trace recorder
# ---------------------------------------------------------------------------


class TraceRecorder:
    """Bounded, deterministic span/event recorder on the virtual clock.

    Every hook early-returns when ``enabled`` is False, and every
    instrumentation site guards on the recorder being attached at all —
    so a run without a recorder executes exactly the pre-recorder code
    path (the CI overhead gate pins this).
    """

    FLEET_PID = -1   # process lane for fleet-level (non-replica) events

    def __init__(self, *, capacity: int = EVENT_RING_CAP,
                 registry: Optional[MetricsRegistry] = None,
                 snapshot_interval_s: float = 1.0,
                 enabled: bool = True):
        self.enabled = enabled
        self.events: deque = deque(maxlen=capacity)
        self.dropped = 0
        self.registry = registry if registry is not None else MetricsRegistry()
        self.snapshot_interval_s = snapshot_interval_s
        self._next_snapshot = snapshot_interval_s
        # req_id -> [stage, start_t, replica]: the open stage span
        self._open: Dict[int, list] = {}
        # req_id -> arrival (for the e2e histogram at finish)
        self._arrival: Dict[int, float] = {}
        self.outcomes: Dict[int, str] = {}

    # -- low-level emit -------------------------------------------------
    def _emit(self, ev: dict) -> None:
        if self.events.maxlen is not None \
                and len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def open_spans(self) -> Dict[int, tuple]:
        """Still-open request stage spans (req_id -> (stage, start,
        replica)).  Empty after a drained run — the span-balance test."""
        return {rid: tuple(v) for rid, v in self._open.items()}

    # -- request lifecycle ----------------------------------------------
    def req_submit(self, rid: int, t: float, replica: int, *,
                   priority: str = "interactive", prompt_len: int = 0,
                   output_len: int = 0) -> None:
        """Open the ``queue`` stage at arrival.  A resubmission (crash
        recovery retry) transitions the open stage back to ``queue``
        instead of opening a second lane."""
        if not self.enabled:
            return
        if rid in self._open:
            self.req_stage(rid, t, "queue", replica)
            return
        t = _r(t)
        self._open[rid] = ["queue", t, replica]
        self._arrival[rid] = t
        self._emit({"ph": "i", "cat": "request", "name": "submit", "t": t,
                    "pid": replica, "req": rid,
                    "args": {"priority": priority, "prompt_len": prompt_len,
                             "output_len": output_len}})
        self.registry.counter(
            "requests_submitted_total",
            "requests submitted to an engine (incl. crash retries)").inc()

    def req_stage(self, rid: int, t: float, stage: str,
                  replica: Optional[int] = None, **args) -> None:
        """Close the request's open stage span and open ``stage`` at ``t``.

        Times are clamped monotonically per request (a cross-replica
        crash-recovery hop may carry a lagging clock), so stage spans are
        always contiguous and non-negative — the partition identity."""
        if not self.enabled:
            return
        t = _r(t)
        st = self._open.get(rid)
        if st is not None:
            prev_stage, t0, rep0 = st
            if t < t0:
                t = t0
            if prev_stage == stage:
                return  # idempotent re-entry (e.g. retry into queue)
            self._emit({"ph": "X", "cat": "request", "name": prev_stage,
                        "t": t0, "dur": _r(t - t0), "pid": rep0, "req": rid,
                        "args": {}})
        rep = replica if replica is not None else (st[2] if st else 0)
        self._open[rid] = [stage, t, rep]

    def req_end(self, rid: int, t: float, outcome: str,
                replica: Optional[int] = None, **args) -> None:
        """Close the request's open span and stamp its terminal outcome."""
        if not self.enabled:
            return
        t = _r(t)
        st = self._open.pop(rid, None)
        rep = replica
        if st is not None:
            stage, t0, rep0 = st
            if t < t0:
                t = t0
            self._emit({"ph": "X", "cat": "request", "name": stage,
                        "t": t0, "dur": _r(t - t0), "pid": rep0, "req": rid,
                        "args": {}})
            if rep is None:
                rep = rep0
        self.outcomes[rid] = outcome
        self._emit({"ph": "i", "cat": "request", "name": outcome, "t": t,
                    "pid": rep if rep is not None else 0, "req": rid,
                    "args": {k: (_r(v) if isinstance(v, float) else v)
                             for k, v in sorted(args.items())}})
        self.registry.counter(f"requests_{outcome}_total",
                              f"requests that ended {outcome}").inc()
        arrival = self._arrival.pop(rid, None)
        if outcome == "finished" and arrival is not None:
            self.registry.histogram(
                "request_e2e_seconds",
                "end-to-end latency of finished requests").observe(t - arrival)

    # -- engine step spans ----------------------------------------------
    def step_span(self, t0: float, t1: float, replica: int, *, batch: int,
                  gamma: int, tokens: int, accepted: int,
                  prefill_tokens: int = 0, draft_ok: bool = True,
                  forced_off: bool = False) -> None:
        """One decode (or hybrid) step on the engine lane: the
        (batch, gamma, n_accepted) tuple the planner observed."""
        if not self.enabled:
            return
        t0, t1 = _r(t0), _r(t1)
        self._emit({"ph": "X", "cat": "engine", "name": "step", "t": t0,
                    "dur": _r(t1 - t0), "pid": replica,
                    "args": {"B": batch, "gamma": gamma, "tokens": tokens,
                             "accepted": accepted,
                             "prefill_tokens": prefill_tokens,
                             "draft_ok": draft_ok,
                             "forced_off": forced_off}})
        reg = self.registry
        reg.counter("steps_total", "engine steps executed").inc()
        reg.counter("tokens_committed_total", "committed tokens").inc(tokens)
        if gamma > 0:
            reg.counter("spec_steps_total", "steps with gamma > 0").inc()
            reg.counter("draft_tokens_proposed_total",
                        "draft tokens proposed (gamma * B)").inc(gamma * batch)
            reg.counter("draft_tokens_accepted_total",
                        "draft tokens accepted by verification").inc(accepted)
        reg.gauge("batch_size", "decode batch size").set(batch)
        reg.gauge("gamma_selected", "speculative length chosen").set(gamma)
        reg.histogram("step_latency_seconds",
                      "engine step latency").observe(t1 - t0)
        if t1 >= self._next_snapshot:
            reg.snapshot(t1)
            while self._next_snapshot <= t1:
                self._next_snapshot += self.snapshot_interval_s

    # -- point events ----------------------------------------------------
    def instant(self, cat: str, name: str, t: float, *,
                replica: Optional[int] = None, args: Optional[dict] = None
                ) -> None:
        """Fleet / engine / memory point event (brownout transition,
        autoscale, crash, detect, recover, shed, offload, reload, spill,
        restore, preempt, fault...)."""
        if not self.enabled:
            return
        self._emit({"ph": "i", "cat": cat, "name": name, "t": _r(t),
                    "pid": self.FLEET_PID if replica is None else replica,
                    "args": {k: (_r(v) if isinstance(v, float) else v)
                             for k, v in sorted((args or {}).items())}})
        self.registry.counter(f"events_{cat}_{name}_total",
                              f"{cat}/{name} events").inc()

    # -- exporters -------------------------------------------------------
    def jsonl_lines(self) -> List[str]:
        return [json.dumps(e, sort_keys=True, separators=(",", ":"))
                for e in self.events]

    def jsonl_bytes(self) -> bytes:
        """The full JSONL trace as bytes — the golden-determinism unit."""
        body = "\n".join(self.jsonl_lines())
        return (body + "\n").encode("utf-8") if body else b""

    def export_jsonl(self, path: str) -> None:
        with open(path, "wb") as f:
            f.write(self.jsonl_bytes())

    def chrome_events(self) -> List[dict]:
        """Chrome trace-event list: replica = process, request = thread
        lane (tid = req_id + 1), engine steps on lane 0, fleet events on
        their own process."""
        out: List[dict] = []
        pids: Dict[int, set] = {}
        for e in self.events:
            pid = e["pid"]
            rid = e.get("req")
            tid = 0 if rid is None else rid + 1
            pids.setdefault(pid, set()).add(tid)
            ts = _r(e["t"] * 1e6)
            row = {"name": e["name"], "cat": e["cat"], "pid": pid,
                   "tid": tid, "ts": ts, "args": e.get("args", {})}
            if e["ph"] == "X":
                row["ph"] = "X"
                row["dur"] = _r(e["dur"] * 1e6)
            else:
                row["ph"] = "i"
                row["s"] = "t" if rid is not None else "p"
            out.append(row)
        meta: List[dict] = []
        for pid in sorted(pids):
            pname = "fleet" if pid == self.FLEET_PID else f"replica {pid}"
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": pname}})
            for tid in sorted(pids[pid]):
                tname = "engine" if tid == 0 else f"req {tid - 1}"
                meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                             "tid": tid, "args": {"name": tname}})
        return meta + out

    def export_chrome(self, path: str) -> None:
        payload = {"traceEvents": self.chrome_events(),
                   "displayTimeUnit": "ms"}
        with open(path, "w", encoding="utf-8", newline="\n") as f:
            json.dump(payload, f, sort_keys=True, separators=(",", ":"))
            f.write("\n")

    def export(self, path: str, fmt: str = "jsonl") -> None:
        if fmt == "jsonl":
            self.export_jsonl(path)
        elif fmt == "chrome":
            self.export_chrome(path)
        else:
            raise ValueError(f"unknown trace format {fmt!r}")
