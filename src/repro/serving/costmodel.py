"""Analytical roofline step-latency model (the paper-scale execution tier).

Step latency = max(compute term, HBM term) + fixed dispatch overhead, the
same three-term structure as EXPERIMENTS.md §Roofline.  This model is what
reproduces the paper's Figure 1/2 crossover on TPU v5e: at small batch the
decode step is weight-read-bound (speculation amortises the reads), at large
batch the verification FLOPs push the step into the compute-bound regime
where speculation loses.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ModelConfig
from ..models import registry


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # FLOP/s (bf16/fp16)
    hbm_bw: float              # bytes/s
    hbm_bytes: float           # capacity
    step_overhead: float       # fixed per-step dispatch latency (s)
    host_link_bw: float        # bytes/s host<->device (offload path)
    ici_bw: float = 0.0        # bytes/s per link (multi-chip)
    chips: int = 1


TPU_V5E = HardwareProfile(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
    step_overhead=35e-6, host_link_bw=32e9, ici_bw=50e9, chips=1)

# the paper's single-GPU testbed (for faithful-reproduction benchmarks)
RTX_4090 = HardwareProfile(
    name="rtx4090", peak_flops=165e12, hbm_bw=1008e9, hbm_bytes=24e9,
    step_overhead=120e-6, host_link_bw=25e9)

A100_40G = HardwareProfile(
    name="a100-40g", peak_flops=312e12, hbm_bw=1555e9, hbm_bytes=40e9,
    step_overhead=90e-6, host_link_bw=25e9)


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    if cfg.family == "ssm":
        return 0  # O(1) state
    layers = cfg.dec_layers if cfg.family == "encdec" else cfg.num_layers
    if cfg.family == "hybrid":
        from ..models.hybrid import attn_points
        layers = len(attn_points(cfg))
    return 2 * layers * cfg.num_kv_heads * cfg.resolved_head_dim * dtype_bytes


class RooflineCostModel:
    """Latency oracle for one hardware profile."""

    def __init__(self, hw: HardwareProfile = TPU_V5E, *, dtype_bytes: int = 2,
                 mfu: float = 0.6, bwu: float = 0.8):
        self.hw = hw
        self.dtype_bytes = dtype_bytes
        self.mfu = mfu   # achievable fraction of peak compute
        self.bwu = bwu   # achievable fraction of HBM bandwidth
        self._pcache = {}

    # ------------------------------------------------------------------
    def _params(self, cfg: ModelConfig):
        key = cfg.name
        if key not in self._pcache:
            self._pcache[key] = (registry.param_count(cfg),
                                 registry.active_param_count(cfg))
        return self._pcache[key]

    def weight_bytes(self, cfg: ModelConfig) -> float:
        return self._params(cfg)[0] * self.dtype_bytes

    # ------------------------------------------------------------------
    def decode_latency(self, cfg: ModelConfig, batch: int, ctx: int,
                       n_tokens: int = 1) -> float:
        """One forward over `n_tokens` new positions per sequence."""
        total, active = self._params(cfg)
        toks = batch * n_tokens
        flops = 2.0 * active * toks
        # attention over the KV cache
        if cfg.num_heads:
            flops += 2.0 * 2.0 * toks * ctx * cfg.num_heads * cfg.resolved_head_dim
        mem = (self.weight_bytes(cfg)
               + batch * ctx * kv_bytes_per_token(cfg, self.dtype_bytes)
               + toks * cfg.d_model * self.dtype_bytes * 8)
        chips = max(self.hw.chips, 1)
        t_compute = flops / (self.hw.peak_flops * self.mfu * chips)
        t_mem = mem / (self.hw.hbm_bw * self.bwu * chips)
        return max(t_compute, t_mem) + self.hw.step_overhead

    def prefill_latency(self, cfg: ModelConfig, batch: int, seq: int) -> float:
        total, active = self._params(cfg)
        toks = batch * seq
        flops = 2.0 * active * toks
        if cfg.num_heads:
            flops += 2.0 * 2.0 * batch * seq * seq * cfg.num_heads \
                * cfg.resolved_head_dim / 2.0  # causal half
        mem = self.weight_bytes(cfg) + toks * cfg.d_model * self.dtype_bytes * 12
        chips = max(self.hw.chips, 1)
        t_compute = flops / (self.hw.peak_flops * self.mfu * chips)
        t_mem = mem / (self.hw.hbm_bw * self.bwu * chips)
        return max(t_compute, t_mem) + self.hw.step_overhead

    def _hybrid_terms(self, cfg: ModelConfig, prefill_tokens: int,
                      batch: int, ctx: int, n_tokens: int = 1,
                      prefill_ctx: int | None = None) -> tuple:
        """(compute seconds, HBM seconds) of one fused mixed step — the two
        roofline terms, exposed so the adaptive chunk budget can find their
        crossover (the compute-bound knee)."""
        total, active = self._params(cfg)
        pctx = prefill_ctx if prefill_ctx is not None else ctx
        toks = batch * n_tokens + prefill_tokens
        flops = 2.0 * active * toks
        if cfg.num_heads:
            hd = cfg.num_heads * cfg.resolved_head_dim
            # decode positions attend to the full KV cache
            flops += 2.0 * 2.0 * batch * n_tokens * ctx * hd
            # chunk positions attend causally to their own prefix
            flops += 2.0 * 2.0 * prefill_tokens * pctx * hd / 2.0
        mem = (self.weight_bytes(cfg)
               + batch * ctx * kv_bytes_per_token(cfg, self.dtype_bytes)
               + toks * cfg.d_model * self.dtype_bytes * 8)
        chips = max(self.hw.chips, 1)
        t_compute = flops / (self.hw.peak_flops * self.mfu * chips)
        t_mem = mem / (self.hw.hbm_bw * self.bwu * chips)
        return t_compute, t_mem

    def hybrid_step_latency(self, cfg: ModelConfig, prefill_tokens: int,
                            batch: int, ctx: int, n_tokens: int = 1,
                            prefill_ctx: int | None = None) -> float:
        """One fused forward over a mixed batch: ``batch * n_tokens`` decode
        positions plus ``prefill_tokens`` prompt-chunk positions whose
        prefixes reach ``prefill_ctx`` tokens (defaults to ``ctx``).

        The chunk shares the single weight-read pass with the decode batch —
        this is the chunked-prefill payoff: in the memory-bound (small-batch)
        regime the chunk's marginal cost is almost pure FLOPs, instead of a
        whole extra weight pass per monolithic prefill call."""
        t_compute, t_mem = self._hybrid_terms(cfg, prefill_tokens, batch, ctx,
                                              n_tokens, prefill_ctx)
        return max(t_compute, t_mem) + self.hw.step_overhead

    def knee_chunk_tokens(self, cfg: ModelConfig, *, batch: int = 0,
                          ctx: int = 1024, lo: int = 16,
                          hi: int = 8192) -> int:
        """Adaptive per-step chunk budget: the largest prefill-token count
        that keeps the fused mixed step on the memory-bound side of the
        roofline (compute term <= HBM term).  Up to this knee the chunk
        rides the weight-read pass almost for free; past it every extra
        chunk token stretches the step and hurts running sequences' TPOT —
        exactly the crossover the ROADMAP's adaptive-budget item asks for."""
        def compute_bound(pt: int) -> bool:
            t_c, t_m = self._hybrid_terms(cfg, pt, batch, ctx)
            return t_c > t_m

        if compute_bound(lo):
            return lo
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if compute_bound(mid):
                hi = mid - 1
            else:
                lo = mid
        return lo

    # ------------------------------------------------------------------
    def ar_step_latency(self, target: ModelConfig, batch: int, ctx: int) -> float:
        return self.decode_latency(target, batch, ctx, 1)

    def spec_step_latency(self, target: ModelConfig, draft: ModelConfig,
                          batch: int, ctx: int, gamma: int) -> float:
        """Chain-draft gamma (+1 sync) steps, then one (gamma+1)-token verify."""
        t_draft = (gamma + 1) * self.decode_latency(draft, batch, ctx, 1)
        t_verify = self.decode_latency(target, batch, ctx, gamma + 1)
        return t_draft + t_verify

    # ------------------------------------------------------------------
    def offload_latency(self, cfg: ModelConfig) -> float:
        return self.weight_bytes(cfg) / self.hw.host_link_bw

    def reload_latency(self, cfg: ModelConfig) -> float:
        return self.weight_bytes(cfg) / self.hw.host_link_bw

    def resolve_chunk_tokens(self, value, cfg: ModelConfig | None = None,
                             *, fallback: int = 256) -> int:
        """CLI helper: ``--chunk-tokens auto`` -> the roofline knee for this
        hardware/model; a plain integer passes through; ``fallback`` covers
        the auto case when no model config is available."""
        if value == "auto":
            if cfg is None:
                return fallback
            return self.knee_chunk_tokens(cfg)
        return int(value)

    def kv_capacity_tokens(self, target: ModelConfig, draft: ModelConfig | None,
                           *, reserve_frac: float = 0.1) -> int:
        """How many KV tokens fit beside the weights."""
        used = self.weight_bytes(target)
        if draft is not None:
            used += self.weight_bytes(draft)
        avail = self.hw.hbm_bytes * self.hw.chips * (1 - reserve_frac) - used
        per = max(kv_bytes_per_token(target, self.dtype_bytes), 1)
        return max(int(avail / per), 0)
