"""Workload generation: arrival processes + dataset length distributions.

The paper's datasets (ShareGPT / Alpaca / SpecBench) and the Azure trace are
not available offline; we synthesise length distributions matched to the
shapes reported in Figure 8 (lognormal fits) and a dynamic request-rate trace
shaped like Figure 10.  Acceptance quality per request is drawn from a Beta
distribution (harder requests accept fewer draft tokens).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from .request import Request

# (prompt mu, prompt sigma, output mu, output sigma, alpha_a, alpha_b,
#  slo_ttft) — lognormal parameters matched to Figure 8's reported
# input/output shapes; slo_ttft is the per-dataset first-token deadline (s)
# used for SLO-attainment / goodput accounting (AdaSpec-style serving SLOs:
# interactive chat gets a tighter deadline than the mixed benchmark).
DATASETS = {
    # chat: long-ish prompts, medium outputs, moderate acceptance
    "sharegpt": dict(p_mu=5.4, p_sigma=0.9, o_mu=5.2, o_sigma=0.8,
                     a_a=6.0, a_b=3.0, slo_ttft=1.0),
    # instruction: short prompts, short outputs
    "alpaca": dict(p_mu=3.6, p_sigma=0.7, o_mu=4.2, o_sigma=0.8,
                   a_a=5.0, a_b=3.0, slo_ttft=0.5),
    # mixed six-task benchmark: broad spread, hardest for the draft
    "specbench": dict(p_mu=5.0, p_sigma=1.2, o_mu=5.0, o_sigma=1.0,
                      a_a=4.0, a_b=3.0, slo_ttft=1.5),
    # templated serving (shared system prompt / few-shot header): the
    # length parameters describe the per-request SUFFIX; every prompt is
    # template_len shared tokens + a drawn suffix.  The prefix-sharing
    # workload: identical prefix blocks per request are exactly what
    # copy-on-write prefix caching reclaims.
    "templated": dict(p_mu=3.6, p_sigma=0.7, o_mu=4.2, o_sigma=0.8,
                      a_a=5.0, a_b=3.0, slo_ttft=0.5, template_len=512),
    # multi-turn chat sessions: each turn's prompt is the full conversation
    # history (initial pasted context + alternating user/assistant tokens),
    # re-submitted after a think-time gap.  p_* describe the per-turn USER
    # message; o_* the assistant response; context_len the turn-0 system
    # prompt / pasted context; think_s the mean think-time between turns.
    # The host-KV-offload workload: between turns a session's prefix blocks
    # go cold and are evicted from a tight device pool — warm-turn TTFT
    # then hinges on whether the evicted history is restorable.
    "sessions": dict(p_mu=4.0, p_sigma=0.6, o_mu=4.0, o_sigma=0.5,
                     a_a=6.0, a_b=3.0, slo_ttft=1.0, context_len=768,
                     turns=6, think_s=8.0),
    # bimodal mix of long-prompt/short-decode (document QA: prefill-bound)
    # and short-prompt/long-decode (generation: decode-bound) traffic — the
    # disaggregation workload.  Colocated replicas interleave both regimes
    # on one chunk budget: resident long decodes eat the budget's decode
    # slots and the KV pool, so long prompts queue behind them and p99 TTFT
    # collapses.  A split fleet prefills on replicas that shed their decode
    # work and decodes on replicas that never see a prompt.  qa_frac is the
    # long-prompt share; the qa_*/gen_* pairs parameterise the two modes.
    # qa_frac=0.25: one document-QA prompt per three long generations —
    # enough long decodes to clog a colocated fleet's batch slots, enough
    # prompts for the disaggregated prefill pool to matter (the regime the
    # --only disagg grid and launch/serve.py examples are tuned to)
    "mixed": dict(p_mu=5.0, p_sigma=1.0, o_mu=4.5, o_sigma=0.9,
                  a_a=5.0, a_b=3.0, slo_ttft=1.0, qa_frac=0.25,
                  qa_p_mu=7.0, qa_p_sigma=0.5, qa_o_mu=3.2, qa_o_sigma=0.5,
                  gen_p_mu=3.8, gen_p_sigma=0.5, gen_o_mu=6.1,
                  gen_o_sigma=0.4),
}


def dataset_slo(dataset: str, slo: "float | None" = None) -> "float | None":
    """Resolve the TTFT deadline: explicit override (<=0 disables) or the
    per-dataset default."""
    if slo is not None:
        return slo if slo > 0 else None
    return DATASETS[dataset].get("slo_ttft")


def _lengths(rng, mu, sigma, n, lo, hi):
    x = rng.lognormal(mu, sigma, size=n)
    return np.clip(x, lo, hi).astype(int)


def poisson_requests(rate_qps: float, n: int, *, dataset: str = "sharegpt",
                     seed: int = 0, max_prompt: int = 2048,
                     max_output: int = 1024,
                     slo: "float | None" = None) -> List[Request]:
    """Poisson arrivals at a static rate."""
    rng = np.random.default_rng(seed)
    d = DATASETS[dataset]
    deadline = dataset_slo(dataset, slo)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    arrivals = np.cumsum(gaps)
    prompts = _lengths(rng, d["p_mu"], d["p_sigma"], n, 4, max_prompt)
    outputs = _lengths(rng, d["o_mu"], d["o_sigma"], n, 4, max_output)
    alphas = rng.beta(d["a_a"], d["a_b"], size=n)
    return [Request(i, float(arrivals[i]), int(prompts[i]), int(outputs[i]),
                    float(alphas[i]), slo=deadline) for i in range(n)]


def dynamic_rate_trace(duration_s: float = 120.0, *, low: float = 2.0,
                       high: float = 30.0, period_s: float = 40.0,
                       seed: int = 0) -> "RateTrace":
    """Figure-10-like trace: alternating low/high phases with ramps."""
    rng = np.random.default_rng(seed)
    ts, rates = [], []
    t = 0.0
    while t < duration_s:
        phase = (t // period_s) % 2
        base = low if phase == 0 else high
        jitter = rng.uniform(0.8, 1.2)
        ts.append(t)
        rates.append(base * jitter)
        t += period_s / 8
    return RateTrace(np.asarray(ts), np.asarray(rates))


def bursty_trace(*, base: float = 4.0, spike: float = 40.0,
                 base_s: float = 20.0, spike_s: float = 15.0,
                 drain_s: float = 25.0, drain: "float | None" = None,
                 jitter: float = 0.1, knot_s: float = 1.0,
                 seed: int = 0) -> "RateTrace":
    """Regime-shift arrival trace: baseline -> spike -> drain.

    The autoscaler workload: a steady ``base`` qps phase, an abrupt
    ``spike`` qps burst of ``spike_s`` seconds (the regime shift a static
    fleet must over-provision for), then a ``drain`` phase (default
    ``base / 2``) long enough for an elastic fleet to scale back down.
    Knots every ``knot_s`` seconds carry seeded multiplicative jitter of
    +-``jitter`` so the phases are noisy but exactly reproducible."""
    if drain is None:
        drain = base / 2.0
    rng = np.random.default_rng(seed)
    ts, rates = [], []
    t = 0.0
    total = base_s + spike_s + drain_s
    while t < total:
        if t < base_s:
            r = base
        elif t < base_s + spike_s:
            r = spike
        else:
            r = drain
        ts.append(t)
        rates.append(r * rng.uniform(1.0 - jitter, 1.0 + jitter))
        t += knot_s
    return RateTrace(np.asarray(ts), np.asarray(rates))


def surge_trace(*, base: float = 6.0, surge_mult: float = 3.0,
                base_s: float = 8.0, surge_s: float = 30.0,
                recover_s: float = 12.0, jitter: float = 0.05,
                knot_s: float = 1.0, seed: int = 0) -> "RateTrace":
    """Sustained-overload trace: baseline -> ``surge_mult``x plateau ->
    recovery at baseline.

    The brownout workload.  Unlike ``bursty_trace`` (a short spike an
    elastic fleet absorbs by scaling), the surge plateau is LONG —
    ``surge_s`` seconds at ``surge_mult`` times baseline, deliberately past
    the fleet's capacity — so the only question is *how* service degrades:
    collapse (every class's tail blows up together) or a controlled
    brownout (cheap capabilities shed first, interactive traffic protected).
    Knots every ``knot_s`` seconds carry seeded jitter, exactly
    reproducible."""
    rng = np.random.default_rng(seed)
    ts, rates = [], []
    t = 0.0
    total = base_s + surge_s + recover_s
    while t < total:
        if t < base_s or t >= base_s + surge_s:
            r = base
        else:
            r = base * surge_mult
        ts.append(t)
        rates.append(r * rng.uniform(1.0 - jitter, 1.0 + jitter))
        t += knot_s
    return RateTrace(np.asarray(ts), np.asarray(rates))


# per-class service contract of the surge workload: (mix weight, TTFT SLO
# seconds, hard end-to-end deadline seconds).  interactive is tight and
# deadline-bound; batch is loose; best_effort carries an SLO for accounting
# but no hard deadline (it is capped/shed by the brownout ladder instead)
SURGE_CLASSES = {
    "interactive": (0.40, 0.5, 8.0),
    "batch": (0.40, 3.0, 20.0),
    "best_effort": (0.20, 6.0, None),
}


def surge_requests(n: int, *, trace: "RateTrace | None" = None,
                   rate_qps: "float | None" = None,
                   dataset: str = "alpaca", seed: int = 0,
                   max_prompt: int = 2048, max_output: int = 1024,
                   classes: "dict | None" = None) -> List[Request]:
    """Mixed-priority-class arrivals for the overload benchmark.

    Arrivals follow ``trace`` (thinning) when given, else a static Poisson
    at ``rate_qps``.  Each request draws a priority class from the
    ``classes`` mix (default ``SURGE_CLASSES``) which fixes its TTFT SLO
    and hard deadline.  Everything is seeded: two calls with the same
    arguments produce identical streams."""
    rng = np.random.default_rng(seed)
    d = DATASETS[dataset]
    spec = classes if classes is not None else SURGE_CLASSES
    names = list(spec)
    probs = np.asarray([spec[c][0] for c in names], dtype=float)
    probs = probs / probs.sum()
    if trace is not None:
        rmax = float(trace.rates.max())
        arrivals: List[float] = []
        t = 0.0
        while len(arrivals) < n:
            t += rng.exponential(1.0 / rmax)
            if rng.uniform() < trace.rate_at(t) / rmax:
                arrivals.append(t)
    else:
        if rate_qps is None:
            raise ValueError("surge_requests needs a trace or a rate_qps")
        arrivals = list(np.cumsum(rng.exponential(1.0 / rate_qps, size=n)))
    prompts = _lengths(rng, d["p_mu"], d["p_sigma"], n, 4, max_prompt)
    outputs = _lengths(rng, d["o_mu"], d["o_sigma"], n, 4, max_output)
    alphas = rng.beta(d["a_a"], d["a_b"], size=n)
    picks = rng.choice(len(names), size=n, p=probs)
    out = []
    for i in range(n):
        cls = names[int(picks[i])]
        _, slo, deadline = spec[cls]
        out.append(Request(i, float(arrivals[i]), int(prompts[i]),
                           int(outputs[i]), float(alphas[i]), slo=slo,
                           priority=cls, deadline=deadline))
    return out


def cancellation_storm(requests: List[Request], *, frac: float = 0.15,
                       start: float = 0.0, end: float = 10.0,
                       seed: int = 0) -> List[tuple]:
    """Pre-generated client-cancellation schedule: seeded ``frac`` sample
    of the requests arriving before ``end``, each cancelled at a seeded
    time in ``[max(start, arrival), end)``.

    This is the WORKLOAD-level storm: explicit ``(t, req_id)`` pairs
    handed to ``ServingCluster(cancels=...)``, so two bench cells that
    differ only in control policy (brownout on vs off) cancel the SAME
    requests at the SAME instants.  The dynamic in-flight variant —
    victims drawn from whatever happens to be live — is the fault-spec
    ``cancelstorm:`` grammar (serving/faults.py), composable with
    crash/straggler chaos."""
    if not 0.0 < frac <= 1.0:
        raise ValueError("storm frac must be in (0, 1]")
    if end <= start:
        raise ValueError("storm window must have end > start")
    rng = np.random.default_rng(seed)
    cands = [r for r in requests if r.arrival < end]
    if not cands:
        return []
    k = min(max(int(round(frac * len(cands))), 1), len(cands))
    idx = rng.choice(len(cands), size=k, replace=False)
    out = []
    for i in sorted(int(j) for j in idx):
        r = cands[i]
        lo = max(start, r.arrival + 1e-6)
        hi = max(end, lo + 1e-6)
        out.append((float(rng.uniform(lo, hi)), r.req_id))
    return sorted(out)


@dataclass
class RateTrace:
    times: np.ndarray
    rates: np.ndarray

    def rate_at(self, t: float) -> float:
        i = int(np.searchsorted(self.times, t, side="right")) - 1
        return float(self.rates[max(i, 0)])

    def sample_requests(self, n: int, *, dataset: str = "sharegpt",
                        seed: int = 0, max_prompt: int = 2048,
                        max_output: int = 1024,
                        slo: "float | None" = None) -> List[Request]:
        """Non-homogeneous Poisson via thinning."""
        rng = np.random.default_rng(seed)
        d = DATASETS[dataset]
        deadline = dataset_slo(dataset, slo)
        rmax = float(self.rates.max())
        arrivals: List[float] = []
        t = 0.0
        while len(arrivals) < n:
            t += rng.exponential(1.0 / rmax)
            if rng.uniform() < self.rate_at(t) / rmax:
                arrivals.append(t)
        prompts = _lengths(rng, d["p_mu"], d["p_sigma"], n, 4, max_prompt)
        outputs = _lengths(rng, d["o_mu"], d["o_sigma"], n, 4, max_output)
        alphas = rng.beta(d["a_a"], d["a_b"], size=n)
        return [Request(i, arrivals[i], int(prompts[i]), int(outputs[i]),
                        float(alphas[i]), slo=deadline) for i in range(n)]


def templated_requests(rate_qps: float, n: int, *, dataset: str = "templated",
                       template_len: "int | None" = None,
                       num_templates: int = 1, seed: int = 0,
                       max_prompt: int = 2048, max_output: int = 1024,
                       vocab: int = 32000,
                       slo: "float | None" = None) -> List[Request]:
    """Poisson arrivals whose prompts share common template prefixes.

    Every request's ``prompt_tokens`` is one of ``num_templates`` distinct
    ``template_len``-token system prompts (each drawn once from ``seed``;
    the per-request template id is a seeded uniform draw) followed by a
    per-request suffix whose length follows the dataset's prompt
    distribution — the canonical prefix-caching workload, and with
    ``num_templates > 1`` the sticky-routing workload: an affinity router
    can partition the template population across replicas so each
    replica's cache specialises, where load-only routing scatters every
    template onto every replica.  ``template_len=0`` produces fully
    disjoint prompts of the same shape (the caching-off control arm).
    Token ids are synthesised (the simulated tier only hashes them; the
    real tier can cap ``vocab`` to the model's)."""
    rng = np.random.default_rng(seed)
    d = DATASETS[dataset]
    if template_len is None:
        template_len = d.get("template_len", 0)
    deadline = dataset_slo(dataset, slo)
    # num_templates == 1 keeps the historical draw order byte-identical
    templates = [rng.integers(0, vocab, size=template_len).tolist()
                 for _ in range(max(num_templates, 1))]
    tids = (rng.integers(0, num_templates, size=n)
            if num_templates > 1 else np.zeros(n, dtype=int))
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    arrivals = np.cumsum(gaps)
    suffixes = _lengths(rng, d["p_mu"], d["p_sigma"], n, 4,
                        max(max_prompt - template_len, 4))
    outputs = _lengths(rng, d["o_mu"], d["o_sigma"], n, 4, max_output)
    alphas = rng.beta(d["a_a"], d["a_b"], size=n)
    out = []
    for i in range(n):
        sfx = rng.integers(0, vocab, size=int(suffixes[i])).tolist()
        toks = templates[int(tids[i])] + sfx
        out.append(Request(i, float(arrivals[i]), len(toks),
                           int(outputs[i]), float(alphas[i]),
                           prompt_tokens=toks, slo=deadline))
    return out


def mixed_requests(rate_qps: float, n: int, *, dataset: str = "mixed",
                   qa_frac: "float | None" = None, seed: int = 0,
                   max_prompt: int = 2048, max_output: int = 1024,
                   slo: "float | None" = None) -> List[Request]:
    """Poisson arrivals from a bimodal long-prompt / long-decode mix.

    Each request is independently a document-QA request (probability
    ``qa_frac``: long prompt, short answer — prefill-bound) or a generation
    request (short prompt, long completion — decode-bound).  The
    disaggregation workload: on a colocated fleet the resident long decodes
    consume the chunked-prefill token budget and KV pool on every replica,
    queueing the long prompts behind them; a disaggregated fleet prefills
    where no decode lives and decodes where no prompt lands."""
    rng = np.random.default_rng(seed)
    d = DATASETS[dataset]
    deadline = dataset_slo(dataset, slo)
    if qa_frac is None:
        qa_frac = d.get("qa_frac", 0.5)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    arrivals = np.cumsum(gaps)
    is_qa = rng.uniform(size=n) < qa_frac
    qa_p = _lengths(rng, d["qa_p_mu"], d["qa_p_sigma"], n, 4, max_prompt)
    qa_o = _lengths(rng, d["qa_o_mu"], d["qa_o_sigma"], n, 4, max_output)
    gen_p = _lengths(rng, d["gen_p_mu"], d["gen_p_sigma"], n, 4, max_prompt)
    gen_o = _lengths(rng, d["gen_o_mu"], d["gen_o_sigma"], n, 4, max_output)
    alphas = rng.beta(d["a_a"], d["a_b"], size=n)
    return [Request(i, float(arrivals[i]),
                    int(qa_p[i]) if is_qa[i] else int(gen_p[i]),
                    int(qa_o[i]) if is_qa[i] else int(gen_o[i]),
                    float(alphas[i]), slo=deadline) for i in range(n)]


def session_requests(n_sessions: int, *, turns: "int | None" = None,
                     rate_qps: float = 0.5, think_s: "float | None" = None,
                     context_len: "int | None" = None,
                     dataset: str = "sessions", seed: int = 0,
                     vocab: int = 32000, max_user: int = 512,
                     max_output: int = 256,
                     slo: "float | None" = None) -> List[Request]:
    """Multi-turn chat sessions with think-time returns and history-growing
    prompts.

    Session starts are Poisson at ``rate_qps``.  Each session opens with a
    ``context_len``-token pasted context (system prompt / document) plus a
    user message; every later turn re-submits the FULL history — previous
    prompt, the synthesised assistant response (``o_*``-distributed length),
    and a fresh user message — after an exponential think-time gap (mean
    ``think_s``, floored at 1s so a turn rarely returns before its
    predecessor finishes).  Turn k's prompt therefore extends turn k-1's
    prompt exactly, which makes warm turns the canonical prefix-restore
    workload: registered history blocks match byte-for-byte, while the gap
    gives a tight device pool time to evict them.

    ``Request.session``/``Request.turn`` tag each request for warm/cold
    TTFT splits; req_ids are assigned in global arrival order."""
    rng = np.random.default_rng(seed)
    d = DATASETS[dataset]
    turns = int(turns if turns is not None else d.get("turns", 6))
    think = float(think_s if think_s is not None else d.get("think_s", 8.0))
    ctx_len = int(context_len if context_len is not None
                  else d.get("context_len", 768))
    deadline = dataset_slo(dataset, slo)
    starts = np.cumsum(rng.exponential(1.0 / rate_qps, size=n_sessions))
    rows = []   # (arrival, session, turn, prompt_tokens, output_len, alpha)
    for sid in range(n_sessions):
        history = rng.integers(0, vocab, size=ctx_len).tolist()
        t = float(starts[sid])
        alpha = float(rng.beta(d["a_a"], d["a_b"]))
        for k in range(turns):
            user_len = int(_lengths(rng, d["p_mu"], d["p_sigma"],
                                    1, 4, max_user)[0])
            prompt = history + rng.integers(0, vocab, size=user_len).tolist()
            out_len = int(_lengths(rng, d["o_mu"], d["o_sigma"],
                                   1, 4, max_output)[0])
            rows.append((t, sid, k, prompt, out_len, alpha))
            # the assistant's (synthesised) response joins the history the
            # next turn re-submits; the think-time gap moves the arrival
            history = prompt + rng.integers(0, vocab, size=out_len).tolist()
            t += 1.0 + float(rng.exponential(think))
    rows.sort(key=lambda r: (r[0], r[1], r[2]))
    return [Request(i, arr, len(p), out, alpha, prompt_tokens=p,
                    slo=deadline, session=sid, turn=k)
            for i, (arr, sid, k, p, out, alpha) in enumerate(rows)]


def split_requests(requests: List[Request], n_replicas: int
                   ) -> List[List[Request]]:
    """Deterministically split ONE arrival stream across N replicas.

    Round-robin in arrival order (ties broken by req_id), preserving each
    request's absolute arrival time — the static-partition baseline against
    the dynamic routers in serving/router.py, and the tool for replaying the
    same global trace against fleets of different sizes."""
    if n_replicas < 1:
        raise ValueError("n_replicas must be >= 1")
    shards: List[List[Request]] = [[] for _ in range(n_replicas)]
    for i, req in enumerate(sorted(requests,
                                   key=lambda r: (r.arrival, r.req_id))):
        shards[i % n_replicas].append(req)
    return shards


def tiny_requests(n: int, *, rate_qps: float = 100.0, prompt_len: int = 16,
                  output_len: int = 8, seed: int = 0, vocab: int = 256,
                  alpha: float = 0.9, template_len: int = 0) -> List[Request]:
    """Small deterministic workload for the real-execution tier / tests.

    ``template_len > 0`` makes the first that many prompt tokens identical
    across all requests (shared system prompt), the tiny analogue of
    :func:`templated_requests` for prefix-caching tests."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_qps, size=n)
    arrivals = np.cumsum(gaps)
    template = rng.integers(0, vocab,
                            size=min(template_len, prompt_len)).tolist()
    out = []
    for i in range(n):
        sfx = rng.integers(0, vocab,
                           size=prompt_len - len(template)).tolist()
        out.append(Request(i, float(arrivals[i]), prompt_len, output_len,
                           alpha, prompt_tokens=template + sfx))
    return out
