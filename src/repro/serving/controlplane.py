"""Cluster control plane: per-replica telemetry, predictive admission and
elastic replica autoscaling.

This is the fleet-control layer the Nightjar thesis implies: a serving
system that *reacts to load* should not stop at per-replica knobs
(speculation on/off, memory squeeze, batch growth) — the fleet itself must
route, admit and scale on the same signals.  Everything here observes only
replica queue state, the ``RooflineCostModel`` latency oracle and completed
request statistics — never simulator internals — so the policies transfer
to the real-execution tier unchanged (SpecServe / AdaSpec-style
deadline-headroom control).

Components
----------
``EWMA``
    A bare online exponentially weighted moving average.
``ReplicaTelemetry``
    Per-replica online estimators fed by completed-request stats: EWMA
    TTFT/TPOT plus a *forecast-residual* bias.  At dispatch time the control
    plane records the model-based TTFT forecast for the routed request; when
    the request finishes, ``observed_ttft - forecast`` updates the bias so
    the predictor self-corrects for everything the analytic term misses
    (decode interference, chunk scheduling, planner behaviour).
``ReplicaSnapshot``
    The observable state one routing/admission/scaling decision sees.
``ControlPlane``
    Owns the per-replica telemetry plus the optional admission and
    autoscale controllers; computes the predicted-TTFT queue-delay forecast
    ``max(clock - now, 0) + prefill_latency(backlog + prompt) + bias``.
``AdmissionController``
    Load shedding with hysteresis: when every replica's predicted TTFT
    exceeds ``slo * shed_factor`` the request is rejected at the door
    (counted as *shed*, not as an SLO miss of admitted traffic) and
    admission only resumes once the forecast falls back under
    ``slo * resume_factor`` — no flapping at the threshold.
``AutoscaleController``
    Elastic replica scaling on a windowed SLO-attainment signal (shed
    requests count as misses) plus a fast pressure path (every replica's
    forecast already past the deadline).  Scale-down drains the
    least-loaded replica: it stops receiving traffic, finishes its running
    work, then retires (see ``ServingCluster``).

The routers built on these signals live in serving/router.py
(``SLOAwareRouter``, ``PrefixAffinityRouter``); the elastic fleet mechanics
(``add_replica`` / ``drain_replica``) live in serving/cluster.py.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from .kv_cache import CHAIN_ROOT, chain_hash
from .request import Request


# ---------------------------------------------------------------------------
# routing-stable template identity
# ---------------------------------------------------------------------------

def template_key(tokens, window_tokens: int = 64) -> Optional[int]:
    """Stable content hash of a prompt's first ``window_tokens`` tokens —
    the sticky-routing identity for prefix-affinity dispatch.

    Uses the BlockManager chain-hash scheme (``kv_cache.chain_hash``, a
    seeded blake2b chain over token ids), NEVER Python's per-process-salted
    ``hash()``: two independently
    constructed clusters — or two processes with different
    ``PYTHONHASHSEED`` — must route an identical request stream identically.
    Returns ``None`` when the request carries no token ids (nothing to be
    sticky about)."""
    if not tokens:
        return None
    return chain_hash(CHAIN_ROOT, [int(t) for t in tokens[:window_tokens]])


# ---------------------------------------------------------------------------
# online estimators
# ---------------------------------------------------------------------------


class EWMA:
    """Online exponentially weighted moving average (None until first obs)."""

    def __init__(self, alpha: float = 0.3):
        if not 0.0 < alpha <= 1.0:
            raise ValueError("alpha must be in (0, 1]")
        self.alpha = alpha
        self.value: Optional[float] = None
        self.n = 0

    def update(self, x: float) -> float:
        self.value = x if self.value is None \
            else self.alpha * x + (1.0 - self.alpha) * self.value
        self.n += 1
        return self.value

    def get(self, default: float = 0.0) -> float:
        return self.value if self.value is not None else default


class ReplicaTelemetry:
    """Per-replica online predictors fed by completed-request stats.

    Three estimators drive the queue-delay forecast:
      * ``ewma_ttft`` / ``ewma_tpot`` — the replica's observed service
        levels (reporting + cost-model-free fallback);
      * ``ewma_slope`` — observed seconds of TTFT per backlog token at
        dispatch time.  The roofline prefill term is a *floor*: it prices
        the prompt FLOPs but not decode interference, batching or planner
        behaviour.  The slope estimator learns the replica's TRUE marginal
        delay per queued token from (dispatch backlog, observed TTFT)
        pairs, so the forecast tracks queue growth proportionally instead
        of by a constant additive correction;
      * ``ewma_err`` — residual of the final forecast, self-correcting
        whatever both terms above still miss.
    """

    def __init__(self, alpha: float = 0.3):
        self.ewma_ttft = EWMA(alpha)
        self.ewma_tpot = EWMA(alpha)
        self.ewma_slope = EWMA(alpha)  # seconds per dispatch-backlog token
        self.ewma_err = EWMA(alpha)    # observed_ttft - dispatch_forecast
        self._forecasts: Dict[int, Tuple[float, int]] = {}
        self._consumed = 0             # index into engine.metrics.requests

    def note_dispatch(self, req_id: int, forecast: float,
                      backlog_tokens: int) -> None:
        self._forecasts[req_id] = (forecast, backlog_tokens)

    def consume_finished(self, engine) -> List:
        """Fold the replica's newly finished requests into the estimators;
        returns the new RequestStats records (for cluster-wide windows)."""
        stats = engine.metrics.requests
        fresh = stats[self._consumed:]
        for r in fresh:
            self.ewma_ttft.update(r.ttft)
            self.ewma_tpot.update(r.tpot)
            rec = self._forecasts.pop(r.req_id, None)
            if rec is not None:
                forecast, backlog = rec
                if backlog > 0:
                    # idle dispatches (zero backlog) observe the service
                    # FLOOR, not a queue-delay slope: folding ttft/1 into
                    # the slope would teach the forecaster seconds-per-
                    # backlog-token ≈ baseline TTFT and inflate every
                    # subsequent busy forecast.  The residual bias
                    # (ewma_err) already captures the floor.
                    self.ewma_slope.update(r.ttft / backlog)
                self.ewma_err.update(r.ttft - forecast)
        self._consumed = len(stats)
        return fresh


@dataclass
class ReplicaSnapshot:
    """Observable replica state at one control decision (no sim internals)."""

    replica_id: int
    t: float                      # decision instant (virtual time)
    clock: float                  # the replica's own clock
    load: int                     # pending + waiting + running requests
    decode_count: int             # decode-ready running sequences
    prefill_backlog_tokens: int   # committed, un-materialised prompt tokens
    kv_allocatable: int           # free + cached-reusable blocks
    kv_total: int
    ewma_ttft: float
    ewma_tpot: float
    predicted_ttft: float         # forecast for a nominal next request
    draining: bool = False

    @property
    def kv_headroom_frac(self) -> float:
        return self.kv_allocatable / self.kv_total if self.kv_total else 0.0


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class AdmissionController:
    """Predictive load shedding with hysteresis.

    Sheds an arrival when the BEST replica's predicted TTFT exceeds
    ``slo * shed_factor`` — past that point admitting the request cannot
    meet its deadline and only deepens every queue behind it (the p99
    collapse).  Once shedding starts it persists until the forecast drops
    back under ``slo * resume_factor`` (< shed_factor), so the controller
    cannot flap admit/shed around a single threshold.  Requests without a
    deadline are never shed.

    ``class_weights`` makes the thresholds priority-aware: both the shed
    and resume thresholds for a request are multiplied by its class's
    weight, and the hysteresis latch is tracked PER CLASS.  A weight below
    1.0 sheds that class earlier (best_effort first), above 1.0 later
    (interactive last); unlisted classes use weight 1.0, so a
    single-class workload with no weights behaves exactly as before."""

    def __init__(self, *, shed_factor: float = 1.5,
                 resume_factor: float = 1.0,
                 default_slo: Optional[float] = None,
                 class_weights: Optional[Dict[str, float]] = None):
        if resume_factor > shed_factor:
            raise ValueError("resume_factor must be <= shed_factor")
        if class_weights and any(w <= 0 for w in class_weights.values()):
            raise ValueError("class weights must be > 0")
        self.shed_factor = shed_factor
        self.resume_factor = resume_factor
        self.default_slo = default_slo
        self.class_weights = dict(class_weights) if class_weights else {}
        self._shedding: Dict[str, bool] = {}
        self.shed_count = 0
        self.shed_by_class: Dict[str, int] = {}

    @property
    def shedding(self) -> bool:
        """True while ANY class is latched shedding (back-compat view)."""
        return any(self._shedding.values())

    def should_shed(self, req: Request, min_forecast: float) -> bool:
        slo = req.slo if req.slo is not None else self.default_slo
        if slo is None:
            return False
        w = self.class_weights.get(req.priority, 1.0)
        cls = req.priority
        if self._shedding.get(cls, False):
            if min_forecast <= slo * self.resume_factor * w:
                self._shedding[cls] = False
                return False
        elif min_forecast > slo * self.shed_factor * w:
            self._shedding[cls] = True
        if self._shedding.get(cls, False):
            self.shed_count += 1
            self.shed_by_class[cls] = self.shed_by_class.get(cls, 0) + 1
        return self._shedding.get(cls, False)


# ---------------------------------------------------------------------------
# fleet brownout ladder
# ---------------------------------------------------------------------------

# Ordered degradation ladder.  Each rung trades a cheaper capability for
# fleet survival; the controller walks ONE rung per evaluation in either
# direction, with a cooldown between transitions, so a load spike degrades
# gracefully instead of collapsing and recovery cannot flap.
BROWNOUT_STAGES = ("normal", "spec_off", "draft_offload", "output_cap",
                   "shed")


class BrownoutController:
    """Hysteresis state machine over fleet telemetry driving the brownout
    ladder.

    Inputs per evaluation (all from ``ReplicaSnapshot`` — observable state
    only, no sim internals): the BEST replica's predicted TTFT (the same
    headroom signal admission and routing use), the fleet's minimum
    allocatable-KV headroom fraction, and optionally the deepest decode
    batch.  Pressure — best forecast past ``slo * enter_factor``, KV
    headroom under ``kv_low_frac``, or decode depth past ``decode_high`` —
    escalates one rung; calm (forecast under ``slo * exit_factor`` AND
    headroom at least ``kv_calm_frac``) de-escalates one rung.  Rungs, in
    order:

    1. ``spec_off``      — force gamma→0 fleet-wide: speculation burns KV
                           (draft slots) and verify FLOPs that overload
                           turns into pure queue delay (the Nightjar
                           gamma→0 saturation limit, applied by fiat).
    2. ``draft_offload`` — offload the draft model to host and expand the
                           KV pool into its slab (§6 squeeze), buying
                           batch growth when KV is the bottleneck.
    3. ``output_cap``    — cap ``max_new_tokens`` for best_effort traffic;
                           long tails stop starving interactive decode.
    4. ``shed``          — class-weighted admission shedding at the door:
                           best_effort always, batch when its own deadline
                           is already forecast blown, interactive never.

    Every transition is recorded in ``events`` with the signals that
    caused it, so post-hoc accounting can prove which rungs fired."""

    def __init__(self, *, slo: float = 1.0,
                 enter_factor: float = 1.5, exit_factor: float = 0.8,
                 kv_low_frac: float = 0.10, kv_calm_frac: float = 0.30,
                 decode_high: Optional[int] = None,
                 best_effort_cap: int = 32,
                 cooldown_s: float = 1.0, check_interval_s: float = 0.25):
        if slo <= 0:
            raise ValueError("brownout slo must be > 0")
        if exit_factor >= enter_factor:
            raise ValueError("exit_factor must be < enter_factor")
        if kv_calm_frac < kv_low_frac:
            raise ValueError("kv_calm_frac must be >= kv_low_frac")
        if best_effort_cap < 1:
            raise ValueError("best_effort_cap must be >= 1")
        self.slo = slo
        self.enter_factor = enter_factor
        self.exit_factor = exit_factor
        self.kv_low_frac = kv_low_frac
        self.kv_calm_frac = kv_calm_frac
        self.decode_high = decode_high
        self.best_effort_cap = best_effort_cap
        self.cooldown_s = cooldown_s
        self.check_interval_s = check_interval_s
        self.stage = 0
        self.shed_count = 0
        self.events: List[dict] = []
        self._last_transition = float("-inf")
        self._last_check = float("-inf")
        # observability seam: the cluster's attach_trace wires this so
        # every rung transition lands in the trace as a fleet instant
        self.trace = None

    # -- evaluation -----------------------------------------------------
    def due(self, now: float) -> bool:
        """Cheap prefilter: snapshots are only built when a check is due."""
        return now - self._last_check >= self.check_interval_s

    def evaluate(self, now: float,
                 snaps: List["ReplicaSnapshot"]) -> Optional[dict]:
        """One ladder decision; returns the transition event or None.
        Moves at most ONE rung per call, and never within ``cooldown_s``
        of the previous transition."""
        self._last_check = now
        if not snaps:
            return None
        best_ttft = min(s.predicted_ttft for s in snaps)
        kv_min = min(s.kv_headroom_frac for s in snaps)
        pressure = (best_ttft > self.slo * self.enter_factor
                    or kv_min < self.kv_low_frac)
        if self.decode_high is not None:
            pressure = pressure or max(s.decode_count for s in snaps) \
                > self.decode_high
        calm = (best_ttft < self.slo * self.exit_factor
                and kv_min >= self.kv_calm_frac)
        if now - self._last_transition < self.cooldown_s:
            return None
        if pressure and self.stage < len(BROWNOUT_STAGES) - 1:
            return self._move(now, self.stage + 1, best_ttft, kv_min)
        if calm and self.stage > 0:
            return self._move(now, self.stage - 1, best_ttft, kv_min)
        return None

    def _move(self, now: float, new: int, ttft: float, kv: float) -> dict:
        ev = {"at": round(now, 6), "from": BROWNOUT_STAGES[self.stage],
              "to": BROWNOUT_STAGES[new], "stage": new,
              "predicted_ttft": round(ttft, 6),
              "kv_headroom": round(kv, 6)}
        self.stage = new
        self._last_transition = now
        self.events.append(ev)
        tr = self.trace
        if tr is not None and tr.enabled:
            tr.instant("fleet", "brownout", now, args=dict(ev))
        return ev

    # -- rung queries (what the cluster applies to every live replica) --
    @property
    def stage_name(self) -> str:
        return BROWNOUT_STAGES[self.stage]

    @property
    def spec_off(self) -> bool:
        return self.stage >= BROWNOUT_STAGES.index("spec_off")

    @property
    def offload_draft(self) -> bool:
        return self.stage >= BROWNOUT_STAGES.index("draft_offload")

    def output_cap_for(self, priority: str) -> Optional[int]:
        """Token cap for new+running output of ``priority`` traffic at the
        current rung (None = uncapped)."""
        if self.stage >= BROWNOUT_STAGES.index("output_cap") \
                and priority == "best_effort":
            return self.best_effort_cap
        return None

    def should_shed(self, req: Request, min_forecast: float) -> bool:
        """Door decision at the top rung only: best_effort always sheds,
        batch sheds when its own deadline is already forecast blown,
        interactive is never brownout-shed (that is the whole point of
        the ladder)."""
        if self.stage < BROWNOUT_STAGES.index("shed"):
            return False
        if req.priority == "interactive":
            return False
        if req.priority != "best_effort":
            slo = req.slo
            if slo is None or min_forecast <= slo:
                return False
        self.shed_count += 1
        return True


# ---------------------------------------------------------------------------
# elastic autoscaling
# ---------------------------------------------------------------------------


class AutoscaleController:
    """Scale the fleet on a windowed SLO-attainment signal.

    Scale **up** (add a replica) when, over the trailing ``window_s`` of
    virtual time, attainment of deadline-carrying traffic — counting shed
    requests as misses — falls below ``up_attainment``, or immediately when
    every replica's predicted TTFT already exceeds the deadline (the fast
    pressure path; the windowed signal alone reacts one window late).

    Scale **down** (drain the least-loaded replica) when windowed attainment
    is at least ``down_attainment``, there is no pressure, and the fleet's
    unfinished-request load would comfortably fit on one fewer replica.
    Actions are separated by ``cooldown_s`` so one burst cannot thrash the
    fleet."""

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 4,
                 window_s: float = 10.0, up_attainment: float = 0.9,
                 down_attainment: float = 0.98,
                 drain_load_per_replica: int = 8,
                 cooldown_s: float = 2.0, min_window_samples: int = 8):
        if min_replicas < 1 or max_replicas < min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.window_s = window_s
        self.up_attainment = up_attainment
        self.down_attainment = down_attainment
        self.drain_load_per_replica = drain_load_per_replica
        self.cooldown_s = cooldown_s
        # below this many window samples the attainment estimate is noise
        # (one unlucky long prompt would flip a scaling decision) — the
        # windowed signal abstains and only the pressure path may act
        self.min_window_samples = min_window_samples
        self._finished: Deque[Tuple[float, bool]] = deque()  # (t, slo_met)
        self._shed: Deque[float] = deque()
        self._last_action = float("-inf")

    # -- signal feeds ---------------------------------------------------
    def record_finish(self, t: float, slo_met: bool) -> None:
        self._finished.append((t, slo_met))

    def record_shed(self, t: float) -> None:
        self._shed.append(t)

    def _trim(self, now: float) -> None:
        lo = now - self.window_s
        while self._finished and self._finished[0][0] < lo:
            self._finished.popleft()
        while self._shed and self._shed[0] < lo:
            self._shed.popleft()

    def window_attainment(self, now: float) -> Optional[float]:
        """Attainment over the trailing window, shed counted as missed;
        None below ``min_window_samples`` (no reliable signal yet)."""
        self._trim(now)
        total = len(self._finished) + len(self._shed)
        if total < max(self.min_window_samples, 1):
            return None
        met = sum(1 for _, ok in self._finished if ok)
        return met / total

    # -- decisions ------------------------------------------------------
    def decide(self, now: float, n_active: int, loads: List[int],
               min_forecast: Optional[float], slo: Optional[float],
               n_alive: Optional[int] = None) -> Optional[str]:
        """One scaling decision at an arrival instant: 'up', 'down' or
        None.  ``loads`` are the active replicas' unfinished-request
        counts; ``min_forecast`` is the best predicted TTFT for the
        arriving request (None when unknown); ``n_alive`` counts every
        replica still doing work — active AND draining (defaults to
        ``n_active``).  The max-replica cap applies to ``n_alive``: a
        draining replica is still consuming capacity, so scaling up past
        it would put more than ``max_replicas`` engines on the hardware
        concurrently."""
        if n_alive is None:
            n_alive = n_active
        if now - self._last_action < self.cooldown_s:
            return None
        att = self.window_attainment(now)
        pressure = (slo is not None and min_forecast is not None
                    and min_forecast > slo)
        if n_alive < self.max_replicas and (
                pressure or (att is not None and att < self.up_attainment)):
            self._last_action = now
            return "up"
        if (n_active > self.min_replicas and not pressure
                and (att is None or att >= self.down_attainment)
                and sum(loads) <= self.drain_load_per_replica
                * (n_active - 1)):
            self._last_action = now
            return "down"
        return None


class DecodePoolAutoscaler:
    """Elastic scaling for the decode pool of a disaggregated fleet.

    The prefill pool scales on TTFT attainment (``AutoscaleController``:
    deadlines are a prefill-side property once decode is offloaded); the
    decode pool's failure modes are different — KV exhaustion (adoption
    fallbacks, preemption churn) and TPOT collapse under oversized decode
    batches — so it scales on those signals instead:

    Scale **up** when any active decode replica's allocatable-KV headroom
    falls under ``kv_pressure_frac``, when the pool's worst EWMA TPOT
    exceeds ``tpot_slo_s`` (if configured), or when any replica's decode
    batch exceeds ``decode_high`` (if configured).  Scale **down** when the
    pool is calm (every headroom above ``calm_kv_frac``, no TPOT/batch
    pressure) and the pool's total decode work would comfortably fit on one
    fewer replica.  Actions are separated by ``cooldown_s``."""

    def __init__(self, *, min_replicas: int = 1, max_replicas: int = 4,
                 kv_pressure_frac: float = 0.15, calm_kv_frac: float = 0.4,
                 tpot_slo_s: Optional[float] = None,
                 decode_high: Optional[int] = None,
                 drain_decode_per_replica: int = 8,
                 cooldown_s: float = 2.0):
        if min_replicas < 1 or max_replicas < min_replicas:
            raise ValueError("need 1 <= min_replicas <= max_replicas")
        if calm_kv_frac < kv_pressure_frac:
            raise ValueError("calm_kv_frac must be >= kv_pressure_frac")
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.kv_pressure_frac = kv_pressure_frac
        self.calm_kv_frac = calm_kv_frac
        self.tpot_slo_s = tpot_slo_s
        self.decode_high = decode_high
        self.drain_decode_per_replica = drain_decode_per_replica
        self.cooldown_s = cooldown_s
        self._last_action = float("-inf")

    def decide(self, now: float, snaps: List["ReplicaSnapshot"],
               n_alive: Optional[int] = None) -> Optional[str]:
        """One scaling decision for the decode pool: 'up', 'down' or None.
        ``snaps`` are the ACTIVE decode replicas' snapshots; ``n_alive``
        counts active + draining decode replicas (capacity cap, same
        convention as ``AutoscaleController.decide``)."""
        if not snaps:
            return None
        n_active = len(snaps)
        if n_alive is None:
            n_alive = n_active
        if now - self._last_action < self.cooldown_s:
            return None
        kv_min = min(s.kv_headroom_frac for s in snaps)
        pressure = kv_min < self.kv_pressure_frac
        if self.tpot_slo_s is not None:
            pressure = pressure or max(s.ewma_tpot for s in snaps) \
                > self.tpot_slo_s
        if self.decode_high is not None:
            pressure = pressure or max(s.decode_count for s in snaps) \
                > self.decode_high
        if pressure and n_alive < self.max_replicas:
            self._last_action = now
            return "up"
        if (n_active > self.min_replicas and not pressure
                and kv_min >= self.calm_kv_frac
                and sum(s.decode_count for s in snaps)
                <= self.drain_decode_per_replica * (n_active - 1)):
            self._last_action = now
            return "down"
        return None


# ---------------------------------------------------------------------------
# failure detection
# ---------------------------------------------------------------------------


class FailureDetector:
    """Missed-heartbeat failure detection on the shared virtual clock.

    Every stepped replica heartbeats (``observe_step``); a replica whose
    last heartbeat is more than ``timeout_s`` of virtual time old is a
    *suspect*.  The cluster confirms a crash fault only through this
    detector — recovery is driven by the observable signal (silence), not
    by the injector's ground truth, so detection latency (MTTD) is a real,
    measured component of MTTR rather than an assumed zero."""

    def __init__(self, timeout_s: float = 0.25):
        if timeout_s <= 0:
            raise ValueError("detector timeout must be > 0")
        self.timeout_s = timeout_s
        self.last_seen: Dict[int, float] = {}

    def heartbeat(self, replica_id: int, now: float) -> None:
        prev = self.last_seen.get(replica_id)
        if prev is None or now > prev:
            self.last_seen[replica_id] = now

    def silent_for(self, replica_id: int, now: float) -> float:
        """Virtual seconds since the replica's last heartbeat (0 for a
        replica never seen — birth counts as a heartbeat)."""
        last = self.last_seen.setdefault(replica_id, now)
        return max(now - last, 0.0)

    def suspects(self, now: float, replica_ids) -> List[int]:
        return [r for r in replica_ids
                if self.silent_for(r, now) >= self.timeout_s]


# ---------------------------------------------------------------------------
# handoff pricing (disaggregated prefill/decode)
# ---------------------------------------------------------------------------


class HandoffPricer:
    """Prices one prefill→decode KV migration.

    The handoff wins exactly when the predicted queue delay the request
    escapes by leaving the prefill replica exceeds the modelled time to
    move its KV blocks across the interconnect:

        saved  = forecast_ttft(src) - forecast_ttft(dst)
        cost   = kv_transfer_seconds(prompt_len) + margin_s
        accept ⇔ saved > cost

    Both forecasts come from the same ``ControlPlane`` book the routers
    and admission use (roofline floor, learned backlog slope, residual
    bias) — so pricing sharpens as telemetry accumulates.  When the
    transfer loses, the request simply decodes where it prefilled: the
    colocated fallback, never worse by construction.  A backend without a
    transfer model (``kv_transfer_seconds``) prices the move at zero —
    accept whenever any delay is saved."""

    def __init__(self, control: "ControlPlane", *, margin_s: float = 0.0):
        self.control = control
        self.margin_s = margin_s
        self.accepted = 0
        self.declined = 0

    def transfer_seconds(self, src, n_tokens: int) -> float:
        fn = getattr(src.backend, "kv_transfer_seconds", None)
        return fn(n_tokens) if fn is not None else 0.0

    def quote(self, src, dst, req: Request,
              now: float) -> Tuple[float, float]:
        """(predicted queue-delay saved, modelled transfer cost)."""
        saved = (self.control.forecast_ttft(src, None, now)
                 - self.control.forecast_ttft(dst, None, now))
        cost = self.transfer_seconds(src, req.prompt_len) + self.margin_s
        return saved, cost

    def decide(self, src, dst, req: Request, now: float) -> bool:
        saved, cost = self.quote(src, dst, req, now)
        win = saved > cost
        if win:
            self.accepted += 1
        else:
            self.declined += 1
        return win


# ---------------------------------------------------------------------------
# the control plane proper
# ---------------------------------------------------------------------------


class ControlPlane:
    """Telemetry book + optional admission/autoscale controllers.

    ``ServingCluster`` creates one per cluster (a bare, telemetry-only
    plane when no controllers are configured), feeds it after every replica
    step and consults it at every arrival.  Routers that dispatch on
    predicted headroom (``SLOAwareRouter``, ``PrefixAffinityRouter``) are
    bound to the same instance so routing, admission and scaling all see
    one consistent forecast."""

    def __init__(self, *, admission: Optional[AdmissionController] = None,
                 autoscaler: Optional[AutoscaleController] = None,
                 alpha: float = 0.3,
                 detector: Optional[FailureDetector] = None):
        self.admission = admission
        self.autoscaler = autoscaler
        self.alpha = alpha
        self.telemetry: Dict[int, ReplicaTelemetry] = {}
        self.detector = detector if detector is not None else FailureDetector()
        self._fc_cache: Optional[Dict[tuple, float]] = None

    def begin_arrival(self) -> None:
        """Open a forecast memo for one arrival decision.  Admission,
        autoscaling, routing and dispatch bookkeeping all evaluate the same
        (replica, request, now) forecasts — and no replica state changes
        while one arrival is being decided — so one computation per replica
        serves all of them.  The cluster closes the memo (``end_arrival``)
        before any engine executes."""
        self._fc_cache = {}

    def end_arrival(self) -> None:
        self._fc_cache = None

    def tel(self, replica_id: int) -> ReplicaTelemetry:
        return self.telemetry.setdefault(replica_id,
                                         ReplicaTelemetry(self.alpha))

    # -- prediction -----------------------------------------------------
    def forecast_ttft(self, engine, req: Optional[Request],
                      now: float) -> float:
        """Predicted TTFT if ``req`` were dispatched to ``engine`` at
        ``now``.

        ``max(roofline floor, learned slope * backlog)`` over the prompt
        tokens the replica is already committed to (plus this prompt), on
        top of the replica's clock lag past the arrival instant, corrected
        by the learned forecast-residual bias.  The roofline term prices
        the pure prefill FLOPs (exact before any request has completed);
        the slope term learns the replica's true marginal delay per queued
        token — decode interference included — from completed-request
        stats.  Falls back to the EWMA TTFT level when the backend exposes
        no cost model (real tier without one)."""
        key = (engine.replica_id, req.req_id if req is not None else None,
               now)
        if self._fc_cache is not None and key in self._fc_cache:
            return self._fc_cache[key]
        tel = self.tel(engine.replica_id)
        lag = max(engine.clock - now, 0.0)
        backlog = engine.prefill_backlog_tokens
        if req is not None:
            backlog += req.prompt_len
        cm = getattr(engine.backend, "cm", None)
        target = getattr(engine.backend, "target", None)
        if cm is not None and isinstance(target, ModelConfig):
            base = cm.prefill_latency(target, 1, max(backlog, 1))
        else:
            base = tel.ewma_ttft.get(0.0)
        learned = tel.ewma_slope.get(0.0) * backlog
        out = max(lag + max(base, learned) + tel.ewma_err.get(0.0), 0.0)
        if self._fc_cache is not None:
            self._fc_cache[key] = out
        return out

    def snapshot(self, engine, now: float, *,
                 draining: bool = False) -> ReplicaSnapshot:
        tel = self.tel(engine.replica_id)
        bm = engine.scheduler.bm
        return ReplicaSnapshot(
            replica_id=engine.replica_id, t=now, clock=engine.clock,
            load=engine.load, decode_count=engine.decode_count,
            prefill_backlog_tokens=engine.prefill_backlog_tokens,
            kv_allocatable=bm.num_allocatable, kv_total=bm.total_blocks,
            ewma_ttft=tel.ewma_ttft.get(0.0),
            ewma_tpot=tel.ewma_tpot.get(0.0),
            predicted_ttft=self.forecast_ttft(engine, None, now),
            draining=draining)

    # -- event feeds ----------------------------------------------------
    def note_dispatch(self, engine, req: Request, now: float) -> None:
        backlog = engine.prefill_backlog_tokens + req.prompt_len
        self.tel(engine.replica_id).note_dispatch(
            req.req_id, self.forecast_ttft(engine, req, now), backlog)

    def observe_step(self, engine) -> None:
        """Consume a replica's newly finished requests after one step."""
        self.detector.heartbeat(engine.replica_id, engine.clock)
        fresh = self.tel(engine.replica_id).consume_finished(engine)
        if self.autoscaler is not None:
            for r in fresh:
                self.autoscaler.record_finish(engine.clock, r.slo_met)

    def note_shed(self, now: float) -> None:
        if self.autoscaler is not None:
            self.autoscaler.record_shed(now)

    def note_handoff(self, src_engine, dst_engine, req_id: int) -> None:
        """A request dispatched to ``src_engine`` migrated to
        ``dst_engine`` mid-flight.  Drop its dispatch-forecast record: the
        source will never see it finish (no learning there), and folding
        its end-to-end TTFT — which includes the source's queue delay —
        into the DESTINATION's residual/slope estimators would inflate
        every decode-pool forecast and talk the pricer out of future
        handoffs (the forecast gap *is* the price signal).  Migrated
        requests still feed the destination's service-level EWMAs via
        ``consume_finished``."""
        self.tel(src_engine.replica_id)._forecasts.pop(req_id, None)
        self.tel(dst_engine.replica_id)  # ensure the book exists
