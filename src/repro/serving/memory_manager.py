"""Elastic memory manager — paper §6: squeeze/expand between draft-model
weights and the paged KV pool.

Triggers (§6.1, hysteresis):
  EXPANSION  — speculation disabled AND N_free < tau_low persisting
               T_persist steps: offload the draft weights to host memory,
               then attach N_draft = ceil(S_draft / B_block) blocks to the
               pool at K_boundary.
  CONTRACTION — |Q_wait| == 0 AND N_free > N_draft + tau_low: build the
               migration plan (§6.4), execute the vectorised block moves,
               commit the logical remapping, trim the pool, reload the draft.

Transfers are modelled as asynchronous (CUDA-stream analogue, §6.2): the
manager records a completion time and the engine's clock only blocks if it
*consumes* the resource before the transfer finishes — offload/reload never
stall the decode path.

With a host KV tier attached to the BlockManager (``host_store``), memory
pressure offloads instead of discarding: every cached-reusable block
``plan_contraction`` evicts is spilled to the ``HostKVStore``, and the
``flush_fn`` hook (``RealBackend.apply_host_transfers`` on the real tier)
runs between planning and the §6.4 data movement so those blocks' pages
are captured BEFORE migration reuses their below-boundary targets and
``shrink_fn`` trims the high region.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

from .kv_cache import BlockManager, MigrationPlan, PhysicalKVPool


@dataclass
class MemoryEvent:
    kind: str        # offload | expand | contract | reload
    at: float
    latency: float
    detail: dict = field(default_factory=dict)


class ElasticMemoryManager:
    def __init__(self, bm: BlockManager, *, draft_blocks: int,
                 tau_low_frac: float = 0.1, t_persist: int = 3,
                 offload_latency: float = 0.0, reload_latency: float = 0.0,
                 migrate_fn: Optional[Callable[[MigrationPlan], float]] = None,
                 offload_fn: Optional[Callable[[], None]] = None,
                 reload_fn: Optional[Callable[[], None]] = None,
                 grow_fn: Optional[Callable[[int], None]] = None,
                 shrink_fn: Optional[Callable[[int], None]] = None,
                 flush_fn: Optional[Callable[[], None]] = None):
        self.bm = bm
        self.draft_blocks = draft_blocks          # N_draft
        self.tau_low_frac = tau_low_frac
        self.t_persist = t_persist
        self.offload_latency = offload_latency
        self.reload_latency = reload_latency
        self.migrate_fn = migrate_fn
        self.offload_fn = offload_fn
        self.reload_fn = reload_fn
        # physical-pool hooks (real tier): grow_fn extends the paged page
        # arrays in lockstep with bm.expand; shrink_fn trims them after the
        # logical contraction commits (PagedKVRuntime.grow/shrink via
        # RealBackend.grow_pools/shrink_pools).  None on the simulated tier.
        self.grow_fn = grow_fn
        self.shrink_fn = shrink_fn
        # host-tier spill flush (real tier: RealBackend.apply_host_transfers)
        # — must run after plan_contraction queued its spills and before the
        # migration/shrink overwrite or trim the spilled blocks' pages
        self.flush_fn = flush_fn

        self.draft_resident = True
        self.expanded = False
        self._low_mem_streak = 0
        self._busy_until = 0.0     # async transfer in flight
        # brownout ladder (controlplane.BrownoutController): while set, the
        # draft offloads IMMEDIATELY (no low-memory streak needed — the
        # fleet controller already decided KV capacity beats speculation)
        # and contraction is suppressed until the stage clears
        self.force_offload = False
        self.events: List[MemoryEvent] = []

    # ------------------------------------------------------------------
    @property
    def tau_low(self) -> int:
        return max(int(self.bm.base_blocks * self.tau_low_frac), 1)

    def can_speculate(self, now: float) -> bool:
        """Draft usable: resident and any reload transfer completed."""
        return self.draft_resident and now >= self._busy_until

    # ------------------------------------------------------------------
    def step(self, now: float, *, spec_disabled: bool, waiting: int) -> None:
        """Called once per scheduling step with the current system state."""
        if now < self._busy_until:
            return  # a transfer is still in flight — §6.2 non-blocking

        if self.draft_resident:
            if self.force_offload:
                # brownout draft-offload stage: reclaim the draft's KV share
                # for batch growth NOW, not after a streak
                self._offload_and_expand(now)
                return
            # track the low-memory streak only while speculation is disabled
            # (cached-reusable prefix blocks count as reclaimable capacity:
            # evicting the cache is always cheaper than offloading the draft)
            if spec_disabled and self.bm.num_allocatable < self.tau_low:
                self._low_mem_streak += 1
            else:
                self._low_mem_streak = 0
            if self._low_mem_streak >= self.t_persist:
                self._offload_and_expand(now)
            return

        # draft offloaded: contraction when the queue is drained and there is
        # room for the draft plus the safety buffer (hysteresis, §6.1) —
        # never while the brownout ladder holds the draft off-device
        if (self.expanded and waiting == 0 and not self.force_offload
                and self.bm.num_allocatable > self.draft_blocks + self.tau_low):
            self._contract_and_reload(now)

    # ------------------------------------------------------------------
    def _offload_and_expand(self, now: float) -> None:
        if self.offload_fn is not None:
            self.offload_fn()
        self.draft_resident = False
        self._busy_until = now + self.offload_latency
        self.events.append(MemoryEvent("offload", now, self.offload_latency))
        start, end = self.bm.expand(self.draft_blocks)
        if self.grow_fn is not None:
            self.grow_fn(self.draft_blocks)   # physical pages follow §6.3
        self.expanded = True
        self._low_mem_streak = 0
        self.events.append(MemoryEvent(
            "expand", now, 0.0, {"range": (start, end)}))

    def _contract_and_reload(self, now: float) -> None:
        plan = self.bm.plan_contraction()
        if plan is None and self.bm.total_blocks != self.bm.base_blocks:
            return  # §6.4 step 2 verification failed — retry later
        migrate_latency = 0.0
        if plan is not None:
            if self.flush_fn is not None:
                self.flush_fn()   # capture contraction-time spills first
            if self.migrate_fn is not None:
                migrate_latency = self.migrate_fn(plan) or 0.0
            self.bm.commit_contraction(plan)
            self.events.append(MemoryEvent(
                "contract", now, migrate_latency,
                {"migrated_blocks": len(plan)}))
        else:
            self.bm.total_blocks = self.bm.base_blocks
            self.bm.free = [b for b in self.bm.free if b < self.bm.boundary]
            self.events.append(MemoryEvent("contract", now, 0.0,
                                           {"migrated_blocks": 0}))
        if self.shrink_fn is not None:
            self.shrink_fn(self.bm.base_blocks)  # physical pages follow §6.4
        self.expanded = False
        if self.reload_fn is not None:
            self.reload_fn()
        self.draft_resident = True
        self._busy_until = now + self.reload_latency + migrate_latency
        self.events.append(MemoryEvent("reload", now, self.reload_latency))
