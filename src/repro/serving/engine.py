"""The Nightjar serving engine: one driver loop over pluggable backends.

The driver couples the four paper components exactly as Figure 4:
  Scheduler (continuous batching)  ->  Planner (MAB, batch size as context)
  ->  Execution (AR step | speculative step)  ->  Elastic Memory Manager.

Backends:
  * SimulatedBackend (simulator.py) — analytical roofline latencies; the
    paper-scale tier used by the benchmarks.
  * RealBackend (real_backend.py)  — actual JAX execution of tiny models;
    used by tests / examples / C_switch profiling.

Both tiers run the SAME scheduler / planner / memory-manager objects — only
the latency source differs (DESIGN.md §7).

Semantics of one engine step:
  1. admit arrivals; prefill the newly admitted sequences
  2. memory manager trigger check (offload/expand or contract/reload)
  3. gamma <- planner (forced 0 while the draft model is off-device)
  4. if switching 0 -> gamma>0: draft catch-up re-prefill of delta_max
     tokens (the C_switch cost, charged to the clock)
  5. execute the step; commit tokens; observe latency-per-token
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Protocol, Sequence as Seq

import numpy as np

from ..core.bandits import Policy
from .memory_manager import ElasticMemoryManager
from .request import Metrics, Request, Sequence
from .scheduler import ContinuousBatchingScheduler


class Backend(Protocol):
    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float: ...

    def step(self, seqs: List[Sequence], gamma: int
             ) -> "StepOutcome": ...

    def draft_catchup(self, seqs: List[Sequence]) -> float: ...

    def release(self, seq: Sequence) -> None: ...


@dataclass
class StepOutcome:
    n_committed: List[int]   # per sequence
    latency: float           # seconds


class ServingEngine:
    def __init__(self, backend: Backend, scheduler: ContinuousBatchingScheduler,
                 policy: Policy, memmgr: Optional[ElasticMemoryManager] = None,
                 *, gamma_max: int = 5):
        self.backend = backend
        self.scheduler = scheduler
        self.policy = policy
        self.memmgr = memmgr
        self.gamma_max = gamma_max
        self.clock = 0.0
        self.prev_gamma_effective = 0

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 1_000_000,
            record_timeline: bool = True) -> Metrics:
        pending = sorted(requests, key=lambda r: r.arrival)
        pi = 0
        m = Metrics()
        start_clock = self.clock
        steps = 0

        while (pi < len(pending) or self.scheduler.num_waiting
               or self.scheduler.num_running):
            if steps >= max_steps:
                break
            steps += 1

            # 1. arrivals up to now
            while pi < len(pending) and pending[pi].arrival <= self.clock:
                self.scheduler.add_request(pending[pi])
                pi += 1

            draft_ok = self.memmgr.can_speculate(self.clock) if self.memmgr else True

            admitted = self.scheduler.schedule()
            if admitted:
                t = self.backend.prefill(admitted, with_draft=draft_ok)
                self.clock += t
                for s in admitted:
                    s.prefill_done_at = self.clock
                    if not draft_ok:
                        s.delta = s.request.prompt_len  # draft never saw it

            if not self.scheduler.running:
                if pi < len(pending):
                    self.clock = max(self.clock, pending[pi].arrival)
                    continue
                break

            running = list(self.scheduler.running)
            B = len(running)
            delta_max = max((s.delta for s in running), default=0)

            # 2. elastic memory triggers
            if self.memmgr is not None:
                self.memmgr.step(
                    self.clock,
                    spec_disabled=(self.prev_gamma_effective == 0),
                    waiting=self.scheduler.num_waiting)
                draft_ok = self.memmgr.can_speculate(self.clock)

            # 3. arm selection
            if draft_ok:
                gamma = self.policy.select(B, delta_max=delta_max)
            else:
                gamma = 0

            # 4. switching cost: draft catch-up prefill
            switched_on = (self.prev_gamma_effective == 0 and gamma > 0)
            if switched_on and any(s.delta > 0 for s in running):
                t_catch = self.backend.draft_catchup(running)
                self.clock += t_catch
                for s in running:
                    s.delta = 0

            # 5. execute
            out = self.backend.step(running, gamma)
            self.clock += out.latency
            total_committed = int(sum(out.n_committed))

            for s, n in zip(running, out.n_committed):
                if n <= 0 or s not in self.scheduler.running:
                    continue  # finished slot or preempted by an earlier commit
                if s.first_token_at is None:
                    s.first_token_at = self.clock
                    m.ttfts.append(self.clock - s.request.arrival)
                ok = self.scheduler.commit_tokens(s, int(n))
                if not ok:
                    continue  # preempted; will re-run from the queue
                if gamma == 0:
                    s.delta += int(n)  # draft cache falls behind
                if s.done:
                    s.finished_at = self.clock
                    m.latencies.append(self.clock - s.request.arrival)
                    self.scheduler.finish(s)
                    self.backend.release(s)

            m.total_tokens += total_committed
            if total_committed > 0 and draft_ok:
                lpt = out.latency / total_committed
                self.policy.observe(B, gamma, lpt,
                                    n_accepted=(total_committed - B) / max(B, 1)
                                    if gamma else None,
                                    delta_max=delta_max)
            if record_timeline:
                m.timeline.append({
                    "t": self.clock, "B": B, "gamma": gamma,
                    "tokens": total_committed, "latency": out.latency,
                    "free_blocks": self.scheduler.bm.num_free,
                    "draft_resident": draft_ok,
                    "waiting": self.scheduler.num_waiting,
                })
            if gamma != self.prev_gamma_effective:
                m.switch_count += 1
            self.prev_gamma_effective = gamma

        m.elapsed = self.clock - start_clock
        if self.memmgr is not None:
            m.offload_events = sum(1 for e in self.memmgr.events
                                   if e.kind == "offload")
            m.reload_events = sum(1 for e in self.memmgr.events
                                  if e.kind == "reload")
        return m
