"""The Nightjar serving engine: a steppable driver over pluggable backends.

The driver couples the four paper components exactly as Figure 4:
  Scheduler (continuous batching)  ->  Planner (MAB, batch size as context)
  ->  Execution (AR step | speculative step)  ->  Elastic Memory Manager.

Backends:
  * SimulatedBackend (simulator.py) — analytical roofline latencies; the
    paper-scale tier used by the benchmarks.
  * RealBackend (real_backend.py)  — actual JAX execution of tiny models
    over a paged-KV runtime (zero-copy block-table indexing, chunked
    prefill via hybrid_step); used by tests / examples / C_switch
    profiling.  DenseSlotBackend is the legacy dense slot-cache tier for
    O(1)-state families.

Both tiers run the SAME scheduler / planner / memory-manager objects — only
the latency source differs (DESIGN.md §7).

Steppable API (the cluster tier, serving/cluster.py, is built on this):
  * ``submit(request)``      — enqueue a request; it is admitted once the
    engine's virtual clock reaches ``request.arrival``.
  * ``peek_next_event()``    — the virtual time at which this engine next
    has work to do (its clock if anything is runnable, the earliest pending
    arrival if idle, or ``None`` when fully drained).  A cluster driver
    advances the replica with the smallest next-event time so N independent
    engine clocks interleave correctly in virtual time.
  * ``step(now=None)``       — execute ONE engine iteration and return a
    :class:`StepReport` (``None`` when there is nothing left to do).
  * ``run(requests)``        — the classic run-to-completion loop, now a
    thin wrapper: submit everything, step until drained.

Semantics of one engine step (identical to the original monolithic loop):
  1. admit arrivals; prefill the newly admitted sequences
  2. memory manager trigger check (offload/expand or contract/reload)
  3. gamma <- planner (forced 0 while the draft model is off-device)
  4. if switching 0 -> gamma>0: draft catch-up re-prefill of delta_max
     tokens (the C_switch cost, charged to the clock)
  5. execute the step; commit tokens; observe latency-per-token
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import List, Optional, Protocol, Sequence as Seq

from ..core.bandits import Policy
from .kv_cache import OutOfBlocks
from .memory_manager import ElasticMemoryManager
from .request import Metrics, Request, Sequence
from .scheduler import ContinuousBatchingScheduler


class Backend(Protocol):
    def prefill(self, seqs: List[Sequence], *, with_draft: bool) -> float: ...

    def step(self, seqs: List[Sequence], gamma: int
             ) -> "StepOutcome": ...

    def hybrid_step(self, chunks, decode: List[Sequence], gamma: int,
                    *, with_draft: bool) -> "StepOutcome": ...

    def draft_catchup(self, seqs: List[Sequence]) -> float: ...

    def release(self, seq: Sequence) -> None: ...


@dataclass
class StepOutcome:
    n_committed: List[int]   # per sequence
    latency: float           # seconds


@dataclass
class StepReport:
    """What one ``ServingEngine.step`` call did (cluster/benchmark probe)."""

    kind: str                # "decode" (executed a batch) | "idle" (clock
                             # fast-forwarded to the next pending arrival)
    t_start: float           # engine clock when the step began
    t_end: float             # engine clock after the step
    batch: int = 0           # decode batch size B
    gamma: int = 0           # speculative length used this step
    tokens: int = 0          # committed tokens
    admitted: int = 0        # sequences admitted (prefilled) this step
    finished: int = 0        # sequences that completed this step
    prefill_tokens: int = 0  # prompt tokens prefilled (chunked mode)


class ServingEngine:
    def __init__(self, backend: Backend, scheduler: ContinuousBatchingScheduler,
                 policy: Policy, memmgr: Optional[ElasticMemoryManager] = None,
                 *, gamma_max: int = 5, replica_id: int = 0):
        self.backend = backend
        self.scheduler = scheduler
        self.policy = policy
        self.memmgr = memmgr
        self.gamma_max = gamma_max
        self.replica_id = replica_id
        self.clock = 0.0
        self.prev_gamma_effective = 0
        self.metrics = Metrics()
        # per-step timeline dicts are opt-in (run(record_timeline=True))
        # and ring-bounded — long benches that never read them no longer
        # accumulate unbounded memory
        self.record_timeline = False
        # observability seam (serving/observability.py): attach_trace wires
        # a TraceRecorder through the scheduler/block-manager; None (the
        # default) keeps every hook a single attribute check
        self.trace = None
        self._memmgr_traced = 0    # memmgr.events already copied to trace
        self._pending: List = []   # heap of (arrival, req_id, Request)
        # incoming prefilled requests migrating from a prefill-pool replica
        # (disaggregated mode): heap of (t_ready, req_id, Request, payload)
        self._handoffs: List = []
        self.handoffs_in = 0       # adopted with KV intact
        self.handoffs_refused = 0  # adoption fell back to local re-prefill
        # fault-injection seam (serving/faults.py): the cluster attaches a
        # FaultInjector; None means every query below is a no-op
        self.faults = None
        self.failed = False        # crashed — permanently out of service
        # brownout-ladder knobs (controlplane.BrownoutController via the
        # cluster; harmless defaults when no controller drives them)
        self.spec_forced_off = False       # stage >= spec_off: gamma -> 0
        self.best_effort_cap: Optional[int] = None  # stage >= output_cap:
                                           # max_new_tokens for best_effort

    # ------------------------------------------------------------------
    # observability seam
    # ------------------------------------------------------------------
    def attach_trace(self, trace) -> None:
        """Wire a :class:`observability.TraceRecorder` through this engine
        and the seams that emit events below it (scheduler preemptions,
        block-manager spill/restore).  The context closures read the LIVE
        clock/replica-id, so a cluster may attach before assigning replica
        ids.  ``None`` detaches everything."""
        self.trace = trace
        ctx = (lambda: (self.clock, self.replica_id)) \
            if trace is not None else None
        self.scheduler.trace = trace
        self.scheduler.trace_ctx = ctx
        self.scheduler.bm.trace = trace
        self.scheduler.bm.trace_ctx = ctx

    def _tracer(self):
        """The active recorder, or None — the zero-cost gate every hook
        shares (detached OR disabled recorders both fold to None)."""
        tr = self.trace
        return tr if (tr is not None and tr.enabled) else None

    def _trace_memmgr(self, tr) -> None:
        """Copy memory-manager events (offload/expand/contract/reload) not
        yet seen into the trace; the seen-counter keeps this incremental
        without touching the manager itself."""
        evs = self.memmgr.events
        if len(evs) > self._memmgr_traced:
            for e in evs[self._memmgr_traced:]:
                args = {"latency": e.latency}
                args.update(e.detail)
                tr.instant("memmgr", e.kind, e.at,
                           replica=self.replica_id, args=args)
            self._memmgr_traced = len(evs)

    # ------------------------------------------------------------------
    # steppable surface
    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request; admitted once the clock reaches its arrival."""
        heapq.heappush(self._pending, (req.arrival, req.req_id, req))
        tr = self._tracer()
        if tr is not None:
            # queue time starts at arrival (e2e latency's origin), not at
            # the submit call — a crash-recovery resubmission of an already
            # open request folds into its existing lane instead
            tr.req_submit(req.req_id, max(req.arrival, 0.0),
                          self.replica_id, priority=req.priority,
                          prompt_len=req.prompt_len,
                          output_len=req.output_len)

    @property
    def num_pending(self) -> int:
        """Submitted requests whose arrival the clock has not reached."""
        return len(self._pending)

    @property
    def load(self) -> int:
        """Total requests owned by this replica that are not yet finished
        admission: pending + waiting + running (router load signal)."""
        return (len(self._pending) + len(self._handoffs)
                + self.scheduler.num_waiting + self.scheduler.num_running)

    @property
    def decode_count(self) -> int:
        """Running sequences that are decode-ready (prefill complete)."""
        return sum(1 for s in self.scheduler.running
                   if s.prompt_remaining == 0)

    @property
    def prefill_backlog_tokens(self) -> int:
        """Prompt tokens this replica has committed to but not yet
        materialised: queued prompts (waiting + submitted-pending) plus the
        un-prefilled remainder of running sequences.  Pure queue-state
        observation — the control plane's queue-delay forecast input, equally
        observable on the real tier."""
        return (sum(r.prompt_len for r in self.scheduler.waiting)
                + sum(s.prompt_remaining for s in self.scheduler.running)
                + sum(item[2].prompt_len for item in self._pending))

    def has_work(self) -> bool:
        return bool(self._pending or self._handoffs
                    or self.scheduler.num_waiting
                    or self.scheduler.num_running)

    def inflight_req_ids(self) -> List[int]:
        """Every request id this replica owns that has not finished:
        cancellation-storm victim pool (pending + handoffs + waiting +
        running)."""
        return ([item[2].req_id for item in self._pending]
                + [item[2].req_id for item in self._handoffs]
                + [r.req_id for r in self.scheduler.waiting]
                + [s.req_id for s in self.scheduler.running])

    def _next_income(self) -> Optional[float]:
        """Earliest instant at which queued income (a submitted arrival or
        an in-flight KV handoff) becomes actionable; ``None`` if neither."""
        cands = []
        if self._pending:
            cands.append(self._pending[0][0])
        if self._handoffs:
            cands.append(self._handoffs[0][0])
        return min(cands) if cands else None

    def _next_expiry(self) -> Optional[float]:
        """Earliest hard deadline among queued work (waiting requests and
        in-flight handoffs).  An otherwise-idle engine must still step at
        that instant so expired requests are reaped and accounted — they
        can never be silently stranded in the waiting queue."""
        exps = [r.arrival + r.deadline for r in self.scheduler.waiting
                if r.deadline is not None]
        exps += [item[2].arrival + item[2].deadline for item in self._handoffs
                 if item[2].deadline is not None]
        return min(exps) if exps else None

    def peek_next_event(self) -> Optional[float]:
        """Virtual time of this engine's next actionable event.

        ``None`` means drained (or stuck: waiting requests that can never be
        admitted because nothing is running and no arrivals remain — the
        run-to-completion loop historically terminated there too).  A
        deadline-carrying waiting request is never stuck: its expiry is an
        actionable event (the reap)."""
        if self.scheduler.num_running:
            return self.clock
        # with nothing running, admission is only retried when the clock
        # moves — the next chance is the next arrival / handoff landing /
        # deadline expiry
        cands = [t for t in (self._next_income(), self._next_expiry())
                 if t is not None]
        return max(self.clock, min(cands)) if cands else None

    # ------------------------------------------------------------------
    # pieces shared by the monolithic and hybrid step paths
    # ------------------------------------------------------------------
    def _drain_arrivals(self) -> None:
        while self._pending and self._pending[0][0] <= self.clock:
            self.scheduler.add_request(heapq.heappop(self._pending)[2])
        while self._handoffs and self._handoffs[0][0] <= self.clock:
            _, _, req, payload = heapq.heappop(self._handoffs)
            self._adopt_prefilled(req, payload)

    # ------------------------------------------------------------------
    # request lifecycle: cancellation + deadline reaping
    # ------------------------------------------------------------------
    def _note_lifecycle(self, req: Request, kind: str) -> None:
        """Account a cancelled/expired request — per-class, never silently
        dropped (the surge acceptance gate sums these against offered)."""
        rec = {"req_id": req.req_id, "at": round(self.clock, 6),
               "priority": req.priority, "slo": req.slo}
        (self.metrics.cancelled if kind == "cancelled"
         else self.metrics.expired).append(rec)
        tr = self._tracer()
        if tr is not None:
            tr.req_end(req.req_id, self.clock, kind, self.replica_id,
                       priority=req.priority)

    def _drop_sequence(self, seq: Sequence, kind: str) -> None:
        """Tear down ONE running sequence without finishing it: release its
        device blocks (registered prefix blocks park in the cached tier —
        their content is still valid, unlike a crash), drop any orphaned
        TTFT sample, and account the request.  Per-request granularity is
        what distinguishes this from ``force_fail`` (whole-replica); I8
        asserts nothing leaks."""
        sched = self.scheduler
        m = self.metrics
        if seq.first_token_at is not None:
            # the request never finishes: remove its orphaned TTFT sample
            # (exact float — the same arithmetic stamped it)
            try:
                m.ttfts.remove(seq.first_token_at - seq.request.arrival)
            except ValueError:
                pass
        sched.bm.release(sched._seq_key(seq))
        if seq in sched.running:
            sched.running.remove(seq)
        self.backend.release(seq)
        self._note_lifecycle(seq.request, kind)

    def cancel_request(self, req_id: int, *, reason: str = "cancelled"
                       ) -> bool:
        """Client cancellation: withdraw a request wherever it lives —
        submitted-pending, migrating handoff, waiting queue, or running
        batch — releasing every device block, CoW pin, host-KV pin and
        queue slot it holds.  Returns False when the request is unknown
        here (already finished, shed, or owned by another replica)."""
        for i, item in enumerate(self._pending):
            if item[2].req_id == req_id:
                self._pending.pop(i)
                heapq.heapify(self._pending)
                self._note_lifecycle(item[2], reason)
                return True
        for i, item in enumerate(self._handoffs):
            if item[2].req_id == req_id:
                self._handoffs.pop(i)
                heapq.heapify(self._handoffs)
                self._note_lifecycle(item[2], reason)
                return True
        for req in self.scheduler.waiting:
            if req.req_id == req_id:
                self.scheduler.waiting.remove(req)
                self._note_lifecycle(req, reason)
                return True
        for seq in list(self.scheduler.running):
            if seq.req_id == req_id:
                self._drop_sequence(seq, reason)
                return True
        return False

    def _reap_expired(self) -> int:
        """Drop every request whose hard deadline has passed — waiting
        (reaped at dispatch: never admitted), running (reaped mid-decode:
        stops burning batch slots on tokens nobody will read) and
        handoffs in transfer.  ``>=`` is load-bearing: the idle path
        fast-forwards the clock EXACTLY to the next expiry."""
        now = self.clock
        sched = self.scheduler
        reaped = 0
        for req in [r for r in sched.waiting if r.deadline is not None
                    and now >= r.arrival + r.deadline]:
            sched.waiting.remove(req)
            self._note_lifecycle(req, "expired")
            reaped += 1
        for seq in [s for s in sched.running
                    if s.request.deadline is not None
                    and now >= s.request.arrival + s.request.deadline]:
            self._drop_sequence(seq, "expired")
            reaped += 1
        if self._handoffs:
            keep = []
            for item in self._handoffs:
                req = item[2]
                if (req.deadline is not None
                        and now >= req.arrival + req.deadline):
                    self._note_lifecycle(req, "expired")
                    reaped += 1
                else:
                    keep.append(item)
            if len(keep) != len(self._handoffs):
                self._handoffs = keep
                heapq.heapify(self._handoffs)
        return reaped

    # ------------------------------------------------------------------
    # disaggregated prefill/decode handoff surface
    # ------------------------------------------------------------------
    def accept_handoff(self, req: Request, t_ready: float,
                       payload: Optional[dict] = None) -> None:
        """Receive a fully-prefilled request migrating from a prefill-pool
        replica.  ``t_ready`` is the virtual instant the KV transfer
        completes (source clock + modelled interconnect time); the request
        joins this replica's decode batch once the clock reaches it."""
        heapq.heappush(self._handoffs, (t_ready, req.req_id, req,
                                        payload or {}))

    def _adopt_prefilled(self, req: Request, payload: dict) -> None:
        """Materialise a handed-off request as a decode-ready sequence.

        The migrated KV blocks land in this replica's pool (block-table
        allocation covering the whole prompt, then the backend's
        ``import_handoff`` writes the payload on the physical tier).  If
        the pool cannot host the prompt right now, fall back to local
        re-prefill through the ordinary waiting queue — strictly the
        colocated behaviour, so a failed adoption is never worse than not
        having handed off (the request always completes)."""
        sched = self.scheduler
        seq = Sequence(request=req)
        key = sched._seq_key(seq)
        tr = self._tracer()
        try:
            sched.bm.allocate(key, max(req.prompt_len, 1))
        except OutOfBlocks:
            self.handoffs_refused += 1
            sched.add_request(req)
            if tr is not None:
                tr.instant("engine", "handoff_refused", self.clock,
                           replica=self.replica_id,
                           args={"req": req.req_id})
                tr.req_stage(req.req_id, self.clock, "queue",
                             self.replica_id)
            return
        if tr is not None:
            tr.req_stage(req.req_id, self.clock, "decode", self.replica_id)
        seq.prefilled = req.prompt_len
        seq.prefill_done_at = self.clock
        # draft-pool coverage travels with the KV: tokens the source's
        # draft never saw still need catch-up before speculating here
        seq.delta = int(payload.get("delta", 0))
        imp = getattr(self.backend, "import_handoff", None)
        if imp is not None:
            imp(seq, payload)
        sched.running.append(seq)
        self.handoffs_in += 1

    def extract_for_handoff(self, seq: Sequence) -> dict:
        """Detach a fully-prefilled, not-yet-decoded sequence for migration
        to a decode replica.  Returns the handoff payload (draft-coverage
        debt, plus the physical KV bytes on the real tier); the sequence's
        device blocks are released here — full prompt blocks stay in this
        replica's prefix cache, so repeat templates keep their affinity
        benefit even though decode happens elsewhere."""
        sched = self.scheduler
        payload: dict = {"delta": seq.delta,
                         "prompt_len": seq.request.prompt_len}
        export = getattr(self.backend, "export_handoff", None)
        if export is not None:
            payload["kv"] = export(seq)
        sched.bm.release(sched._seq_key(seq))
        if seq in sched.running:
            sched.running.remove(seq)
        self.backend.release(seq)
        return payload

    def _output_limit(self, req: Request) -> int:
        """Effective output length: ``best_effort`` requests are clipped to
        the brownout ladder's ``best_effort_cap`` when set (a capped
        request still *finishes* — shorter, not dropped)."""
        cap = self.best_effort_cap
        if cap is not None and req.priority == "best_effort":
            return min(req.output_len, cap)
        return req.output_len

    def _commit_decode(self, seqs: Seq[Sequence], n_committed: Seq[int],
                       gamma: int) -> "tuple[int, int]":
        """Commit per-sequence decode tokens; returns (sequences finished,
        tokens clipped by the best-effort output cap).  Clipped tokens are
        subtracted from the step's committed-token count by the caller —
        zero whenever no cap is active, keeping the uncapped path
        byte-identical."""
        m = self.metrics
        tr = self._tracer()
        finished = 0
        clipped = 0
        for s, n in zip(seqs, n_committed):
            if s not in self.scheduler.running:
                continue  # preempted/cancelled by an earlier commit
            limit = self._output_limit(s.request)
            raw = int(n)
            n = min(raw, max(limit - s.generated, 0))
            clipped += max(raw - n, 0)
            if n <= 0 and s.generated < limit:
                continue  # finished slot (raw <= 0) — nothing to commit
            if n > 0:
                if s.first_token_at is None:
                    s.first_token_at = self.clock
                    m.ttfts.append(self.clock - s.request.arrival)
                ok = self.scheduler.commit_tokens(s, n)
                if not ok:
                    continue  # preempted; will re-run from the queue
                if gamma == 0:
                    s.delta += n  # draft cache falls behind
            if s.generated >= limit:
                s.finished_at = self.clock
                m.latencies.append(self.clock - s.request.arrival)
                m.record_finish(s, self.clock)
                self.scheduler.finish(s)
                self.backend.release(s)
                finished += 1
                if tr is not None:
                    tr.req_end(s.req_id, self.clock, "finished",
                               self.replica_id, tokens=s.generated)
        return finished, clipped

    def _reserve_kv(self, seqs: List[Sequence], gamma: int) -> List[Sequence]:
        """Physical KV reservation (paged real backend): grow block tables to
        cover this step's gamma+1 writes BEFORE executing; sequences whose
        reservation fails are preempted (recompute policy) so no paged write
        can ever land in another sequence's blocks.  Backends without a
        ``reserve`` hook (simulated / dense slots) skip this entirely."""
        reserve = getattr(self.backend, "reserve", None)
        if reserve is None or not seqs:
            return seqs
        while seqs:
            failed = reserve(seqs, gamma)
            if not failed:
                break
            # preempt ONE victim (lowest class then youngest among the
            # failed, matching the recompute policy) and retry: its
            # released blocks often cover the rest
            victim = max(failed, key=self.scheduler._age_key)
            self.scheduler.preempt(victim)
            seqs = [s for s in seqs if s in self.scheduler.running]
        return seqs

    def _drain_host_transfers(self) -> float:
        """Consume the host KV tier's transfer queues on the simulated tier
        and return the modelled restore latency (charged to the clock:
        restored blocks gate the admitted sequence's prefill, so the
        host→device copy is synchronous; spills ride the async DMA stream,
        §6.2, and cost nothing here).  Real backends drain these queues
        themselves inside their timed steps (``apply_host_transfers``), so
        this is a no-op for them."""
        bm = self.scheduler.bm
        hs = getattr(bm, "host_store", None)
        if hs is None or hasattr(self.backend, "apply_host_transfers"):
            return 0.0
        spills = bm.drain_pending_spills()
        for _, h in spills:
            if h in hs.records:
                hs.stats["spilled_blocks"] += 1
        restores = bm.drain_pending_restores()
        for h, _ in restores:
            hs.take(h)
        lat_fn = getattr(self.backend, "host_transfer_latency", None)
        lat = (lat_fn(len(spills), len(restores))
               if lat_fn is not None and restores else 0.0)
        if lat:
            hs.stats["restore_s"] += lat
        return lat

    def flush_host_transfers(self) -> float:
        """Complete every queued host-tier KV transfer *now* and charge the
        modelled latency to the engine clock.

        The step loop only drains these queues while the engine executes
        steps; a drained replica with empty request queues never steps
        again, so transfers queued by its last step's evictions (phase-5
        commit evictions land AFTER the in-step drain point) would be
        stranded — spilled payloads lost and restore-pinned
        ``HostKVStore`` records leaked.  The cluster calls this at the
        drain-to-retire transition.  Real backends move the bytes
        themselves (``apply_host_transfers``)."""
        bm = self.scheduler.bm
        if getattr(bm, "host_store", None) is None:
            return 0.0
        apply = getattr(self.backend, "apply_host_transfers", None)
        if apply is not None:
            apply()
            return 0.0
        lat = self._drain_host_transfers()
        self.clock += lat
        return lat

    def _faulty(self, dt: float) -> float:
        """Apply any straggler fault window covering (replica, clock) to a
        step latency.  The SCALED value is what the clock, timeline and
        planner all see — the policy adapting to a straggling replica is
        the desired behaviour, not a measurement artifact.  The injected
        surplus is tracked separately in ``metrics.fault_injected_s``."""
        if self.faults is None or dt <= 0:
            return dt
        mult = self.faults.latency_multiplier(self.replica_id, self.clock)
        if mult > 1.0:
            self.metrics.fault_injected_s += dt * (mult - 1.0)
            return dt * mult
        return dt

    # ------------------------------------------------------------------
    # crash surface (serving/faults.py · cluster crash recovery)
    # ------------------------------------------------------------------
    def force_fail(self) -> List[Request]:
        """Crash this replica: all in-flight work is lost, all device state
        is gone.  Returns every request this replica owned (pending,
        migrating, waiting, running) in req-id order so the cluster can
        re-dispatch them; releases every block, cancels every pending
        transfer and drops every host-store pin so nothing leaks (invariant
        I7, ``check_invariants(failed=True)``).  The host-side spill
        records themselves are irrelevant after the crash — the replica
        never serves again — but pins and queues must clear because the
        invariant checker (and the leak they model) is per-store."""
        sched = self.scheduler
        bm = sched.bm
        m = self.metrics
        lost: List[Request] = [item[2] for item in self._pending]
        self._pending.clear()
        lost += [item[2] for item in self._handoffs]
        self._handoffs.clear()
        lost += list(sched.waiting)
        sched.waiting.clear()
        for seq in list(sched.running):
            # a half-decoded sequence already contributed a TTFT sample;
            # its recovery run will contribute another from a different
            # replica — remove the orphaned sample so the crashed attempt
            # doesn't double-count (exact float: same arithmetic stamped it)
            if seq.first_token_at is not None:
                try:
                    m.ttfts.remove(seq.first_token_at - seq.request.arrival)
                except ValueError:
                    pass
            bm.release(sched._seq_key(seq))
            self.backend.release(seq)
            lost.append(seq.request)
        sched.running.clear()
        # device content is gone: unregister every cached-reusable block
        # straight back to the free list — NO spill (the payload a spill
        # would capture no longer exists), which also cancels in-flight
        # restores and unpins their host records via _unregister
        for b in list(bm.cached):
            bm.cached.pop(b, None)
            bm._unregister(b)
            bm.free.append(b)
        bm.pending_copies.clear()
        bm.pending_spills.clear()
        assert not bm.pending_restores, "restore survived its target"
        self.failed = True
        lost.sort(key=lambda r: r.req_id)
        tr = self._tracer()
        if tr is not None:
            # every lost request stalls at the crash instant; recovery
            # (cluster retry) reopens its queue span on another replica
            for r in lost:
                tr.req_stage(r.req_id, self.clock, "stall", self.replica_id)
        return lost

    def _record_timeline(self, B: int, gamma: int, tokens: int,
                         latency: float, draft_ok: bool,
                         prefill_tokens: int = 0) -> None:
        self.metrics.timeline.append({
            "t": self.clock, "B": B, "gamma": gamma,
            "tokens": tokens, "latency": latency,
            "prefill_tokens": prefill_tokens,
            "free_blocks": self.scheduler.bm.num_free,
            "cached_blocks": len(self.scheduler.bm.cached),
            "draft_resident": draft_ok,
            "waiting": self.scheduler.num_waiting,
        })

    # ------------------------------------------------------------------
    def step(self, now: Optional[float] = None) -> Optional[StepReport]:
        """Advance the engine by one iteration of the Figure-4 loop."""
        if self.scheduler.chunk_tokens is not None:
            return self._step_hybrid(now)
        if now is not None and now > self.clock:
            self.clock = now
        m = self.metrics
        t_start = self.clock

        # 1. arrivals up to now; reap expired deadlines BEFORE dispatch so
        #    a dead-on-arrival request never consumes prefill compute
        self._drain_arrivals()
        reaped = self._reap_expired()

        draft_ok = self.memmgr.can_speculate(self.clock) if self.memmgr else True
        tr = self._tracer()

        admitted = self.scheduler.schedule()
        if admitted:
            t_prefill0 = self.clock
            t = self.backend.prefill(admitted, with_draft=draft_ok)
            self.clock += self._faulty(t)
            for s in admitted:
                s.prefill_done_at = self.clock
                if not draft_ok:
                    s.delta = s.request.prompt_len  # draft never saw it
                if tr is not None:
                    tr.req_stage(s.req_id, t_prefill0, "prefill",
                                 self.replica_id)
                    tr.req_stage(s.req_id, self.clock, "decode",
                                 self.replica_id)

        if not self.scheduler.running:
            cands = [t for t in (self._next_income(), self._next_expiry())
                     if t is not None]
            if cands:
                # idle: fast-forward to the next arrival / handoff landing /
                # deadline expiry (expired waiting work still needs reaping)
                self.clock = max(self.clock, min(cands))
                return StepReport("idle", t_start, self.clock,
                                  admitted=len(admitted))
            if reaped:
                return StepReport("idle", t_start, self.clock)
            return None

        running = list(self.scheduler.running)
        B = len(running)
        delta_max = max((s.delta for s in running), default=0)

        # 2. elastic memory triggers
        if self.memmgr is not None:
            self.memmgr.step(
                self.clock,
                spec_disabled=(self.prev_gamma_effective == 0),
                waiting=self.scheduler.num_waiting)
            draft_ok = self.memmgr.can_speculate(self.clock)
            if tr is not None:
                self._trace_memmgr(tr)

        # 3. arm selection (brownout stage >= spec_off forces gamma -> 0
        #    fleet-wide — the paper's MAB-disable recast as overload control)
        if draft_ok and not self.spec_forced_off:
            gamma = self.policy.select(B, delta_max=delta_max)
        else:
            gamma = 0

        # 4. physical KV reservation, then switching cost (draft catch-up)
        running = self._reserve_kv(running, gamma)
        if not running:
            return StepReport("idle", t_start, self.clock,
                              admitted=len(admitted))
        B = len(running)
        delta_max = max((s.delta for s in running), default=0)
        switched_on = (self.prev_gamma_effective == 0 and gamma > 0)
        if switched_on and any(s.delta > 0 for s in running):
            t_catch = self.backend.draft_catchup(running)
            self.clock += self._faulty(t_catch)
            for s in running:
                s.delta = 0
            if tr is not None:
                tr.instant("engine", "draft_catchup", self.clock,
                           replica=self.replica_id,
                           args={"delta_max": delta_max, "batch": B})

        # 5. execute
        t_exec0 = self.clock
        out = self.backend.step(running, gamma)
        out.latency = self._faulty(out.latency)
        self.clock += out.latency
        total_committed = int(sum(out.n_committed))

        finished, clipped = self._commit_decode(running, out.n_committed,
                                                gamma)
        total_committed -= clipped  # best-effort cap: tokens never written

        m.total_tokens += total_committed
        if total_committed > 0 and draft_ok:
            lpt = out.latency / total_committed
            self.policy.observe(B, gamma, lpt,
                                n_accepted=(total_committed - B) / max(B, 1)
                                if gamma else None,
                                delta_max=delta_max)
        if self.record_timeline:
            self._record_timeline(B, gamma, total_committed, out.latency,
                                  draft_ok)
        if self.record_timeline or tr is not None:
            m.note_spec_step(B, gamma, total_committed, out.latency,
                             forced_off=self.spec_forced_off or not draft_ok,
                             restarted=switched_on)
        if tr is not None:
            tr.step_span(t_exec0, self.clock, self.replica_id, batch=B,
                         gamma=gamma, tokens=total_committed,
                         accepted=max(total_committed - B, 0)
                         if gamma > 0 else 0,
                         draft_ok=draft_ok,
                         forced_off=self.spec_forced_off)
        if gamma != self.prev_gamma_effective:
            m.switch_count += 1
        self.prev_gamma_effective = gamma
        # CoW copies not consumed by a physical backend (simulated tier)
        self.scheduler.bm.drain_pending_copies()
        return StepReport("decode", t_start, self.clock, batch=B, gamma=gamma,
                          tokens=total_committed, admitted=len(admitted),
                          finished=finished)

    # ------------------------------------------------------------------
    def _step_hybrid(self, now: Optional[float] = None) -> Optional[StepReport]:
        """One iteration in chunked-prefill hybrid mode: the scheduler emits
        prefill chunks (token-budgeted) mixed with the decode batch, and one
        fused backend call executes both.  Speculation is forced off (gamma=0)
        whenever any chunk is in flight — the draft/verify machinery only runs
        on pure-decode steps, applied to the decode portion."""
        if now is not None and now > self.clock:
            self.clock = now
        m = self.metrics
        t_start = self.clock

        # 1. arrivals up to now; reap expired deadlines BEFORE dispatch so
        #    a dead-on-arrival request never consumes chunk budget
        self._drain_arrivals()
        reaped = self._reap_expired()

        draft_ok = self.memmgr.can_speculate(self.clock) if self.memmgr else True
        tr = self._tracer()

        batch = self.scheduler.schedule_chunks()
        if batch.empty:
            cands = [t for t in (self._next_income(), self._next_expiry())
                     if t is not None]
            if cands:
                # idle: fast-forward to the next arrival / handoff landing /
                # deadline expiry (expired waiting work still needs reaping)
                self.clock = max(self.clock, min(cands))
                return StepReport("idle", t_start, self.clock)
            if reaped:
                return StepReport("idle", t_start, self.clock)
            return None

        # newly admitted sequences may carry a cached prefix: let the
        # backend seed its materialised-length bookkeeping (paged real
        # backend: tkv/dkv ctx = cached boundary; cached blocks are valid in
        # both pools by the registration rule)
        on_admit = getattr(self.backend, "on_admit", None)
        if on_admit is not None:
            for s in batch.admitted:
                on_admit(s)
        if tr is not None:
            for s in batch.admitted:
                # fully-cached admissions (whole prompt from the prefix
                # cache) never enter the chunk loop: straight to decode
                tr.req_stage(s.req_id, self.clock,
                             "decode" if s.prompt_remaining == 0
                             else "prefill", self.replica_id)

        # host-tier KV transfers queued during admission (spills from LRU
        # eviction, restores from match_prefix host hits) complete before
        # the fused step reads the restored prefixes
        self.clock += self._drain_host_transfers()

        decode = [s for s in batch.decode]
        B = len(decode)
        delta_max = max((s.delta for s in decode), default=0)

        # 2. elastic memory triggers
        if self.memmgr is not None:
            self.memmgr.step(
                self.clock,
                spec_disabled=(self.prev_gamma_effective == 0),
                waiting=self.scheduler.num_waiting)
            draft_ok = self.memmgr.can_speculate(self.clock)
            if tr is not None:
                self._trace_memmgr(tr)

        # 3. arm selection — gamma only ever applies to the decode portion,
        #    and is forced to 0 while any prefill chunk is in flight or the
        #    brownout ladder has speculation disabled fleet-wide
        if (batch.prefill_chunks or not draft_ok or B == 0
                or self.spec_forced_off):
            gamma = 0
        else:
            gamma = self.policy.select(B, delta_max=delta_max)

        # 4. physical KV reservation for the decode rows (chunk rows were
        #    reserved block-by-block at schedule time), then switching cost
        decode = self._reserve_kv(decode, gamma)
        B = len(decode)
        delta_max = max((s.delta for s in decode), default=0)
        switched_on = (self.prev_gamma_effective == 0 and gamma > 0)
        if switched_on and any(s.delta > 0 for s in decode):
            t_catch = self.backend.draft_catchup(decode)
            self.clock += self._faulty(t_catch)
            for s in decode:
                s.delta = 0
            if tr is not None:
                tr.instant("engine", "draft_catchup", self.clock,
                           replica=self.replica_id,
                           args={"delta_max": delta_max, "batch": B})

        # 5. execute the fused step
        t_exec0 = self.clock
        out = self.backend.hybrid_step(batch.prefill_chunks, decode, gamma,
                                       with_draft=draft_ok)
        out.latency = self._faulty(out.latency)
        self.clock += out.latency
        total_committed = int(sum(out.n_committed))

        # chunk progress: blocks were reserved at schedule time; freshly
        # completed full prompt blocks are published to the prefix cache
        for s, n in batch.prefill_chunks:
            s.prefilled += n
            if not draft_ok:
                s.delta += n  # the draft never saw these prompt tokens
            self.scheduler.note_prefill_progress(s, draft_ok=draft_ok)
            if s.prompt_remaining == 0:
                s.prefill_done_at = self.clock
                if tr is not None:
                    tr.req_stage(s.req_id, self.clock, "decode",
                                 self.replica_id)

        finished, clipped = self._commit_decode(decode, out.n_committed,
                                                gamma)
        total_committed -= clipped  # best-effort cap: tokens never written

        m.total_tokens += total_committed
        # the planner only learns from pure-decode steps: mixed-step latency
        # includes prefill work and would corrupt the latency-per-token signal
        if (total_committed > 0 and draft_ok and not batch.prefill_chunks):
            lpt = out.latency / total_committed
            self.policy.observe(B, gamma, lpt,
                                n_accepted=(total_committed - B) / max(B, 1)
                                if gamma else None,
                                delta_max=delta_max)
        if self.record_timeline:
            self._record_timeline(B, gamma, total_committed, out.latency,
                                  draft_ok,
                                  prefill_tokens=batch.prefill_tokens)
        if self.record_timeline or tr is not None:
            m.note_spec_step(B, gamma, total_committed, out.latency,
                             forced_off=self.spec_forced_off or not draft_ok,
                             restarted=switched_on)
        if tr is not None:
            tr.step_span(t_exec0, self.clock, self.replica_id, batch=B,
                         gamma=gamma, tokens=total_committed,
                         accepted=max(total_committed - B, 0)
                         if gamma > 0 else 0,
                         prefill_tokens=batch.prefill_tokens,
                         draft_ok=draft_ok,
                         forced_off=self.spec_forced_off)
        if gamma != self.prev_gamma_effective:
            m.switch_count += 1
        self.prev_gamma_effective = gamma
        # CoW copies not consumed by a physical backend (simulated tier)
        self.scheduler.bm.drain_pending_copies()
        return StepReport("decode", t_start, self.clock, batch=B, gamma=gamma,
                          tokens=total_committed, admitted=len(batch.admitted),
                          finished=finished,
                          prefill_tokens=batch.prefill_tokens)

    # ------------------------------------------------------------------
    def finalize_metrics(self, start_clock: float = 0.0) -> Metrics:
        """Stamp elapsed time + memory-manager / prefix-cache counters onto
        the metrics."""
        m = self.metrics
        m.elapsed = self.clock - start_clock
        if self.memmgr is not None:
            m.offload_events = sum(1 for e in self.memmgr.events
                                   if e.kind == "offload")
            m.reload_events = sum(1 for e in self.memmgr.events
                                  if e.kind == "reload")
        bm = self.scheduler.bm
        m.blocks_allocated = bm.stats["allocated_blocks"]
        if bm.prefix_caching:
            m.prefix = {k: bm.stats[k] for k in
                        ("queries", "hits", "saved_tokens", "shared_blocks",
                         "forks", "evictions", "restored_blocks")}
        hs = getattr(bm, "host_store", None)
        if hs is not None:
            m.host = dict(hs.stats)
        return m

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], *, max_steps: int = 1_000_000,
            record_timeline: bool = False) -> Metrics:
        """Run-to-completion convenience wrapper over ``step``.

        Each call returns metrics for THIS batch of requests only (fresh
        Metrics object); the virtual clock and planner state carry over.
        ``record_timeline`` opts in to the (ring-bounded) per-step
        timeline dicts — off by default so long runs that never read them
        pay nothing."""
        self.metrics = Metrics()
        self.record_timeline = record_timeline
        if record_timeline:
            self.metrics.use_timeline_ring()
        for r in requests:
            self.submit(r)
        start_clock = self.clock
        steps = 0
        while steps < max_steps:
            if self.step() is None:
                break
            steps += 1
        return self.finalize_metrics(start_clock)
