"""Paged KV-cache block management with elastic expansion/contraction.

Implements the paper's §6.3 (expansion) and §6.4 (contraction with logical
remapping) faithfully:

  * ``BlockManager`` — logical bookkeeping: free list, refcounts, per-sequence
    block tables, K_boundary, migration-plan construction (§6.4 steps 1-2, 4-5).
  * ``PhysicalKVPool`` — the actual (L, num_blocks, block_size, KH, hd)
    arrays; ``migrate()`` executes the §6.4 step-3 vectorised data movement
    through the block-migration kernel (pure-jnp oracle on CPU, Pallas on TPU).

Invariants (property-tested):
  I1  a block id is either in the free list or referenced by >=1 sequence
  I2  refcounts equal the number of tables referencing the block
  I3  after contraction no table references id >= K_boundary
  I4  migration preserves every sequence's logical KV contents bit-exactly
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(Exception):
    pass


@dataclass
class MigrationPlan:
    """One-to-one mapping b_old -> b_new (old >= K_boundary, new < K_boundary)."""

    src: List[int]
    dst: List[int]

    def __len__(self):
        return len(self.src)


class BlockManager:
    """vLLM-style block allocator + Nightjar's elastic boundary."""

    def __init__(self, num_blocks: int, block_size: int):
        self.block_size = block_size
        self.base_blocks = num_blocks      # N_orig
        self.total_blocks = num_blocks     # N_orig or N_scale
        self.boundary = num_blocks         # K_boundary
        self.free: List[int] = list(range(num_blocks))
        self.refcount: Dict[int, int] = {}
        self.tables: Dict[int, List[int]] = {}   # seq_id -> block ids
        self.lengths: Dict[int, int] = {}        # seq_id -> token count
        self.reserved: set = set()                # blocks mid-migration

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    def blocks_needed(self, tokens: int) -> int:
        return max((tokens + self.block_size - 1) // self.block_size, 1)

    def can_allocate(self, tokens: int) -> bool:
        return self.num_free >= self.blocks_needed(tokens)

    # ------------------------------------------------------------------
    def _grow_table(self, table: List[int], need: int, what: str) -> List[int]:
        """Acquire ``need`` free blocks onto ``table`` (the single home of
        the free-list pop / refcount / append bookkeeping)."""
        if len(self.free) < need:
            raise OutOfBlocks(f"{what} needs {need}, free {len(self.free)}")
        added = []
        for _ in range(need):
            b = self.free.pop()
            self.refcount[b] = self.refcount.get(b, 0) + 1
            table.append(b)
            added.append(b)
        return added

    def allocate(self, seq_id: int, tokens: int) -> List[int]:
        table: List[int] = []
        self._grow_table(table, self.blocks_needed(tokens), "allocate")
        self.tables[seq_id] = table
        self.lengths[seq_id] = tokens
        return table

    def append_tokens(self, seq_id: int, n: int = 1) -> List[int]:
        """Extend a sequence by n tokens, allocating new blocks on demand."""
        table = self.tables[seq_id]
        new = self.lengths[seq_id] + n
        need = self.blocks_needed(new) - len(table)
        added = self._grow_table(table, need, "append") if need > 0 else []
        self.lengths[seq_id] = new
        return added

    def ensure_capacity(self, seq_id: int, tokens: int) -> List[int]:
        """Grow a sequence's block table to COVER ``tokens`` positions
        without changing its logical length — the real backend reserves
        room for this step's KV writes (decode token / speculative chunk /
        prefill chunk) BEFORE executing, so a paged write can never land in
        another sequence's blocks.  A later ``append_tokens`` for positions
        already covered allocates nothing."""
        table = self.tables[seq_id]
        need = self.blocks_needed(tokens) - len(table)
        if need <= 0:
            return []
        return self._grow_table(table, need, "reserve")

    def grow_to(self, seq_id: int, tokens: int) -> List[int]:
        """Ensure a sequence's table covers ``tokens`` positions, allocating
        only the shortfall (chunked prefill reserves per chunk, not per
        prompt).  No-op when the table already covers the target."""
        have = self.lengths[seq_id]
        if tokens <= have:
            return []
        return self.append_tokens(seq_id, tokens - have)

    def release(self, seq_id: int) -> None:
        for b in self.tables.pop(seq_id, []):
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                del self.refcount[b]
                if b < self.total_blocks and b not in self.reserved:
                    self.free.append(b)
        self.lengths.pop(seq_id, None)

    # ------------------------------------------------------------------
    # §6.3 expansion: attach [boundary, boundary + extra) to the pool
    def expand(self, extra_blocks: int) -> Tuple[int, int]:
        start = self.total_blocks
        self.total_blocks += extra_blocks
        # (1) allocatable ids extended; (2) refcounts implicitly zero;
        # (3) appended to the free queue
        self.free.extend(range(start, self.total_blocks))
        return start, self.total_blocks

    # §6.4 steps 1-2: identify evictees + build the migration plan
    def plan_contraction(self) -> Optional[MigrationPlan]:
        if self.total_blocks == self.base_blocks:
            return None
        evict = sorted(
            b for t in self.tables.values() for b in t if b >= self.boundary)
        # preserved-region free slots
        low_free = [b for b in self.free if b < self.boundary]
        if len(low_free) < len(evict):
            return None  # not enough room — §6.4 step 2 verification failed
        dst = sorted(low_free)[: len(evict)]
        # remove migration targets from the free list & mark reserved
        dst_set = set(dst)
        self.free = [b for b in self.free if b not in dst_set and b < self.boundary]
        self.reserved |= dst_set
        return MigrationPlan(src=evict, dst=dst)

    # §6.4 step 4: atomic metadata update & remapping
    def commit_contraction(self, plan: MigrationPlan) -> None:
        mapping = dict(zip(plan.src, plan.dst))
        for seq_id, table in self.tables.items():
            self.tables[seq_id] = [mapping.get(b, b) for b in table]
        for old, new in mapping.items():
            self.refcount[new] = self.refcount.pop(old)
            self.reserved.discard(new)
        # §6.4 step 5: trim the allocator index set
        self.free = [b for b in self.free if b < self.boundary]
        self.total_blocks = self.base_blocks
        self.reserved.clear()

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        refs: Dict[int, int] = {}
        for t in self.tables.values():
            for b in t:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self.refcount, (refs, self.refcount)
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "duplicate free blocks"
        for b in refs:
            assert b not in free_set, f"block {b} both free and referenced"
            assert 0 <= b < self.total_blocks
        for b in free_set:
            assert 0 <= b < self.total_blocks


class PhysicalKVPool:
    """Physical paged KV storage for one model (stacked over layers)."""

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.block_size = block_size
        self.k = jnp.zeros(self.shape, dtype)
        self.v = jnp.zeros(self.shape, dtype)

    @property
    def bytes_per_block(self) -> int:
        L, _, bs, kh, hd = self.shape
        return 2 * L * bs * kh * hd * self.k.dtype.itemsize  # k + v

    def grow(self, extra_blocks: int) -> None:
        L, n, bs, kh, hd = self.shape
        pad = jnp.zeros((L, extra_blocks, bs, kh, hd), self.k.dtype)
        self.k = jnp.concatenate([self.k, pad], axis=1)
        self.v = jnp.concatenate([self.v, pad], axis=1)
        self.shape = (L, n + extra_blocks, bs, kh, hd)

    def shrink(self, to_blocks: int) -> None:
        L, n, bs, kh, hd = self.shape
        self.k = self.k[:, :to_blocks]
        self.v = self.v[:, :to_blocks]
        self.shape = (L, to_blocks, bs, kh, hd)

    def write_tokens(self, layer_k, layer_v, block_table, start_pos: int) -> None:
        """Write contiguous token K/V (L, T, KH, hd) into paged storage."""
        L, T = layer_k.shape[0], layer_k.shape[1]
        for t in range(T):
            pos = start_pos + t
            blk = block_table[pos // self.block_size]
            off = pos % self.block_size
            self.k = self.k.at[:, blk, off].set(layer_k[:, t])
            self.v = self.v.at[:, blk, off].set(layer_v[:, t])

    def gather_sequence(self, block_table: Sequence[int], length: int):
        """Return contiguous (L, length, KH, hd) K/V for one sequence."""
        idx = jnp.asarray(list(block_table), jnp.int32)
        k = self.k[:, idx].reshape(self.shape[0], -1, *self.shape[3:])[:, :length]
        v = self.v[:, idx].reshape(self.shape[0], -1, *self.shape[3:])[:, :length]
        return k, v

    def migrate(self, plan: MigrationPlan, *, use_kernel: bool = True) -> None:
        """§6.4 step 3: vectorised block migration (kernel-backed)."""
        if not len(plan):
            return
        from ..kernels import block_migration
        src = jnp.asarray(plan.src, jnp.int32)
        dst = jnp.asarray(plan.dst, jnp.int32)
        self.k = block_migration.migrate_blocks(self.k, src, dst,
                                                use_kernel=use_kernel)
        self.v = block_migration.migrate_blocks(self.v, src, dst,
                                                use_kernel=use_kernel)
