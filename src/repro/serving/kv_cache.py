"""Paged KV-cache block management with elastic expansion/contraction and
vLLM-style copy-on-write prefix sharing.

Implements the paper's §6.3 (expansion) and §6.4 (contraction with logical
remapping) faithfully:

  * ``BlockManager`` — logical bookkeeping: free list, refcounts, per-sequence
    block tables, K_boundary, migration-plan construction (§6.4 steps 1-2, 4-5).
  * ``PhysicalKVPool`` — the actual (L, num_blocks, block_size, KH, hd)
    arrays; ``migrate()`` executes the §6.4 step-3 vectorised data movement
    through the block-migration kernel (pure-jnp oracle on CPU, Pallas on TPU).

Prefix sharing (``prefix_caching=True``) adds a content-hash index over
*full* prefix blocks, hash-chained over token ids:

  * ``match_prefix`` finds the longest cached prefix of a prompt;
  * ``share`` maps those blocks into a new sequence's table at refcount+1;
  * ``register_prefix`` publishes a sequence's freshly materialised full
    prompt blocks for reuse;
  * ``fork_for_write`` privatises any refcount>1 block a write range covers
    (copy-on-write) and records the (src, dst) copy for the physical tier
    to execute (``drain_pending_copies``);
  * a block whose refcount drops to 0 while registered is *cached-reusable*:
    it parks in an LRU tier instead of the free list and is only recycled
    when the free list runs dry (eviction unregisters it).

A block is therefore in exactly one of three states: **free** (allocatable,
content dead), **cached-reusable** (refcount 0, content live in the hash
index, reclaimable on demand) or **pinned** (refcount >= 1).

With a ``HostKVStore`` attached (``host_store=``), eviction from the
cached-reusable tier gains a fourth, host-side destination: instead of
discarding the block's content, the manager records it under the block's
chain hash and queues a device→host copy (``pending_spills``); a later
``match_prefix`` walk that misses the device index but hits the host store
restores the content into a *free* device block (``pending_restores``,
host→device) and re-registers the hash, so the admission path counts the
restored prefix as cached.  The physical tier drains both queues before its
step writes — spills before restores, so a block spilled and re-matched in
the same scheduling round restores the just-captured payload.  This is the
KV-side generalisation of the paper's draft-offload move (§6.2): cold
prefix state parks in host memory instead of being recomputed.

Invariants (property-tested):
  I1  a block id is in the free list, the cached-LRU tier, or referenced
      by >=1 sequence — exactly one of the three
  I2  refcounts equal the number of tables referencing the block
  I3  after contraction no table references id >= K_boundary
  I4  migration preserves every sequence's logical KV contents bit-exactly
  I5  every cached hash maps to a live (non-free) block whose stored token
      chain reproduces the hash
  I6  (host tier) every pending restore targets a registered device block
      backed by a pinned host record; host and device indices are disjoint
      except for restores in flight, and every host record either
      reproduces its key AND passes its payload checksum, or fails the
      checksum (no *silently* corrupt record — a failed checksum is
      detectable and the restore path drops the record); pinned records
      (restore in flight) always verify
  I7  (crash) a FAILED replica owns nothing: no block tables, no
      refcounts, no cached/registered blocks, every block back on the
      free list, no pending copies/spills/restores, and no pinned host
      records (``check_invariants(failed=True)``)
  I8  (lifecycle completeness) every block id in the pool is reachable:
      free, cached-reusable, table-referenced, or reserved for an
      in-flight contraction migration — a cancelled or deadline-expired
      request can never strand a block in NO structure (the leak I1's
      disjointness checks alone cannot see)
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OutOfBlocks(Exception):
    pass


class SharedBlockWrite(Exception):
    """A write would land in a block with refcount > 1.  Shared (prefix)
    blocks are immutable; the write must be routed through
    ``BlockManager.fork_for_write`` first (copy-on-write)."""


# ---------------------------------------------------------------------------
# Stable content hashing.  Any identity derived from token content that can
# cross a process boundary (the prefix-cache index here, and the routing-tier
# template keys in serving/controlplane.py) must come from this seeded
# blake2b chain — never from Python's hash(), whose salting rules are an
# implementation detail we refuse to depend on.  The chain is:
#   h_0 = CHAIN_ROOT;  h_i = chain_hash(h_{i-1}, block_i_token_ids)
# so a block's hash commits to the entire prefix before it.
# ---------------------------------------------------------------------------

# chain-hash seed for the first block of a prompt (a fixed, documented seed
# so independently constructed processes agree on every chain value)
CHAIN_ROOT = 0x517CC1B727220A95
_CHAIN_ROOT = CHAIN_ROOT   # backward-compatible alias
_MASK64 = (1 << 64) - 1


def chain_hash(parent: int, tokens: Sequence[int]) -> int:
    """Seeded content hash of one token block chained onto ``parent``.

    blake2b over the parent hash plus the token ids serialised as
    little-endian int64 — one C-level call per block, deterministic across
    processes, platforms, interpreter versions and ``PYTHONHASHSEED``
    values (regression-tested against golden values in
    tests/test_controlplane.py).  This sits on the per-admission hot path
    (every full block of every prompt is hashed), hence no Python-level
    per-token loop."""
    buf = (parent & _MASK64).to_bytes(8, "little") \
        + np.asarray(tokens, dtype="<i8").tobytes()
    return int.from_bytes(hashlib.blake2b(buf, digest_size=8).digest(),
                          "little")


@dataclass
class MigrationPlan:
    """One-to-one mapping b_old -> b_new (old >= K_boundary, new < K_boundary)."""

    src: List[int]
    dst: List[int]

    def __len__(self):
        return len(self.src)


@dataclass
class HostBlockRecord:
    """One spilled prefix block in host memory.

    ``parent``/``tokens`` are the chain-hash material (enough to re-verify
    the key and to re-register the block on restore); ``data`` holds the
    per-pool page payloads once the physical tier executes the spill —
    keyed ``"<pool_tag>:<page_key>"`` (e.g. ``"t:k_pages"``) with
    host-side numpy arrays.  The simulated tier never fills ``data``.
    ``checksum`` is the blake2b integrity stamp over (parent, tokens,
    data), written at spill time (and re-sealed after the physical tier
    fills ``data``) and verified before any restore — host memory is
    outside the device's ECC domain, so a record is never trusted on
    faith."""

    parent: int
    tokens: Tuple[int, ...]
    data: Dict[str, np.ndarray] = field(default_factory=dict)
    checksum: Optional[int] = None


def record_checksum(parent: int, tokens: Sequence[int],
                    data: Dict[str, np.ndarray]) -> int:
    """Integrity checksum of one host record: blake2b over the chain-hash
    material plus every payload page (key + raw bytes, key-sorted so the
    stamp is independent of dict insertion order)."""
    hsh = hashlib.blake2b(digest_size=8)
    hsh.update((parent & _MASK64).to_bytes(8, "little"))
    hsh.update(np.asarray(tokens, dtype="<i8").tobytes())
    for key in sorted(data):
        hsh.update(key.encode())
        hsh.update(np.ascontiguousarray(data[key]).tobytes())
    return int.from_bytes(hsh.digest(), "little")


class HostKVStore:
    """Host-memory spill tier for evicted cached-reusable prefix blocks.

    An LRU dict keyed by the block's blake2b chain hash — the same
    process-stable identity the device-side ``hash_index`` uses, so a
    restored block re-registers under exactly the key ``match_prefix``
    walks.  Capacity is counted in blocks; inserting past capacity evicts
    host-LRU records, except records *pinned* by an in-flight restore
    (the device side already re-registered their hash; dropping the record
    before the physical copy would serve garbage content)."""

    def __init__(self, capacity_blocks: int = 4096):
        self.capacity = max(int(capacity_blocks), 1)
        self.records: "OrderedDict[int, HostBlockRecord]" = OrderedDict()
        self.pinned: set = set()               # hashes with restores in flight
        self.stats: Dict[str, float] = dict(
            spills=0, spilled_blocks=0, restores=0, host_evictions=0,
            spill_s=0.0, restore_s=0.0, corrupt_dropped=0)

    def __len__(self) -> int:
        return len(self.records)

    def __contains__(self, h: int) -> bool:
        return h in self.records

    def put(self, h: int, parent: int, tokens: Tuple[int, ...]) -> None:
        """Index (or refresh) a spilled block.  A re-spill of a hash the
        store already holds keeps the existing record (content is fully
        determined by the hash) and just refreshes its LRU position."""
        rec = self.records.get(h)
        if rec is None:
            rec = HostBlockRecord(parent, tuple(tokens))
            rec.checksum = record_checksum(rec.parent, rec.tokens, rec.data)
            self.records[h] = rec
            self.stats["spills"] += 1
        self.records.move_to_end(h)
        while len(self.records) > self.capacity:
            victim = next((k for k in self.records if k not in self.pinned),
                          None)
            if victim is None:
                break                      # everything pinned: tolerate spill
            del self.records[victim]
            self.stats["host_evictions"] += 1

    def get(self, h: int) -> Optional[HostBlockRecord]:
        rec = self.records.get(h)
        if rec is not None:
            self.records.move_to_end(h)
        return rec

    def pin(self, h: int) -> None:
        self.pinned.add(h)

    def unpin(self, h: int) -> None:
        self.pinned.discard(h)

    def take(self, h: int) -> Optional[HostBlockRecord]:
        """Consume a record at restore time: move semantics — once the
        content is back in a device block the host copy is dropped (a later
        eviction re-spills it)."""
        rec = self.records.pop(h, None)
        self.pinned.discard(h)
        if rec is not None:
            self.stats["restores"] += 1
        return rec

    # -- integrity ---------------------------------------------------------

    def seal(self, h: int) -> None:
        """Re-stamp a record's checksum after its payload pages are filled
        (the physical tier writes ``data`` after ``put`` indexed the
        record, so the stamp must follow the bytes)."""
        rec = self.records.get(h)
        if rec is not None:
            rec.checksum = record_checksum(rec.parent, rec.tokens, rec.data)

    def verify(self, h: int) -> bool:
        """True iff the record exists and its bytes match its stamp."""
        rec = self.records.get(h)
        return rec is not None and rec.checksum == record_checksum(
            rec.parent, rec.tokens, rec.data)

    def drop_corrupt(self, h: int) -> None:
        """Discard a record that failed verification.  The prefix it held
        will cold-re-prefill — strictly better than serving bad KV."""
        self.records.pop(h, None)
        self.pinned.discard(h)
        self.stats["corrupt_dropped"] += 1

    def corrupt(self, h: int) -> bool:
        """Fault injection: flip payload bits of one record WITHOUT
        updating its stamp (models bit rot / a bad DMA).  Pinned records
        are refused — an in-flight restore already owns that content.
        Returns True if the record was corrupted."""
        rec = self.records.get(h)
        if rec is None or h in self.pinned:
            return False
        if rec.data:
            key = sorted(rec.data)[0]
            arr = np.ascontiguousarray(rec.data[key])
            flat = arr.view(np.uint8).reshape(-1).copy()
            if not flat.size:
                return False
            flat[0] ^= 0xFF
            rec.data[key] = flat.view(arr.dtype).reshape(arr.shape)
        else:
            # simulated tier holds no pages: corrupt the chain material
            rec.tokens = (rec.tokens[0] ^ 1,) + rec.tokens[1:]
        return True


class BlockManager:
    """vLLM-style block allocator + Nightjar's elastic boundary."""

    def __init__(self, num_blocks: int, block_size: int, *,
                 prefix_caching: bool = False,
                 host_store: Optional[HostKVStore] = None):
        self.block_size = block_size
        self.base_blocks = num_blocks      # N_orig
        self.total_blocks = num_blocks     # N_orig or N_scale
        self.boundary = num_blocks         # K_boundary
        self.free: List[int] = list(range(num_blocks))
        self.refcount: Dict[int, int] = {}
        self.tables: Dict[int, List[int]] = {}   # seq_id -> block ids
        self.lengths: Dict[int, int] = {}        # seq_id -> token count
        self.reserved: set = set()                # blocks mid-migration
        # --- prefix-sharing state (all empty with caching off) ---
        self.prefix_caching = prefix_caching
        self.hash_index: Dict[int, int] = {}      # chain hash -> block id
        self.block_hash: Dict[int, int] = {}      # block id -> chain hash
        # block id -> (parent chain hash, token tuple): collision guard +
        # the material for the I5 invariant check
        self.block_chain: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self.cached: "OrderedDict[int, None]" = OrderedDict()  # LRU order
        self.pending_copies: List[Tuple[int, int]] = []  # CoW (src, dst)
        # --- host offload tier (inactive when host_store is None) ---
        # spill: (block id, hash) device→host copies the physical tier owes;
        # restore: (hash, block id) host→device copies, queued by
        # match_prefix when a chain walk hits the host store.  Both drain
        # before the step's writes, spills first.
        self.host_store = host_store if prefix_caching else None
        self.pending_spills: List[Tuple[int, int]] = []
        self.pending_restores: List[Tuple[int, int]] = []
        self.stats: Dict[str, int] = dict(
            queries=0, hits=0, saved_tokens=0, shared_blocks=0, forks=0,
            evictions=0, allocated_blocks=0, restored_blocks=0)
        # observability seam: engine.attach_trace wires these (trace_ctx
        # yields the live (clock, replica_id) for spill/restore instants)
        self.trace = None
        self.trace_ctx = None

    def _trace_instant(self, name: str, **args) -> None:
        tr = self.trace
        if tr is not None and tr.enabled and self.trace_ctx is not None:
            t, rep = self.trace_ctx()
            tr.instant("kv", name, t, replica=rep, args=args)

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def num_allocatable(self) -> int:
        """Blocks an allocation may consume: truly free plus cached-reusable
        (refcount-0 prefix blocks, evicted LRU-first on demand)."""
        return len(self.free) + len(self.cached)

    def blocks_needed(self, tokens: int) -> int:
        return max((tokens + self.block_size - 1) // self.block_size, 1)

    def can_allocate(self, tokens: int) -> bool:
        return self.num_allocatable >= self.blocks_needed(tokens)

    # ------------------------------------------------------------------
    def _pop_block(self, what: str) -> int:
        """One allocatable block id: the free list first, then LRU eviction
        of a cached-reusable prefix block (which unregisters it, spilling
        its content to the host tier when one is attached)."""
        if self.free:
            return self.free.pop()
        if self.cached:
            b = next(iter(self.cached))              # least recently used
            self._evict_cached_block(b)
            return b
        raise OutOfBlocks(f"{what}: pool exhausted")

    def _grow_table(self, table: List[int], need: int, what: str) -> List[int]:
        """Acquire ``need`` free blocks onto ``table`` (the single home of
        the free-list pop / refcount / append bookkeeping)."""
        if self.num_allocatable < need:
            raise OutOfBlocks(
                f"{what} needs {need}, allocatable {self.num_allocatable}")
        added = []
        for _ in range(need):
            b = self._pop_block(what)
            self.refcount[b] = self.refcount.get(b, 0) + 1
            table.append(b)
            added.append(b)
        self.stats["allocated_blocks"] += need
        return added

    def allocate(self, seq_id: int, tokens: int) -> List[int]:
        table: List[int] = []
        self._grow_table(table, self.blocks_needed(tokens), "allocate")
        self.tables[seq_id] = table
        self.lengths[seq_id] = tokens
        return table

    def _assert_writable(self, table: List[int], start: int, end: int,
                         what: str) -> None:
        """Hard error if the content-write range [start, end) covers any
        block shared with another sequence — the silent-aliasing hazard a
        missing ``fork_for_write`` would otherwise introduce."""
        bs = self.block_size
        for idx in range(start // bs, min(-(-end // bs), len(table))):
            b = table[idx]
            if self.refcount.get(b, 0) > 1:
                raise SharedBlockWrite(
                    f"{what}: positions [{start},{end}) cover block {b} "
                    f"(refcount {self.refcount[b]}); route the write through "
                    "fork_for_write first")

    def append_tokens(self, seq_id: int, n: int = 1) -> List[int]:
        """Extend a sequence by n tokens, allocating new blocks on demand.
        The appended token content lands in [old_len, old_len+n): that range
        must be private (see :meth:`fork_for_write`)."""
        table = self.tables[seq_id]
        old = self.lengths[seq_id]
        new = old + n
        self._assert_writable(table, old, new, "append")
        need = self.blocks_needed(new) - len(table)
        added = self._grow_table(table, need, "append") if need > 0 else []
        self.lengths[seq_id] = new
        return added

    def ensure_capacity(self, seq_id: int, tokens: int) -> List[int]:
        """Grow a sequence's block table to COVER ``tokens`` positions
        without changing its logical length — the real backend reserves
        room for this step's KV writes (decode token / speculative chunk /
        prefill chunk) BEFORE executing, so a paged write can never land in
        another sequence's blocks.  A later ``append_tokens`` for positions
        already covered allocates nothing."""
        table = self.tables[seq_id]
        need = self.blocks_needed(tokens) - len(table)
        if need <= 0:
            return []
        return self._grow_table(table, need, "reserve")

    def grow_to(self, seq_id: int, tokens: int) -> List[int]:
        """Ensure a sequence's table covers ``tokens`` positions, allocating
        only the shortfall (chunked prefill reserves per chunk, not per
        prompt).  No-op when the table already covers the target."""
        have = self.lengths[seq_id]
        if tokens <= have:
            return []
        return self.append_tokens(seq_id, tokens - have)

    def release(self, seq_id: int) -> None:
        dropped: List[int] = []
        for b in self.tables.pop(seq_id, []):
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                del self.refcount[b]
                if b >= self.total_blocks or b in self.reserved:
                    self._unregister(b)
                    continue
                if b in self.block_hash:
                    # registered prefix content stays reusable: park in the
                    # LRU tier (most-recently-used end) instead of freeing
                    self.cached[b] = None
                    self.cached.move_to_end(b)
                else:
                    self.free.append(b)
                    dropped.append(b)
        if dropped and self.pending_copies:
            # a pending CoW copy targeting a block that just went back to
            # the free list is moot (its forking sequence is gone) — and
            # executing it after reallocation would clobber the new owner
            ds = set(dropped)
            self.pending_copies = [p for p in self.pending_copies
                                   if p[1] not in ds]
        self.lengths.pop(seq_id, None)

    # ------------------------------------------------------------------
    # prefix sharing: content-hash index + copy-on-write forking
    # ------------------------------------------------------------------
    def _unregister(self, b: int) -> None:
        h = self.block_hash.pop(b, None)
        if h is not None and self.hash_index.get(h) == b:
            del self.hash_index[h]
        self.block_chain.pop(b, None)
        self.cached.pop(b, None)
        if h is not None and self.pending_restores:
            # the block was a restore TARGET whose host→device copy never
            # executed: cancel the restore — the host record (still pinned
            # until now) remains the sole owner of the content
            kept = [(ph, pb) for ph, pb in self.pending_restores if pb != b]
            if len(kept) != len(self.pending_restores):
                self.pending_restores = kept
                if self.host_store is not None:
                    self.host_store.unpin(h)

    def _evict_cached_block(self, b: int) -> None:
        """Evict one cached-reusable block: spill its content to the host
        tier (when attached, and unless the block is itself an
        unmaterialised restore target — then the host record already owns
        the content), then unregister.  The caller decides where the freed
        id goes (returned to the caller by ``_pop_block``, appended to the
        free list by ``plan_contraction``)."""
        hs = self.host_store
        h = self.block_hash.get(b)
        self.cached.pop(b, None)
        if hs is not None and h is not None and \
                not any(pb == b for _, pb in self.pending_restores):
            parent, toks = self.block_chain[b]
            hs.put(h, parent, toks)
            self.pending_spills.append((b, h))
            self._trace_instant("spill", block=b)
        self._unregister(b)
        self.stats["evictions"] += 1

    def match_prefix(self, tokens: Optional[Sequence[int]]
                     ) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: walk the hash chain over
        full blocks, verifying stored token content (collision guard).
        When the device index misses but the host tier holds the hash, the
        walk continues by *restoring*: a free device block is registered
        under the hash, parked in the cached-LRU tier, and the host→device
        copy queued for the physical tier — so admission accounting sees
        restorable blocks as cached.  Returns (block ids, matched token
        count) — both empty/0 when caching is off or nothing matches."""
        if not self.prefix_caching or not tokens:
            return [], 0
        self.stats["queries"] += 1
        bs = self.block_size
        blocks: List[int] = []
        h = _CHAIN_ROOT
        for i in range(len(tokens) // bs):
            blk = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            h = chain_hash(h, blk)
            b = self.hash_index.get(h)
            if b is not None:
                if self.block_chain[b][1] != blk:
                    break
                blocks.append(b)
                continue
            b = self._restore_block(h, blk)
            if b is None:
                break
            blocks.append(b)
        return blocks, len(blocks) * bs

    def _restore_block(self, h: int, blk: Tuple[int, ...]) -> Optional[int]:
        """Pull one host-tier block back onto the device: allocate strictly
        from the free list (never evict device-cached content to make room
        — that would thrash the warmer tier), register the hash at the new
        home, park it cached-reusable, and queue the host→device copy.  The
        host record stays (pinned) until the physical drain consumes it."""
        hs = self.host_store
        if hs is None or not self.free:
            return None
        rec = hs.get(h)
        if rec is None:
            return None
        if not hs.verify(h):
            # integrity stamp mismatch (bit rot, bad DMA, injected fault):
            # drop the record and let the prefix cold-re-prefill — bad KV
            # is never restored into the device tier
            hs.drop_corrupt(h)
            return None
        if rec.tokens != blk:
            return None
        b = self.free.pop()
        self.hash_index[h] = b
        self.block_hash[b] = h
        self.block_chain[b] = (rec.parent, rec.tokens)
        self.cached[b] = None
        self.cached.move_to_end(b)
        self.pending_restores.append((h, b))
        hs.pin(h)
        self.stats["restored_blocks"] += 1
        self._trace_instant("restore", block=b)
        return b

    def drain_pending_spills(self) -> List[Tuple[int, int]]:
        """Hand the queued device→host (block, hash) spills to the physical
        tier, which gathers each block's pages into the matching
        ``HostKVStore`` record (skipping hashes the host LRU already
        dropped).  Must run before this step's writes AND before
        ``drain_pending_restores`` — a block spilled and re-matched in the
        same round restores the payload this drain captures."""
        out, self.pending_spills = self.pending_spills, []
        return out

    def drain_pending_restores(self) -> List[Tuple[int, int]]:
        """Hand the queued host→device (hash, block) restores to the
        physical tier, which scatters each record's payload into the
        target block and then ``take``s the record (move semantics)."""
        out, self.pending_restores = self.pending_restores, []
        return out

    def share(self, seq_id: int, blocks: List[int], tokens: int) -> List[int]:
        """Admission side of prefix sharing: map cached prefix ``blocks``
        into a new sequence's table at refcount+1, crediting ``tokens``
        materialised positions (the cached prefix needs no prefill compute
        and no new blocks).  Cached-reusable blocks become pinned again."""
        assert seq_id not in self.tables, seq_id
        table: List[int] = []
        for b in blocks:
            self.cached.pop(b, None)          # pinned while refcount >= 1
            self.refcount[b] = self.refcount.get(b, 0) + 1
            table.append(b)
        self.tables[seq_id] = table
        self.lengths[seq_id] = tokens
        self.stats["hits"] += 1
        self.stats["saved_tokens"] += tokens
        self.stats["shared_blocks"] += len(blocks)
        return table

    def register_prefix(self, seq_id: int, tokens: Optional[Sequence[int]],
                        upto: int) -> int:
        """Publish a sequence's materialised *full* prompt blocks (the first
        ``upto`` tokens of ``tokens``) in the hash index so future
        admissions can share them.  Idempotent; already-cached hashes keep
        their first publisher.  Returns the number of newly indexed blocks."""
        if not self.prefix_caching or tokens is None:
            return 0
        table = self.tables.get(seq_id)
        if table is None:
            return 0
        bs = self.block_size
        n = min(upto, len(tokens)) // bs
        h = _CHAIN_ROOT
        added = 0
        for i in range(min(n, len(table))):
            blk = tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
            parent = h
            h = chain_hash(parent, blk)
            b = table[i]
            if self.block_hash.get(b) == h:
                continue                      # already registered
            if h in self.hash_index or b in self.block_hash:
                continue                      # hash or block taken elsewhere
            self.hash_index[h] = b
            self.block_hash[b] = h
            self.block_chain[b] = (parent, blk)
            if self.host_store is not None:
                # the sequence re-materialised this content on device (e.g.
                # a restore was skipped for lack of free blocks): the fresh
                # device copy supersedes the host record — drop it so the
                # tiers stay disjoint.  It cannot be pinned: a pinned hash
                # has a pending restore, hence is already in hash_index and
                # was skipped above.
                self.host_store.records.pop(h, None)
            added += 1
        return added

    def shared_blocks_in_range(self, blocks: List[int], start: int,
                               end: int) -> int:
        """How many of ``blocks`` (a table prefix) a write to positions
        [start, end) would touch — the worst-case fork count an admission
        must budget for."""
        bs = self.block_size
        lo = start // bs
        hi = min(-(-end // bs), len(blocks))
        return max(hi - lo, 0)

    def fork_for_write(self, seq_id: int, start: int, end: int
                       ) -> List[Tuple[int, int]]:
        """Copy-on-write: privatise every refcount>1 block the write range
        [start, end) covers.  Allocates a private replacement (may evict
        cached-reusable blocks), swaps it into the table, and records the
        (src, dst) pair in ``pending_copies`` for the physical tier to
        execute before the step's writes.  Returns the new pairs."""
        table = self.tables.get(seq_id)
        if table is None:
            return []
        bs = self.block_size
        copies: List[Tuple[int, int]] = []
        for idx in range(start // bs, min(-(-end // bs), len(table))):
            b = table[idx]
            if self.refcount.get(b, 0) > 1:
                nb = self._pop_block("fork")   # may raise OutOfBlocks
                self.refcount[b] -= 1
                self.refcount[nb] = 1
                table[idx] = nb
                # queue IMMEDIATELY: if a later block's fork raises, the
                # already-swapped private copies must still receive their
                # shared content (the caller preempts a victim and retries,
                # and the retry skips blocks that are now private)
                self.pending_copies.append((b, nb))
                copies.append((b, nb))
                self.stats["forks"] += 1
                self.stats["allocated_blocks"] += 1
        return copies

    def drain_pending_copies(self) -> List[Tuple[int, int]]:
        """Hand the accumulated CoW (src, dst) copies to the caller (the
        physical runtime batches them into one block-migration launch)."""
        out, self.pending_copies = self.pending_copies, []
        return out

    # ------------------------------------------------------------------
    # §6.3 expansion: attach [boundary, boundary + extra) to the pool
    def expand(self, extra_blocks: int) -> Tuple[int, int]:
        start = self.total_blocks
        self.total_blocks += extra_blocks
        # (1) allocatable ids extended; (2) refcounts implicitly zero;
        # (3) appended to the free queue
        self.free.extend(range(start, self.total_blocks))
        return start, self.total_blocks

    # §6.4 steps 1-2: identify evictees + build the migration plan
    def plan_contraction(self) -> Optional[MigrationPlan]:
        if self.total_blocks == self.base_blocks:
            return None
        # Cached-reusable (refcount-0) prefix blocks AT OR ABOVE the
        # boundary cannot survive the trim: evict them (spilling to the
        # host tier when attached).  Below-boundary cached blocks KEEP
        # their registrations — the shrunk pool can hold them, and
        # evicting them too would cold-restart the prefix cache on every
        # contraction cycle.
        for b in [x for x in self.cached if x >= self.boundary]:
            self._evict_cached_block(b)
            if b < self.total_blocks and b not in self.reserved:
                self.free.append(b)
        # deduplicate: a shared prefix block (refcount > 1) appears in
        # several tables but must migrate exactly once — a per-reference
        # list would reserve one dst per REFERENCE and strand the extras
        # in no tier (caught by I8)
        evict = sorted(
            {b for t in self.tables.values() for b in t if b >= self.boundary})
        # preserved-region free slots; when they cannot host every migrated
        # block, evict the minimum number of below-boundary cached blocks
        # (LRU-first, spilled like any other eviction) to make room —
        # pinned content always outranks reusable content
        low_free = [b for b in self.free if b < self.boundary]
        while len(low_free) < len(evict):
            b = next((x for x in self.cached if x < self.boundary), None)
            if b is None:
                break
            self._evict_cached_block(b)
            self.free.append(b)
            low_free.append(b)
        if len(low_free) < len(evict):
            return None  # not enough room — §6.4 step 2 verification failed
        dst = sorted(low_free)[: len(evict)]
        # remove migration targets from the free list & mark reserved
        dst_set = set(dst)
        self.free = [b for b in self.free if b not in dst_set and b < self.boundary]
        self.reserved |= dst_set
        return MigrationPlan(src=evict, dst=dst)

    # §6.4 step 4: atomic metadata update & remapping
    def commit_contraction(self, plan: MigrationPlan) -> None:
        mapping = dict(zip(plan.src, plan.dst))
        for seq_id, table in self.tables.items():
            self.tables[seq_id] = [mapping.get(b, b) for b in table]
        # queued CoW copies follow the same remapping: the §6.4 step-3 data
        # movement already relocated a migrated block's content, so a pending
        # (src, dst) pair must point at the blocks' post-migration homes
        # (stale high ids would index past the shrunk physical pools)
        self.pending_copies = [(mapping.get(s, s), mapping.get(d, d))
                               for s, d in self.pending_copies]
        for old, new in mapping.items():
            self.refcount[new] = self.refcount.pop(old)
            self.reserved.discard(new)
            # registered (pinned) prefix blocks carry their hash to the new
            # home; high cached refcount-0 blocks were already evicted at
            # plan time (below-boundary ones survive in place, untouched by
            # the mapping), so only table-referenced registrations appear
            h = self.block_hash.pop(old, None)
            if h is not None:
                self.block_hash[new] = h
                self.block_chain[new] = self.block_chain.pop(old)
                if self.hash_index.get(h) == old:
                    self.hash_index[h] = new
        # §6.4 step 5: trim the allocator index set
        self.free = [b for b in self.free if b < self.boundary]
        self.total_blocks = self.base_blocks
        self.reserved.clear()

    # ------------------------------------------------------------------
    def check_invariants(self, *, failed: bool = False) -> None:
        if failed:
            # I7: a FAILED replica owns nothing.  Its in-flight work is
            # lost, its blocks are gone — every block must be back on the
            # free list with no residual registrations, queued transfers
            # or host-store pins (a leak here is permanent: the replica
            # never steps again to drain anything).
            assert not self.tables, f"FAILED replica owns tables {self.tables}"
            assert not self.refcount, "FAILED replica holds refcounts"
            assert not self.cached, "FAILED replica holds cached blocks"
            assert not self.hash_index and not self.block_hash, \
                "FAILED replica holds registrations"
            assert not self.pending_copies, "FAILED replica owes CoW copies"
            assert not self.pending_spills, "FAILED replica owes spills"
            assert not self.pending_restores, "FAILED replica owes restores"
            assert len(self.free) == self.total_blocks, \
                (len(self.free), self.total_blocks)
            if self.host_store is not None:
                assert not self.host_store.pinned, \
                    f"FAILED replica pins host records {self.host_store.pinned}"
        refs: Dict[int, int] = {}
        for t in self.tables.values():
            for b in t:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self.refcount, (refs, self.refcount)
        free_set = set(self.free)
        assert len(free_set) == len(self.free), "duplicate free blocks"
        for b in refs:
            assert b not in free_set, f"block {b} both free and referenced"
            assert 0 <= b < self.total_blocks
        for b in free_set:
            assert 0 <= b < self.total_blocks
        # I8: completeness — every pool block is in SOME structure.  The
        # checks above prove disjointness; this proves a release path
        # (cancellation, deadline reaping, force_fail) leaked nothing.
        covered = (free_set | set(refs) | set(self.cached)
                   | set(self.reserved))
        leaked = set(range(self.total_blocks)) - covered
        assert not leaked, f"blocks {sorted(leaked)} leaked (in no tier)"
        # I5: the prefix-cache index is consistent — every cached hash maps
        # to a live block whose stored token chain reproduces the hash, and
        # the cached-LRU tier is disjoint from both the free list and tables
        for b in self.cached:
            assert b in self.block_hash, f"cached block {b} unregistered"
            assert b not in refs, f"cached block {b} still referenced"
            assert b not in free_set, f"cached block {b} also free"
        for h, b in self.hash_index.items():
            assert self.block_hash.get(b) == h, (h, b)
            parent, toks = self.block_chain[b]
            assert chain_hash(parent, toks) == h, f"stale chain for block {b}"
            assert len(toks) == self.block_size, "partial block registered"
            assert b not in free_set, f"registered block {b} in free list"
            assert b in refs or b in self.cached, f"registered block {b} dead"
            assert 0 <= b < self.total_blocks
        for b, h in self.block_hash.items():
            assert self.hash_index.get(h) == b, (b, h)
        for src, dst in self.pending_copies:
            assert refs.get(dst) == 1, f"CoW target {dst} not private"
        # I6: the host tier's index is consistent with the device's — every
        # pending restore targets a registered (cached or pinned) device
        # block backed by a pinned host record, tiers are disjoint except
        # for restores in flight, and every host record reproduces its key
        hs = self.host_store
        if hs is not None:
            restoring = {h for h, _ in self.pending_restores}
            for h, b in self.pending_restores:
                assert h in hs.records, f"restore {h:#x} lost its record"
                assert h in hs.pinned, f"restore {h:#x} not pinned"
                assert self.hash_index.get(h) == b, (h, b)
                assert b in self.cached or b in refs, \
                    f"restore target {b} neither cached nor referenced"
            for h, rec in hs.records.items():
                if hs.verify(h):
                    assert chain_hash(rec.parent, rec.tokens) == h, \
                        f"host record {h:#x} chain mismatch"
                    assert len(rec.tokens) == self.block_size, \
                        "partial block spilled"
                else:
                    # a record may carry injected corruption, but never
                    # SILENTLY: the stamp must catch it, and a pinned
                    # record (restore in flight — its content is about to
                    # land on the device) must always verify
                    assert h not in hs.pinned, \
                        f"pinned host record {h:#x} fails its checksum"
                if h not in restoring:
                    assert h not in self.hash_index, \
                        f"hash {h:#x} live on both tiers without a restore"


class PhysicalKVPool:
    """Physical paged KV storage for one model (stacked over layers)."""

    def __init__(self, num_layers: int, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        self.shape = (num_layers, num_blocks, block_size, num_kv_heads, head_dim)
        self.block_size = block_size
        self.k = jnp.zeros(self.shape, dtype)
        self.v = jnp.zeros(self.shape, dtype)

    @property
    def bytes_per_block(self) -> int:
        L, _, bs, kh, hd = self.shape
        return 2 * L * bs * kh * hd * self.k.dtype.itemsize  # k + v

    def grow(self, extra_blocks: int) -> None:
        L, n, bs, kh, hd = self.shape
        pad = jnp.zeros((L, extra_blocks, bs, kh, hd), self.k.dtype)
        self.k = jnp.concatenate([self.k, pad], axis=1)
        self.v = jnp.concatenate([self.v, pad], axis=1)
        self.shape = (L, n + extra_blocks, bs, kh, hd)

    def shrink(self, to_blocks: int) -> None:
        L, n, bs, kh, hd = self.shape
        self.k = self.k[:, :to_blocks]
        self.v = self.v[:, :to_blocks]
        self.shape = (L, to_blocks, bs, kh, hd)

    def write_tokens(self, layer_k, layer_v, block_table, start_pos: int) -> None:
        """Write contiguous token K/V (L, T, KH, hd) into paged storage."""
        L, T = layer_k.shape[0], layer_k.shape[1]
        for t in range(T):
            pos = start_pos + t
            blk = block_table[pos // self.block_size]
            off = pos % self.block_size
            self.k = self.k.at[:, blk, off].set(layer_k[:, t])
            self.v = self.v.at[:, blk, off].set(layer_v[:, t])

    def gather_sequence(self, block_table: Sequence[int], length: int):
        """Return contiguous (L, length, KH, hd) K/V for one sequence."""
        idx = jnp.asarray(list(block_table), jnp.int32)
        k = self.k[:, idx].reshape(self.shape[0], -1, *self.shape[3:])[:, :length]
        v = self.v[:, idx].reshape(self.shape[0], -1, *self.shape[3:])[:, :length]
        return k, v

    def migrate(self, plan: MigrationPlan, *, use_kernel: bool = True) -> None:
        """§6.4 step 3: vectorised block migration (kernel-backed)."""
        if not len(plan):
            return
        from ..kernels import block_migration
        src = jnp.asarray(plan.src, jnp.int32)
        dst = jnp.asarray(plan.dst, jnp.int32)
        self.k = block_migration.migrate_blocks(self.k, src, dst,
                                                use_kernel=use_kernel)
        self.v = block_migration.migrate_blocks(self.v, src, dst,
                                                use_kernel=use_kernel)

    def spill_blocks(self, ids: Sequence[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Device→host gather of whole blocks for the host KV tier: one
        batched index gather per array (the spill half of the offload
        path), returned as host-side numpy of shape (L, n, bs, KH, hd)."""
        idx = jnp.asarray(list(ids), jnp.int32)
        return np.asarray(self.k[:, idx]), np.asarray(self.v[:, idx])

    def restore_blocks(self, ids: Sequence[int], k_payload, v_payload) -> None:
        """Host→device scatter of spilled blocks back into the pool — the
        same batched index-vector scatter the block-migration kernel's
        oracle performs, with the source staged from host memory."""
        idx = jnp.asarray(list(ids), jnp.int32)
        self.k = self.k.at[:, idx].set(jnp.asarray(k_payload, self.k.dtype))
        self.v = self.v.at[:, idx].set(jnp.asarray(v_payload, self.v.dtype))
