"""Sharding rules: parameter / batch / cache PartitionSpecs for every family.

Scheme (DESIGN.md §4):
  * training: 2D FSDP x TP — every weight sharded P(fsdp=data, tp=model) on
    its two largest dims (ZeRO-3 semantics: XLA all-gathers at use);
    activations constrained to (batch, sequence) sharding between layers
    (Megatron-style sequence parallelism on the residual stream).
  * serving ("tp" weight mode): weights replicated over data (replica
    groups), sharded over model; the KV cache shards its sequence dim over
    `model` (context parallelism — flash-decoding with an LSE-combining
    psum, inserted automatically by SPMD or explicitly via
    collectives.decode_attention).
  * every dim assignment is divisibility-checked with graceful fallback, so
    odd vocab sizes (whisper 51865) and head counts (qwen3 40H) stay valid.

Multi-pod: the leading `pod` axis joins the batch axes (pure DP) — weights
replicate across pods, gradients all-reduce over `pod`.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# activation-sharding hook (used inside model code; no-op by default)
# ---------------------------------------------------------------------------

_ACT_SPEC: contextvars.ContextVar = contextvars.ContextVar("act_spec", default=None)


def shard_activations(x):
    """Constrain the residual stream (B, S, d) between layers."""
    spec = _ACT_SPEC.get()
    if spec is None:
        return x
    batch_axes, seq_axis = spec
    if x.ndim < 3:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, seq_axis, *([None] * (x.ndim - 2))))


def shard_moe_slots(x):
    """Constrain MoE dispatch buffers (G, E, C, d): the group dim G is
    aligned with the data-parallel batch axes, keeping every dispatch
    gather/compute buffer shard-local instead of replicated at
    million-token dispatch sizes."""
    spec = _ACT_SPEC.get()
    if spec is None or x.ndim != 4:
        return x
    batch_axes, _ = spec
    return jax.lax.with_sharding_constraint(x, P(batch_axes, None, None, None))


def shard_decode_scores(s):
    """Constrain decode attention scores (B, KH, G, T, S): context dim S over
    the model axis.  Steers SPMD toward the flash-decoding schedule (partial
    softmax per KV shard + small LSE all-reduce) instead of all-gathering the
    KV cache for the contraction."""
    spec = _ACT_SPEC.get()
    if spec is None or s.ndim != 5:
        return s
    batch_axes, seq_axis = spec
    return jax.lax.with_sharding_constraint(
        s, P(batch_axes, None, None, None, seq_axis))


def replicate_new_kv(x):
    """Constrain freshly projected decode K/V (B, T, KH, hd) to be replicated
    over the model axis BEFORE the cache write.  The projection output is
    head-sharded (TP weights); merging it into the sequence-sharded cache
    without this hint makes SPMD reshard the multi-GB cache instead of the
    multi-KB new tokens (observed +21 GB temp / +8.6 GB collectives per
    decode step — EXPERIMENTS §Perf)."""
    spec = _ACT_SPEC.get()
    if spec is None or x.ndim != 4:
        return x
    batch_axes, _ = spec
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, None, None, None))


def shard_kv_cache(x):
    """Constrain a (B, S, KH, hd) KV cache layer: batch over data axes,
    sequence over the model axis (context parallelism)."""
    spec = _ACT_SPEC.get()
    if spec is None or x.ndim != 4:
        return x
    batch_axes, seq_axis = spec
    return jax.lax.with_sharding_constraint(
        x, P(batch_axes, seq_axis, None, None))


@contextlib.contextmanager
def activation_sharding(batch_axes, seq_axis):
    tok = _ACT_SPEC.set((batch_axes, seq_axis))
    try:
        yield
    finally:
        _ACT_SPEC.reset(tok)


# ---------------------------------------------------------------------------
# rule engine
# ---------------------------------------------------------------------------


def _div(n: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0


def _assign(dims, shape, idx, axes, mesh):
    """Put `axes` on dims[idx] if divisible and still free."""
    if dims[idx] is None and axes is not None and _div(shape[idx], mesh, axes):
        dims[idx] = axes
        return True
    return False


def _leaf_spec(name: str, shape: Tuple[int, ...], mesh: Mesh, *,
               fsdp, tp, scan_prefix: bool, seq_attn: bool = False) -> P:
    """PartitionSpec for one parameter leaf, keyed on its field name.

    seq_attn (decode/context-parallel mode): attention projections shard
    their CONTRACTION dims so q/k/v/o come out replicated (small psums) and
    the KV cache — sharded over sequence — never has to be resharded."""
    r = len(shape)
    dims: list = [None] * r
    off = 1 if (scan_prefix and r >= 2) else 0  # stacked layer dim

    def a(i, axes):
        return _assign(dims, shape, off + i, axes, mesh)

    eff = r - off  # effective rank
    if name in ("wq", "wk", "wv"):            # (d, H, hd)
        if seq_attn:
            a(0, tp)                           # row-parallel: psum tiny qkv
        else:
            a(0, fsdp)
            a(1, tp) or a(2, tp)
    elif name == "wo":                         # (H, hd, d)
        if seq_attn:
            a(0, tp) or a(1, tp)               # contraction dims: psum o
        else:
            (a(0, tp) or a(1, tp))
            a(2, fsdp)
    elif name in ("wg", "wu", "w1"):           # (d, f) or (E, d, f)
        if eff == 3:                           # moe experts
            a(1, fsdp)
            a(2, tp)
        else:
            a(0, fsdp)
            a(1, tp)
    elif name in ("wd", "w2"):                 # (f, d) or (E, f, d)
        if eff == 3:
            a(1, tp)
            a(2, fsdp)
        else:
            a(0, tp)
            a(1, fsdp)
    elif name == "router":                     # (d, E)
        a(0, fsdp)
    elif name in ("embed", "lm_head"):         # (V, d)
        a(0, tp)
        a(1, fsdp)
    elif name in ("pos_embed", "enc_pos", "dec_pos"):  # (Pmax, d)
        a(0, tp)
        a(1, fsdp)
    elif name == "image_proj":                 # (d, d)
        a(0, fsdp)
        a(1, tp)
    elif name == "in_proj":                    # (d, Z)
        a(0, fsdp)
        a(1, tp)
    elif name == "out_proj":                   # (d_in, d)
        a(0, tp)
        a(1, fsdp)
    # conv_w / biases / norms / A_log / D / dt_bias: replicated
    return P(*dims)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", getattr(entry, "idx", None))
        if isinstance(key, str) and key not in ("scale", "bias"):
            return key
    return ""


def param_specs(cfg, param_tree, mesh: Mesh, *, weight_mode: str = "fsdp",
                ) -> Any:
    """Pytree of PartitionSpecs matching `param_tree` (shapes or arrays).

    weight_mode: "fsdp" (train: 2D shard), "tp" (serve: replicate over data,
    head-parallel attention), "tp_seq" (decode: context-parallel attention —
    attention projections row-parallel so new K/V are replicated and the
    sequence-sharded cache is never resharded)."""
    fsdp = "data" if weight_mode == "fsdp" else None
    tp = "model"
    seq_attn = weight_mode == "tp_seq"
    scan_prefix = bool(getattr(cfg, "scan_layers", False))

    def rule(path, leaf):
        name = _leaf_name(path)
        in_layers = any(getattr(e, "key", None) in
                        ("layers", "enc_layers", "dec_layers")
                        for e in path if hasattr(e, "key"))
        # list-based layers (hybrid) have an integer index => not stacked
        stacked = scan_prefix and in_layers
        return _leaf_spec(name, leaf.shape, mesh, fsdp=fsdp, tp=tp,
                          scan_prefix=stacked, seq_attn=seq_attn)

    return jax.tree_util.tree_map_with_path(rule, param_tree)


def to_named(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def data_specs(cfg, batch_tree, mesh: Mesh) -> Any:
    """Input batch: tokens/labels (B, S); enc_emb (B, S, d); image_emb."""
    ba = batch_axes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        B = leaf.shape[0]
        dims: list = [None] * len(leaf.shape)
        if _div(B, mesh, ba):
            dims[0] = ba
        if name == "enc_emb" and _div(leaf.shape[1], mesh, "model"):
            dims[1] = "model"  # sequence-parallel frames
        return P(*dims)

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_specs(cfg, cache_tree, mesh: Mesh) -> Any:
    """Decode caches: KV sequence dim over `model` (context parallelism);
    batch over the data axes; SSM state heads over `model`."""
    ba = batch_axes(mesh)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        dims: list = [None] * len(shape)
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            # (L, B, S, KH, hd)
            if _div(shape[1], mesh, ba):
                dims[1] = ba
            if _div(shape[2], mesh, "model"):
                dims[2] = "model"
        elif name == "ssm":                    # (L, B, H, P, N)
            if _div(shape[1], mesh, ba):
                dims[1] = ba
            if _div(shape[2], mesh, "model"):
                dims[2] = "model"
        elif name == "conv":                   # (L, B, K-1, C)
            if _div(shape[1], mesh, ba):
                dims[1] = ba
        # length / enc_len: replicated
        return P(*dims)

    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def token_specs(tok_tree, mesh: Mesh) -> Any:
    ba = batch_axes(mesh)

    def rule(path, leaf):
        dims: list = [None] * len(leaf.shape)
        if _div(leaf.shape[0], mesh, ba):
            dims[0] = ba
        return P(*dims)

    return jax.tree_util.tree_map_with_path(rule, tok_tree)
