"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.

38L d=2048 32H (kv=32) shared-block ff=8192, ssm_state=64, vocab=32000.
[arXiv:2411.15242]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
    scan_layers=False,        # heterogeneous layer sequence
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="zamba2-1.2b-draft",
    family="ssm",
    num_layers=4,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=32000,
    ssm_state=32,
    ssm_headdim=32,
    ssm_expand=2,
    tie_embeddings=True,
)
