"""gemma-7b [dense]: GeGLU, head_dim=256. 28L d=3072 16H (kv=16) ff=24576
vocab=256000.  [arXiv:2403.08295]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    mlp_type="geglu",
    rmsnorm_offset=True,     # gemma's (1 + w) RMSNorm
    embed_scale=True,        # embeddings scaled by sqrt(d_model)
    tie_embeddings=True,
)

DRAFT = ModelConfig(
    name="gemma-7b-draft",
    family="dense",
    num_layers=4,
    d_model=768,
    num_heads=4,
    num_kv_heads=1,          # MQA draft (gemma-2b style)
    head_dim=256,
    d_ff=2048,
    vocab_size=256_000,
    mlp_type="geglu",
    rmsnorm_offset=True,
    embed_scale=True,
    tie_embeddings=True,
)
